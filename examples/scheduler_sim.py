"""Reproduce the paper's simulation study (Figs. 7, 8 and Table 1 sim
columns) — the C3 artifact.

    PYTHONPATH=src python examples/scheduler_sim.py [--seeds 100]

With --seeds 100 this is the paper's full experiment (~a minute); the default
uses 20 seeds for a quick look.
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=20)
    args = ap.parse_args()

    import numpy as np

    from repro.core.simulator import (VARIANTS, make_jacobi_jobs, run_variant)

    def sweep(label, gaps, tgap=None, gap=None):
        print(f"\n=== {label} ===")
        hdr = f"{'policy':10s}" + "".join(f"{g:>22}" for g in gaps)
        print(hdr)
        for metric_i, metric in enumerate(
                ["total", "util", "resp", "compl"]):
            print(f"-- {metric}")
            for v in VARIANTS:
                cells = []
                for g in gaps:
                    rows = []
                    for seed in range(args.seeds):
                        specs = make_jacobi_jobs(seed=seed, n_jobs=16,
                                                 submission_gap=float(
                                                     g if tgap is None else gap))
                        m = run_variant(
                            v, specs, total_slots=64,
                            rescale_gap=float(g if tgap is not None else 180.0))
                        rows.append([m.total_time, m.utilization,
                                     m.weighted_mean_response,
                                     m.weighted_mean_completion])
                    cells.append(np.mean(rows, axis=0)[metric_i])
                fmt = "{:>22.2%}" if metric == "util" else "{:>22.1f}"
                print(f"{v:10s}" + "".join(fmt.format(c) for c in cells))

    # Fig. 7: submission-gap sweep
    sweep("Fig. 7 — vary submission gap (T_rescale_gap=180s)",
          [0, 60, 120, 180, 240, 300])
    # Fig. 8: T_rescale_gap sweep
    sweep("Fig. 8 — vary T_rescale_gap (submission gap=180s)",
          [0, 180, 600, 1200], tgap=True, gap=180.0)

    # Table 1 (sim columns), one configuration
    print("\n=== Table 1 (simulation) — gap=90s, T_rescale_gap=180s ===")
    specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
    for v in VARIANTS:
        m = run_variant(v, specs, total_slots=64, rescale_gap=180.0)
        print(f"{v:10s} {m.row()}")


if __name__ == "__main__":
    main()
