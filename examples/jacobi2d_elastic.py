"""The paper's own workload: a 2-D Jacobi solver — implemented in JAX and run
ELASTICALLY: the grid is resharded across a changing device set mid-solve,
reproducing Fig. 6's timeline (slower after shrink, faster after expand) with
bit-exact iterates.

    PYTHONPATH=src python examples/jacobi2d_elastic.py [--n 512] [--iters 60]
"""
import argparse
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--iters", type=int, default=60)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    n = args.n
    devs = jax.devices()

    def make_step(ndev):
        mesh = Mesh(np.array(devs[:ndev]).reshape(ndev, 1), ("x", "y"))
        sh = NamedSharding(mesh, P("x", None))

        @jax.jit
        def step(g):
            up = jnp.roll(g, 1, 0)
            down = jnp.roll(g, -1, 0)
            left = jnp.roll(g, 1, 1)
            right = jnp.roll(g, -1, 1)
            out = 0.25 * (up + down + left + right)
            # fixed boundary
            out = out.at[0, :].set(1.0).at[-1, :].set(0.0)
            return jax.lax.with_sharding_constraint(out, sh)
        return step, sh

    grid = jnp.zeros((n, n)).at[0, :].set(1.0)
    step, sh = make_step(4)
    grid = jax.device_put(grid, sh)

    phases = [(4, args.iters // 3), (2, args.iters // 3), (8, args.iters // 3)]
    reference = None
    t_hist = []
    for ndev, iters in phases:
        t0 = time.perf_counter()
        # elastic rescale: reshard the live grid onto the new device set
        step, sh = make_step(ndev)
        grid = jax.device_put(grid, sh)
        t_rescale = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(iters):
            grid = step(grid)
        grid.block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        t_hist.append((ndev, dt))
        print(f"devices={ndev}: rescale={t_rescale * 1e3:6.1f}ms  "
              f"{dt * 1e6:8.1f} us/iter  residual={float(jnp.abs(grid).sum()):.4f}")

    # verify against a single-device solve (elasticity must not change math)
    ref = jnp.zeros((n, n)).at[0, :].set(1.0)
    step1, _ = make_step(1)
    for _ in range(sum(i for _, i in phases)):
        ref = step1(ref)
    err = float(jnp.max(jnp.abs(ref - jax.device_get(grid))))
    print(f"max |elastic - static| = {err:.3e}")
    assert err < 1e-5


if __name__ == "__main__":
    main()
