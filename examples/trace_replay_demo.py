"""Trace replay walkthrough: load the bundled Azure-style sample trace,
characterize its shape, and replay it open-loop through the node-autoscaled
cloud simulator — then compare against a static fleet running the same
arrivals rigidly, the way a conventional batch scheduler would have.

The elastic replay also runs under the repro.obs flight recorder: the run's
JSONL trace is rendered as a text Gantt timeline and re-audited for
conservation invariants (slot ownership, dollar conservation, preempt/resume
pairing) — proof the replay's accounting holds together from the trace alone.

    PYTHONPATH=src python examples/trace_replay_demo.py
"""
from repro.cloud import (AutoscalerConfig, CloudProvider, NodeAutoscaler,
                         NodePool)
from repro.obs import Tracer
from repro.obs.audit import audit_records
from repro.obs.timeline import render
from repro.workloads import (ReplayConfig, characterize, fixture_path,
                             load_azure_trace, replay_cloud)

CLUSTER_SLOTS = 64
SLOTS_PER_NODE = 8


def provider(initial_nodes: int) -> CloudProvider:
    return CloudProvider([NodePool(
        "od", slots_per_node=SLOTS_PER_NODE, price_per_slot_hour=0.048,
        boot_latency=120.0, teardown_delay=30.0,
        max_nodes=CLUSTER_SLOTS // SLOTS_PER_NODE,
        initial_nodes=initial_nodes)], seed=5)


def main():
    raw = load_azure_trace(fixture_path("azure_sample.csv"))
    trace = raw.normalized(CLUSTER_SLOTS)
    stats = characterize(trace)
    print(f"trace: {raw.name} ({raw.source})")
    print(f"shape: {stats.describe()}")
    cfg = ReplayConfig(cluster_slots=CLUSTER_SLOTS)

    print("\n-- static fleet, rigid jobs at their observed request size --")
    rigid = replay_cloud(trace, cfg, provider(CLUSTER_SLOTS // SLOTS_PER_NODE),
                         variant="rigid")
    print(rigid.metrics.row())

    print("\n-- autoscaled fleet, elastic policy (flight recorder on) --")
    asc_prov = provider(initial_nodes=1)
    autoscaler = NodeAutoscaler(asc_prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=180.0, headroom_slots=SLOTS_PER_NODE))
    tracer = Tracer()   # in-memory: keeps .records instead of writing JSONL
    elastic = replay_cloud(trace, cfg, asc_prov, variant="elastic",
                           autoscaler=autoscaler, tracer=tracer)
    print(elastic.metrics.row())
    print(f"autoscaler: {autoscaler.scale_ups} scale-ups, "
          f"{autoscaler.scale_downs} scale-downs")

    print(f"\n-- flight recorder: {len(tracer.records)} records --")
    print(render(tracer.records, width=64, max_jobs=16))
    for report in audit_records(tracer.records, source="replay"):
        print(report.summary())

    saving = 1.0 - elastic.metrics.total_cost / rigid.metrics.total_cost
    wmct_gain = 1.0 - (elastic.metrics.weighted_mean_completion
                       / rigid.metrics.weighted_mean_completion)
    print(f"\nelastic+autoscaler vs rigid static fleet: "
          f"{saving:.1%} cheaper, WMCT {wmct_gain:+.1%}")


if __name__ == "__main__":
    main()
