"""Flagship end-to-end demo — the paper's system in one run.

A live cluster controller (the Kubernetes-operator analog) schedules five
REAL JAX training jobs with different priorities onto 8 device slots using
the paper's elastic policy.  Watch:

  * the low-priority job start wide, get SHRUNK when a high-priority job
    arrives (Fig. 2 path), and EXPAND back on completions (Fig. 3 path);
  * a mid-run node failure: the victim restarts from its disk checkpoint
    (paper §3.2.2 fault tolerance);
  * final cluster metrics (the paper's four: makespan, utilization,
    weighted response/completion times).

    PYTHONPATH=src python examples/elastic_cluster_demo.py
"""
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")


def main():
    import jax

    from repro.checkpoint import DiskCheckpointStore
    from repro.configs import smoke_config
    from repro.core import (ElasticClusterController, ElasticTrainer, JobSpec,
                            PolicyConfig, TrainJobConfig)

    devs = jax.devices()
    store = DiskCheckpointStore(tempfile.mkdtemp(prefix="elastic_ckpt_"))
    op = ElasticClusterController(
        devs, slots=8, policy=PolicyConfig(rescale_gap=0.0),
        disk_store=store, steps_per_tick=2)

    def factory(arch, steps, seed):
        def f(devices):
            return ElasticTrainer(
                smoke_config(arch),
                TrainJobConfig(global_batch=8, seq_len=32, total_steps=steps,
                               seed=seed), devices)
        return f

    jobs = [
        ("batch-lowprio", 1, 2, 8, 0.000, "yi-6b", 28),
        ("interactive", 5, 4, 8, 0.001, "granite-moe-3b-a800m", 10),
        ("research-a", 3, 2, 4, 0.002, "mamba2-1.3b", 16),
        ("research-b", 3, 2, 4, 0.003, "yi-6b", 12),
        ("nightly", 2, 2, 8, 0.004, "minitron-4b", 14),
    ]
    for jid, prio, mn, mx, sub, arch, steps in jobs:
        op.submit(JobSpec(jid, prio, mn, mx, sub, divides=8),
                  factory(arch, steps, hash(jid) % 97), checkpoint_every=4)
        print(f"submitted {jid:14s} prio={prio} replicas=[{mn},{mx}] ({arch})")

    # advance a few ticks, then kill a node under research-a
    op._process_submissions()
    for _ in range(2):
        for j in list(op.cluster.jobs.values()):
            lv = op.live[j.job_id]
            if lv.trainer is not None and not lv.trainer.done \
                    and j.status.value == "running":
                lv.trainer.step()
    if "research-a" in op.cluster.jobs and \
            op.cluster.jobs["research-a"].status.value == "running":
        op.live["research-a"].trainer.save_disk(store, "research-a")
        print(">>> injecting node failure into research-a ...")
        op.inject_failure("research-a")

    metrics = op.run()

    print("\n--- rescale events (job: old->new, stage breakdown) ---")
    for t, jid, old, new, tm in op.rescale_events:
        print(f"  {jid:14s} {old}->{new}  total={tm.total:5.2f}s "
              f"(lb={tm.load_balance:.3f} ckpt={tm.checkpoint:.3f} "
              f"restart={tm.restart:.2f} restore={tm.restore:.3f})")
    print("\n--- jobs ---")
    for jid, j in sorted(op.cluster.jobs.items()):
        lv = op.live[jid]
        print(f"  {jid:14s} status={j.status.value:9s} "
              f"rescales={j.rescale_count} failures={lv.failures} "
              f"steps={lv.trainer.step_idx if lv.trainer else '-'}")
    print(f"\ncluster metrics: {metrics.row()}")
    assert metrics.dropped_jobs == 0


if __name__ == "__main__":
    main()
