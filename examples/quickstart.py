"""Quickstart: train a small LM end-to-end with the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch yi-6b] [--steps 30]

Uses the reduced (smoke) variant of the chosen architecture so it runs on a
laptop/CI CPU in ~a minute; pass --full on real hardware.
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, smoke_config
    from repro.core.elastic import ElasticTrainer, TrainJobConfig

    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    job = TrainJobConfig(global_batch=8, seq_len=64, total_steps=args.steps,
                         seed=0, peak_lr=3e-3)
    trainer = ElasticTrainer(cfg, job, jax.devices())
    n = sum(x.size for x in jax.tree.leaves(trainer.params))
    print(f"training {cfg.name}: {n:,} params on {len(jax.devices())} device(s)")
    while not trainer.done:
        m = trainer.step()
        if m["step"] % 5 == 0 or trainer.done:
            print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"lr {m['lr']:.2e}  grad_norm {m['grad_norm']:.2f}")
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
