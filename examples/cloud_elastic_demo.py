"""Cloud elasticity walkthrough: a node-autoscaled cluster rides a bursty
job stream, survives a spot-market preemption (victim checkpoints to disk,
requeues, resumes with progress intact), and the bill is itemized at the end.

    PYTHONPATH=src python examples/cloud_elastic_demo.py
"""
from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool, NodeState)
from repro.core.autoscale import PreemptingPolicy
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload


def workload(steps, slow=2.0, fast=1.0):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, slow), (32.0, fast))),
        total_work=float(steps), data_bytes=2e9, rescale=RescaleModel())


def main():
    provider = CloudProvider([
        NodePool("on-demand", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=120.0, teardown_delay=30.0, initial_nodes=1,
                 max_nodes=6),
        NodePool("spot", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=90.0, teardown_delay=30.0,
                 max_nodes=6, spot_lifetime_mean=250.0),  # volatile market
    ], seed=42)
    autoscaler = NodeAutoscaler(provider, AutoscalerConfig(
        tick_interval=20.0, scale_up_cooldown=20.0, scale_down_cooldown=90.0,
        idle_timeout=150.0, spot_fraction=0.5, budget_cap=5.0))
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(provider, pcfg, policy=PreemptingPolicy(pcfg),
                         autoscaler=autoscaler)

    # a morning burst, then a lull, then one afternoon straggler
    for i in range(5):
        sim.submit(JobSpec(f"burst{i}", priority=1 + i % 4, min_replicas=4,
                           max_replicas=16, submit_time=10.0 + 5.0 * i),
                   workload(180))
    sim.submit(JobSpec("straggler", priority=5, min_replicas=8,
                       max_replicas=16, submit_time=1200.0), workload(120))

    metrics = sim.run()
    print("== schedule ==")
    for job in sorted(sim.cluster.jobs.values(),
                      key=lambda j: j.spec.submit_time):
        print(f"  {job.job_id:10s} prio={job.priority} "
              f"start={job.start_time:7.1f}s end={job.end_time:7.1f}s "
              f"preempted={job.preempt_count}x rescaled={job.rescale_count}x")
    print("== nodes ==")
    for node in provider.nodes.values():
        up = f"{node.up_at:7.1f}" if node.up_at is not None else "  never"
        print(f"  {node.node_id:12s} [{node.pool.name:9s}] state="
              f"{node.state.value:12s} up_at={up}s "
              f"billed={node.billed_hours(sim.now):5.3f}h")
    print("== the bill ==")
    r = sim.cost_report
    print(f"  total     ${r.total_cost:7.4f}")
    print(f"  wasted    ${r.idle_cost:7.4f}  ({r.idle_fraction:.1%} idle)")
    print(f"  node-hrs  {r.node_hours:7.2f}")
    print(f"  spot preemptions: {r.spot_preemptions} "
          f"(job victims: {sim.spot_victim_jobs})")
    print("  per-job attribution ($, blended on-demand/spot rate):")
    for job_id, dollars in sorted(r.job_costs.items()):
        print(f"    {job_id:10s} ${dollars:7.4f}")
    print("== summary ==")
    print(" ", metrics.row())
    print(f"  autoscaler: {autoscaler.scale_ups} scale-ups, "
          f"{autoscaler.scale_downs} scale-downs")


if __name__ == "__main__":
    main()
