"""Batched serving demo: prefill + greedy decode on any architecture
(smoke-size on CPU), including the MLA latent-cache path and the SSM
recurrent-state path.

    PYTHONPATH=src python examples/serve_demo.py --arch deepseek-v2-236b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-236b")
    args = ap.parse_args()
    sys.argv = ["serve", "--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "16", "--gen", "12"]
    serve.main()


if __name__ == "__main__":
    main()
