"""Deterministic synthetic data pipeline with elastic re-balancing.

The global batch at step ``t`` is a pure function of ``(job_seed, t)`` —
independent of the replica count.  Rescaling a job therefore re-splits the
*same* global batch across the new replicas ("load balance" stage of the
paper's rescale pipeline, DESIGN.md §2), and a training run that shrinks and
expands produces bit-identical loss trajectories to a static run.  Tests pin
this invariance.

Tokens follow a Zipf-ish distribution with a deterministic Markov twist so the
loss actually decreases (a pure-uniform stream has no learnable signal).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class TokenStream:
    seed: int
    vocab_size: int
    global_batch: int
    seq_len: int

    def _rng(self, step: int, salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, salt]))

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch dict with (global_batch, seq_len) int32 tokens/labels."""
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        ranks = np.arange(1, V + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        base = rng.choice(V, size=(B, S + 1), p=probs).astype(np.int64)
        # learnable structure: every even position is a deterministic
        # function of the previous token
        nxt = (base * 2654435761 % V).astype(np.int64)
        base[:, 1::2] = nxt[:, 0:-1:2]
        return {"tokens": np.ascontiguousarray(base[:, :-1]).astype(np.int32),
                "labels": np.ascontiguousarray(base[:, 1:]).astype(np.int32)}

    def shard_bounds(self, replica_idx: int, num_replicas: int) -> Tuple[int, int]:
        assert self.global_batch % num_replicas == 0, \
            f"global_batch {self.global_batch} not divisible by {num_replicas}"
        per = self.global_batch // num_replicas
        return replica_idx * per, (replica_idx + 1) * per

    def shard_at(self, step: int, replica_idx: int, num_replicas: int):
        batch = self.global_batch_at(step)
        lo, hi = self.shard_bounds(replica_idx, num_replicas)
        return {k: v[lo:hi] for k, v in batch.items()}


@dataclass(frozen=True)
class EncDecStream(TokenStream):
    """Adds deterministic encoder frame embeddings (frontend stub output)."""
    enc_len: int = 0
    d_model: int = 0

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        batch = super().global_batch_at(step)
        rng = self._rng(step, salt=1)
        batch["enc_embeds"] = rng.standard_normal(
            (self.global_batch, self.enc_len, self.d_model)).astype(np.float32)
        return batch


def make_stream(cfg, *, seed: int, global_batch: int, seq_len: int,
                enc_len: int = 0):
    if cfg.enc_layers:
        return EncDecStream(seed=seed, vocab_size=cfg.vocab_size,
                            global_batch=global_batch, seq_len=seq_len,
                            enc_len=enc_len or seq_len, d_model=cfg.d_model)
    return TokenStream(seed=seed, vocab_size=cfg.vocab_size,
                       global_batch=global_batch, seq_len=seq_len)
