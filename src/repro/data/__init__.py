from repro.data.pipeline import TokenStream, EncDecStream, make_stream

__all__ = ["TokenStream", "EncDecStream", "make_stream"]
