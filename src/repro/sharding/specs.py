"""Logical-axis sharding (MaxText-style).

Every parameter and annotated activation carries *logical* axis names
(``'embed'``, ``'heads'``, ``'ffn'``, ``'experts'``, ``'batch'``, ...).  An
:class:`AxisRules` maps logical names to mesh axes.  Model code never mentions
mesh axes directly, so the same model lowers on a 1-device CPU, the 16x16
single-pod mesh, or the 2x16x16 multi-pod mesh — only the rules change.  This
is also the hillclimbing surface: §Perf iterations swap rule sets, nothing
else.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass
class AxisRules:
    """mesh + logical->mesh mapping. ``mesh=None`` disables all constraints.

    ``spec_for`` is *shape-aware*: a mesh axis is only assigned to a tensor
    dimension when the dimension size is divisible by it (GSPMD argument
    shardings must divide evenly).  Indivisible dims fall back to a divisible
    prefix of the requested axis tuple, or replication — and the freed mesh
    axis stays available for a later logical axis (e.g. when 4 kv_heads can't
    shard 16-way, the 'qk' head_dim rule picks up 'model' instead).
    """
    mesh: Optional[Mesh] = None
    rules: Dict[str, MeshAxes] = field(default_factory=dict)

    def spec_for(self, logical: Tuple[Optional[str], ...],
                 shape: Optional[Tuple[int, ...]] = None) -> P:
        out = []
        used = set()
        for i, name in enumerate(logical):
            ax = self.rules.get(name) if name else None
            if ax is None:
                out.append(None)
                continue
            axs = (ax,) if isinstance(ax, str) else tuple(ax)
            # a mesh axis may appear at most once in a PartitionSpec
            axs = tuple(a for a in axs
                        if a not in used and a in self.mesh.axis_names)
            if shape is not None:
                # keep the longest prefix whose size product divides the dim
                dim = shape[i]
                kept = []
                prod = 1
                for a in axs:
                    n = self.mesh.shape[a]
                    if dim % (prod * n) == 0:
                        kept.append(a)
                        prod *= n
                    else:
                        break
                axs = tuple(kept)
            used.update(axs)
            if not axs:
                out.append(None)
            elif len(axs) == 1:
                out.append(axs[0])
            else:
                out.append(axs)
        return P(*out)


_STATE = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def shard_constraint(x, *logical: Optional[str]):
    """Annotate activation ``x`` with logical axes; no-op without rules."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec_for(tuple(logical), tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def rule_axis_size(logical: str) -> int:
    """Product of mesh-axis sizes the current rules map ``logical`` to
    (1 when no rules are active or the name is unmapped)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return 1
    ax = r.rules.get(logical)
    if ax is None:
        return 1
    axs = (ax,) if isinstance(ax, str) else tuple(ax)
    prod = 1
    for a in axs:
        if a in r.mesh.axis_names:
            prod *= r.mesh.shape[a]
    return prod


def can_shard(n: int, logical: str) -> bool:
    """Whether dim size ``n`` divides the mesh axes the current rules map
    ``logical`` to (False when no rules are active)."""
    prod = rule_axis_size(logical)
    return prod > 1 and n % prod == 0


def logical_to_spec(rules: AxisRules, logical: Tuple[Optional[str], ...],
                    shape=None) -> P:
    return rules.spec_for(tuple(logical), shape)


def _is_axes_leaf(l) -> bool:
    return isinstance(l, tuple) and all(
        a is None or isinstance(a, str) for a in l)


def make_param_shardings(rules: AxisRules, logical_tree, shape_tree=None):
    """tree of logical-axis tuples (+ optional parallel tree of
    shapes/ShapeDtypeStructs) -> tree of NamedSharding."""
    if rules.mesh is None:
        return jax.tree.map(lambda _: None, logical_tree,
                            is_leaf=_is_axes_leaf)
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(rules.mesh, rules.spec_for(axes)),
            logical_tree, is_leaf=_is_axes_leaf)
    shapes = jax.tree.map(lambda s: tuple(s.shape) if hasattr(s, "shape")
                          else tuple(s), shape_tree)
    flat_a, treedef = jax.tree.flatten(logical_tree, is_leaf=_is_axes_leaf)
    flat_s = treedef.flatten_up_to(shapes)
    out = [NamedSharding(rules.mesh, rules.spec_for(a, tuple(s)))
           for a, s in zip(flat_a, flat_s)]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Rule sets (the hillclimbing surface — see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------
# Logical axes used by the model zoo:
#   batch, seq            activations
#   embed, embed2         residual/model dim (embed2 = second embed-sized dim)
#   heads, kv_heads, qk   attention projections
#   ffn                   dense-FFN hidden
#   vocab                 embedding / lm-head vocab dim
#   experts, expert_ffn   MoE
#   lora                  MLA low-rank dims
#   ssm_inner, ssm_state, ssm_heads
#   layers                stacked-scan leading axis (never sharded)
#   cache_seq             KV-cache sequence dim

def _base_rules() -> Dict[str, MeshAxes]:
    return {
        "layers": None,
        "batch": ("pod", "data"),
        "seq": None,
        "embed": None,
        "embed2": None,
        "heads": "model",
        "kv_heads": "model",
        # fallback: when heads/kv_heads cannot shard (indivisible), the
        # head_dim picks up 'model' (shape-aware spec_for drops used axes)
        "qk": "model",
        "ffn": "model",
        "vocab": "model",
        "experts": "model",
        "expert_ffn": None,
        "expert_cap": None,
        "lora": None,
        "ssm_inner": "model",
        "ssm_state": None,
        "ssm_heads": "model",
        "cache_seq": None,
        "cache_batch": ("pod", "data"),
    }


def rules_tp() -> Dict[str, MeshAxes]:
    """Pure tensor-parallel over 'model'; params replicated over 'data'."""
    return _base_rules()


def rules_tp_fsdp() -> Dict[str, MeshAxes]:
    """TP over 'model' + FSDP of params over ('pod','data') on the embed dim.

    Weights are stored fully sharded; GSPMD all-gathers them per layer.
    Required for the >30B archs (params do not fit replicated)."""
    r = _base_rules()
    r.update(embed=("pod", "data"))
    return r


def rules_tp_sp() -> Dict[str, MeshAxes]:
    """TP + sequence parallelism: residual-stream activations sharded over
    'model' on the sequence dim between layers (norms run sequence-local)."""
    r = _base_rules()
    r.update(seq="model")
    return r


def rules_tp_fsdp_sp() -> Dict[str, MeshAxes]:
    r = rules_tp_fsdp()
    r.update(seq="model")
    return r


def rules_decode() -> Dict[str, MeshAxes]:
    """Serving: KV cache batch-sharded over ('pod','data') and sequence-
    sharded over 'model' (context parallelism — scales to 500k contexts and
    sidesteps kv_heads < model_parallelism indivisibility)."""
    r = _base_rules()
    # cache_seq claims 'model' first on self-attn caches (batch, seq, kv, hd),
    # so kv_heads keeps its 'model' mapping for tensors WITHOUT a cache_seq
    # dim — e.g. seamless's cross-attention KV cache (35 GB/chip when
    # replicated; fits once head-sharded).  Shape-aware spec_for drops it
    # automatically where kv doesn't divide.
    r.update(cache_seq="model")
    return r


def rules_decode_long() -> Dict[str, MeshAxes]:
    """long_500k (batch=1): the data axis is idle for batch, so the KV cache
    sequence shards over BOTH ('data','model') — 512k/256 = 2k per chip."""
    r = rules_decode()
    r.update(cache_seq=("data", "model"))
    return r


def rules_decode_batch_model() -> Dict[str, MeshAxes]:
    """Serving for few-kv-head archs: shard cache batch over everything,
    replicate weights' head dims (avoids indivisible kv_heads/model)."""
    r = _base_rules()
    r.update(batch=("pod", "data", "model"),
             cache_batch=("pod", "data", "model"),
             heads=None, kv_heads=None, ffn=None, vocab=None,
             ssm_inner=None, ssm_heads=None, experts=None)
    return r


RULE_SETS = {
    "tp": rules_tp,
    "tp_fsdp": rules_tp_fsdp,
    "tp_sp": rules_tp_sp,
    "tp_fsdp_sp": rules_tp_fsdp_sp,
    "decode": rules_decode,
    "decode_long": rules_decode_long,
    "decode_batch_model": rules_decode_batch_model,
}


def rules_for(name: str, mesh: Optional[Mesh]) -> AxisRules:
    return AxisRules(mesh=mesh, rules=RULE_SETS[name]())
