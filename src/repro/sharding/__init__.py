from repro.sharding.specs import (AxisRules, axis_rules, can_shard, rule_axis_size,
                                  current_rules, logical_to_spec,
                                  make_param_shardings, shard_constraint,
                                  RULE_SETS, rules_for)

__all__ = ["AxisRules", "axis_rules", "can_shard", "rule_axis_size", "current_rules",
           "logical_to_spec", "make_param_shardings", "shard_constraint",
           "RULE_SETS", "rules_for"]
