"""Async checkpoint writes — overlap the disk write with compute.

The paper's preempt path is synchronous: stop stepping, snapshot, write,
release the slots.  That puts the full disk write on the critical path of
every preemption.  ``AsyncCheckpointer`` moves it off: ``submit`` snapshots
the tree to host RAM *inline* (cheap, and it pins the step's values — JAX
arrays are immutable, but the caller may rebind the name to the next step's
tree) then hands the disk write to a single background worker thread.
Training continues while the npz lands.

At preempt time the scheduler calls ``barrier()``: it joins all pending
writes, so the store's ``latest_step`` is guaranteed to name a fully
published (``os.replace``d) checkpoint — never a half-written one.  A write
that raised re-raises at the barrier instead of being silently dropped.

Serialization: one worker thread per checkpointer, writes drain in submit
order, so delta checkpoints chain correctly (each save sees its
predecessor's manifest).
"""
from __future__ import annotations

import queue
import threading
from typing import Optional

from repro.checkpoint.disk import DiskCheckpointStore
from repro.checkpoint.reshard import snapshot_to_host


class AsyncCheckpointer:
    def __init__(self, store: DiskCheckpointStore, *, delta: bool = True):
        self.store = store
        self.delta = delta
        self._q: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        self.pending = 0
        self.completed = 0

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            job_id, step, flat, meta = item
            try:
                self.store.save_flat(job_id, step, flat, meta,
                                     delta=self.delta)
                with self._lock:
                    self.completed += 1
            except BaseException as e:      # surfaced at barrier()
                with self._lock:
                    self._errors.append(e)
            finally:
                with self._lock:
                    self.pending -= 1
                self._q.task_done()

    def submit(self, job_id: str, step: int, tree,
               meta: Optional[dict] = None, *, fused: bool = False) -> None:
        """Snapshot ``tree`` to host now; write it to disk in the background."""
        flat = snapshot_to_host(tree, fused=fused)
        with self._lock:
            self.pending += 1
        self._q.put((job_id, step, flat, meta))
        self._ensure_worker()

    def barrier(self) -> None:
        """Block until every submitted write is fully published.

        After this returns, ``store.latest_step`` names a complete
        checkpoint — the preempt path calls this before releasing slots.
        Re-raises the first background write error, if any."""
        self._q.join()
        with self._lock:
            if self._errors:
                raise self._errors.pop(0)

    def close(self) -> None:
        self.barrier()
        if self._worker is not None and self._worker.is_alive():
            self._q.put(None)
            self._worker.join(timeout=5.0)
            self._worker = None
