"""In-memory checkpoint store — the /dev/shm analog (paper §3.1).

Charm++ checkpoints rescale state to Linux shared memory to avoid disk; here
the equivalent is a host-RAM dict of numpy arrays per job.  No persistent
volume, no filesystem.  ``nbytes`` feeds the rescale-overhead benchmarks
(paper Fig. 5 analog).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.checkpoint.reshard import snapshot_to_host


class MemoryCheckpointStore:
    def __init__(self):
        self._store: Dict[str, Dict[str, np.ndarray]] = {}
        self._meta: Dict[str, dict] = {}

    def save(self, job_id: str, tree, meta: Optional[dict] = None, *,
             fused: bool = False) -> float:
        """Checkpoint ``tree`` under ``job_id``; returns seconds taken.

        ``fused=True`` routes the device→host copies through the Pallas
        pack kernel (one transfer per dtype group)."""
        t0 = time.perf_counter()
        self._store[job_id] = snapshot_to_host(tree, fused=fused)
        self._meta[job_id] = dict(meta or {}, saved_at=time.time())
        return time.perf_counter() - t0

    def load(self, job_id: str) -> Dict[str, np.ndarray]:
        return self._store[job_id]

    def meta(self, job_id: str) -> dict:
        return self._meta[job_id]

    def nbytes(self, job_id: str) -> int:
        return sum(a.nbytes for a in self._store[job_id].values())

    def delete(self, job_id: str):
        self._store.pop(job_id, None)
        self._meta.pop(job_id, None)

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._store
