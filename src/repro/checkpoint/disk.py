"""Disk checkpoints — fault tolerance and preemption (paper §3.2.2).

The paper's scheduler deliberately avoids a shared filesystem; it notes that
fault tolerance and job preemption need disk checkpoints + a restart flag.
This store provides exactly that: atomic .npz snapshots with a json manifest,
``latest_step`` discovery, and restart-from-checkpoint used by the operator's
failure path and by the preemption policy in ``core/autoscale.py``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.reshard import snapshot_to_host


class DiskCheckpointStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, job_id: str) -> str:
        d = os.path.join(self.root, job_id)
        os.makedirs(d, exist_ok=True)
        return d

    def save(self, job_id: str, step: int, tree,
             meta: Optional[dict] = None) -> float:
        t0 = time.perf_counter()
        flat = snapshot_to_host(tree)
        d = self._dir(job_id)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz")
        os.close(fd)
        # npz keys cannot contain some path chars reliably -> index manifest
        keys = sorted(flat.keys())
        np.savez(tmp, **{f"a{i}": flat[k] for i, k in enumerate(keys)})
        os.replace(tmp, os.path.join(d, f"step_{step:09d}.npz"))
        manifest = {"step": step, "keys": keys, "meta": meta or {},
                    "saved_at": time.time()}
        mtmp = os.path.join(d, ".manifest.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(d, f"step_{step:09d}.json"))
        return time.perf_counter() - t0

    def latest_step(self, job_id: str) -> Optional[int]:
        d = os.path.join(self.root, job_id)
        if not os.path.isdir(d):
            return None
        steps = [int(f[5:-5]) for f in os.listdir(d)
                 if f.startswith("step_") and f.endswith(".json")]
        return max(steps) if steps else None

    def load(self, job_id: str, step: Optional[int] = None
             ) -> Tuple[Dict[str, np.ndarray], dict]:
        step = self.latest_step(job_id) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint for {job_id}")
        d = os.path.join(self.root, job_id)
        with open(os.path.join(d, f"step_{step:09d}.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, f"step_{step:09d}.npz")) as z:
            flat = {k: z[f"a{i}"] for i, k in enumerate(manifest["keys"])}
        return flat, manifest
