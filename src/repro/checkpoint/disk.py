"""Disk checkpoints — fault tolerance and preemption (paper §3.2.2).

The paper's scheduler deliberately avoids a shared filesystem; it notes that
fault tolerance and job preemption need disk checkpoints + a restart flag.
This store provides exactly that: atomic .npz snapshots with a json manifest,
``latest_step`` discovery, and restart-from-checkpoint used by the operator's
failure path and by the preemption policy in ``core/autoscale.py``.

Fast-lane additions (README §Checkpoint fast lane):

- **delta checkpoints** — ``save(..., delta=True)`` hashes every leaf
  (blake2b over the raw bytes) and rewrites only the leaves whose content
  changed since the previous manifest; unchanged cold weights are
  *referenced* from the step they were last written in (per-leaf
  ``{"file", "slot", "hash"}`` entries in the manifest).  A 2 GB/slot
  physics job (table5's shape) whose optimizer slabs churn but whose frozen
  weights don't stops rewriting the cold majority every preempt.
  ``last_bytes_written`` / ``manifest["bytes_written"]`` expose the actual
  payload for the table5 CSV gate.
- **atomicity under concurrency** — every save stages BOTH files through
  ``tempfile.mkstemp`` paths (the manifest used to funnel through one fixed
  ``.manifest.tmp``, so two concurrent saves for one job could interleave
  write/replace and publish a corrupt manifest), and a save that dies
  mid-``np.savez`` removes its orphaned tmp file.  Readers only ever see
  ``os.replace``d complete files; an orphan ``.npz`` without its manifest is
  invisible to ``latest_step``/``load``.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.reshard import snapshot_to_host


def _leaf_hash(arr: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).view(np.uint8).data)
    return h.hexdigest()


class DiskCheckpointStore:
    def __init__(self, root: str):
        self.root = root
        self.last_bytes_written = 0     # npz payload of the latest save
        os.makedirs(root, exist_ok=True)

    def _dir(self, job_id: str) -> str:
        d = os.path.join(self.root, job_id)
        os.makedirs(d, exist_ok=True)
        return d

    def _manifest_path(self, d: str, step: int) -> str:
        return os.path.join(d, f"step_{step:09d}.json")

    def save(self, job_id: str, step: int, tree,
             meta: Optional[dict] = None, *, delta: bool = False,
             fused: bool = False) -> float:
        flat = snapshot_to_host(tree, fused=fused)
        return self.save_flat(job_id, step, flat, meta, delta=delta)

    def save_flat(self, job_id: str, step: int, flat: Dict[str, np.ndarray],
                  meta: Optional[dict] = None, *, delta: bool = False
                  ) -> float:
        """Write an already host-resident ``{path-key: ndarray}`` snapshot.

        The async checkpointer snapshots inline and defers this call to a
        worker thread; going through ``save`` again would re-escape the
        ``/`` separators already present in the flat keys."""
        t0 = time.perf_counter()
        d = self._dir(job_id)
        keys = sorted(flat.keys())
        npz_name = f"step_{step:09d}.npz"

        # delta: reuse unchanged leaves from the previous manifest's files
        prev_leaves: Dict[str, dict] = {}
        if delta:
            prev_step = self.latest_step(job_id)
            if prev_step is not None and prev_step != step:
                with open(self._manifest_path(d, prev_step)) as f:
                    prev_leaves = self._leaf_index(json.load(f))
        leaves: Dict[str, dict] = {}
        to_write = []                       # (slot, key) pairs for OUR npz
        for i, k in enumerate(keys):
            # hash on EVERY save (not just delta ones) so any checkpoint can
            # serve as the delta base of the next
            h = _leaf_hash(np.asarray(flat[k]))
            prev = prev_leaves.get(k)
            if prev is not None and prev.get("hash") == h:
                leaves[k] = dict(prev)      # cold leaf: point at old file
            else:
                slot = f"a{len(to_write)}"
                to_write.append((slot, k))
                leaves[k] = {"file": npz_name, "slot": slot, "hash": h}

        # npz keys cannot contain some path chars reliably -> slot manifest
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        try:
            # write via the open fd: np.savez APPENDS ".npz" to a path that
            # lacks it (publishing the empty mkstemp file), never to a
            # file object
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **{slot: flat[k] for slot, k in to_write})
        except BaseException:
            # np.savez died mid-write: never leave the orphan tmp behind
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.last_bytes_written = os.path.getsize(tmp)
        os.replace(tmp, os.path.join(d, npz_name))

        manifest = {"step": step, "keys": keys, "leaves": leaves,
                    "meta": meta or {}, "saved_at": time.time(),
                    "delta": bool(prev_leaves),
                    "bytes_written": self.last_bytes_written}
        # a PER-SAVE tmp path: the old fixed ".manifest.tmp" let two
        # concurrent saves interleave write/replace and publish a manifest
        # whose bytes came from both
        mfd, mtmp = tempfile.mkstemp(dir=d, suffix=".json.tmp")
        try:
            with os.fdopen(mfd, "w") as f:
                json.dump(manifest, f)
            os.replace(mtmp, self._manifest_path(d, step))
        except BaseException:
            try:
                os.unlink(mtmp)
            except OSError:
                pass
            raise
        return time.perf_counter() - t0

    @staticmethod
    def _leaf_index(manifest: dict) -> Dict[str, dict]:
        """key -> {"file","slot","hash"} for any manifest generation: new
        manifests carry it verbatim; legacy ones (pre-delta) map key i to
        slot ``a{i}`` of their own npz."""
        if "leaves" in manifest:
            return manifest["leaves"]
        npz = f"step_{manifest['step']:09d}.npz"
        return {k: {"file": npz, "slot": f"a{i}", "hash": None}
                for i, k in enumerate(manifest["keys"])}

    def latest_step(self, job_id: str) -> Optional[int]:
        d = os.path.join(self.root, job_id)
        if not os.path.isdir(d):
            return None
        steps = [int(f[5:-5]) for f in os.listdir(d)
                 if f.startswith("step_") and f.endswith(".json")]
        return max(steps) if steps else None

    def load(self, job_id: str, step: Optional[int] = None
             ) -> Tuple[Dict[str, np.ndarray], dict]:
        step = self.latest_step(job_id) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint for {job_id}")
        d = os.path.join(self.root, job_id)
        with open(self._manifest_path(d, step)) as f:
            manifest = json.load(f)
        leaves = self._leaf_index(manifest)
        flat: Dict[str, np.ndarray] = {}
        by_file: Dict[str, list] = {}
        for k in manifest["keys"]:
            by_file.setdefault(leaves[k]["file"], []).append(k)
        for fname, ks in by_file.items():       # open each referenced npz once
            with np.load(os.path.join(d, fname)) as z:
                for k in ks:
                    flat[k] = z[leaves[k]["slot"]]
        return flat, manifest

    def nbytes_on_disk(self, job_id: str) -> int:
        """Total bytes of all npz files for ``job_id`` (delta-chain cost)."""
        d = os.path.join(self.root, job_id)
        if not os.path.isdir(d):
            return 0
        return sum(os.path.getsize(os.path.join(d, f))
                   for f in os.listdir(d) if f.endswith(".npz"))
