from repro.checkpoint.memory import MemoryCheckpointStore
from repro.checkpoint.disk import DiskCheckpointStore
from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.checkpoint.reshard import (device_reshard, flatten_tree,
                                      restore_from_host, snapshot_to_host,
                                      surviving_devices, tree_path_keys,
                                      unflatten_tree)

__all__ = ["MemoryCheckpointStore", "DiskCheckpointStore", "AsyncCheckpointer",
           "device_reshard", "snapshot_to_host", "restore_from_host",
           "flatten_tree", "unflatten_tree", "tree_path_keys",
           "surviving_devices"]
