from repro.checkpoint.memory import MemoryCheckpointStore
from repro.checkpoint.disk import DiskCheckpointStore
from repro.checkpoint.reshard import (device_reshard, flatten_tree,
                                      restore_from_host, snapshot_to_host,
                                      unflatten_tree)

__all__ = ["MemoryCheckpointStore", "DiskCheckpointStore", "device_reshard",
           "snapshot_to_host", "restore_from_host", "flatten_tree",
           "unflatten_tree"]
