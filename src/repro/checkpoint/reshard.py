"""Cross-mesh resharding of pytrees — the mechanism behind shrink/expand.

Two paths (DESIGN.md §2):

- paper-faithful: ``snapshot_to_host`` (checkpoint to host RAM, the /dev/shm
  analog) then ``restore_from_host`` with the new mesh's shardings;
- beyond-paper: ``device_reshard`` — a single ``jax.device_put`` straight onto
  the new shardings, letting the runtime move bytes device-to-device.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np


def flatten_tree(tree, prefix: str = "") -> Dict[str, object]:
    """pytree -> flat {'a/b/c': leaf} dict (stable, path-keyed)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[prefix + key] = leaf
    return flat


def unflatten_tree(template, flat: Dict[str, object], prefix: str = ""):
    """Rebuild a pytree shaped like ``template`` from a flat dict."""
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    leaves = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(flat[prefix + key])
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def snapshot_to_host(tree) -> Dict[str, np.ndarray]:
    """Device -> host-RAM snapshot (the paper's shared-memory checkpoint)."""
    flat = flatten_tree(tree)
    arrs = jax.device_get(list(flat.values()))
    return {k: np.asarray(v) for k, v in zip(flat.keys(), arrs)}


def restore_from_host(host_flat: Dict[str, np.ndarray], template, shardings):
    """Host snapshot -> device arrays under ``shardings`` (new mesh)."""
    tree = unflatten_tree(template, host_flat)
    return jax.device_put(tree, shardings)


def device_reshard(tree, shardings):
    """Live device-to-device reshard (no host round-trip)."""
    return jax.device_put(tree, shardings)
