"""Cross-mesh resharding of pytrees — the mechanism behind shrink/expand.

Three paths (DESIGN.md §2, README §Checkpoint fast lane):

- paper-faithful: ``snapshot_to_host`` (checkpoint to host RAM, the /dev/shm
  analog) then ``restore_from_host`` with the new mesh's shardings;
- beyond-paper: ``device_reshard`` — a single ``jax.device_put`` straight onto
  the new shardings, letting the runtime move bytes device-to-device.  This
  is the DEFAULT rescale path whenever source devices survive the resize
  (``surviving_devices`` detects the overlap);
- fused: ``snapshot_to_host(tree, fused=True)`` coalesces the per-leaf
  device->host copies through the Pallas pack kernel
  (``repro.kernels.pack``) — one contiguous transfer per dtype group
  instead of one small copy per leaf.

Path keys: every leaf is addressed by a stable ``a/b/0/c``-style string.
``GetAttrKey`` entries (NamedTuple / registered-dataclass pytrees) resolve
via ``.name`` — probing only ``.key``/``.idx`` used to stringify them as
``GetAttrKey(name='w')`` fragments like ``layer/.w``.  Literal ``/`` inside
dict keys is escaped (``%`` then ``/``) so ``{"a/b": x}`` can never collide
with ``{"a": {"b": x}}``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np


def _escape(part: str) -> str:
    """Escape a single path component so '/' stays a reserved separator."""
    return part.replace("%", "%25").replace("/", "%2F")


def _path_part(entry) -> str:
    """One pytree path entry -> string.  jax emits DictKey(.key),
    SequenceKey(.idx), GetAttrKey(.name), FlattenedIndexKey(.key); custom
    pytrees may emit anything — fall back to str(entry)."""
    for attr in ("key", "idx", "name"):
        v = getattr(entry, attr, None)
        if v is not None:
            return _escape(str(v))
    return _escape(str(entry))


def tree_path_keys(tree) -> List[Tuple[str, object]]:
    """[(stable 'a/b/c' key, leaf)] in tree_flatten_with_path order."""
    return [("/".join(_path_part(p) for p in path), leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]]


def flatten_tree(tree, prefix: str = "") -> Dict[str, object]:
    """pytree -> flat {'a/b/c': leaf} dict (stable, path-keyed)."""
    flat = {}
    for key, leaf in tree_path_keys(tree):
        full = prefix + key
        if full in flat:            # escaping makes this unreachable for
            raise ValueError(       # builtin containers; guard custom nodes
                f"duplicate pytree path key {full!r}")
        flat[full] = leaf
    return flat


def unflatten_tree(template, flat: Dict[str, object], prefix: str = ""):
    """Rebuild a pytree shaped like ``template`` from a flat dict."""
    leaves = [flat[prefix + key] for key, _ in tree_path_keys(template)]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def snapshot_to_host(tree, *, fused: bool = False) -> Dict[str, np.ndarray]:
    """Device -> host-RAM snapshot (the paper's shared-memory checkpoint).

    ``fused=True`` routes the copies through the Pallas pack kernel: leaves
    are gathered into one contiguous device buffer per dtype group and the
    host sees one large transfer instead of len(tree) small ones (the fig5
    slow-lane microbench quantifies the difference)."""
    if fused:
        from repro.kernels.pack import packed_snapshot_to_host
        return packed_snapshot_to_host(tree)
    flat = flatten_tree(tree)
    arrs = jax.device_get(list(flat.values()))
    return {k: np.asarray(v) for k, v in zip(flat.keys(), arrs)}


def restore_from_host(host_flat: Dict[str, np.ndarray], template, shardings):
    """Host snapshot -> device arrays under ``shardings`` (new mesh)."""
    tree = unflatten_tree(template, host_flat)
    return jax.device_put(tree, shardings)


def device_reshard(tree, shardings):
    """Live device-to-device reshard (no host round-trip)."""
    return jax.device_put(tree, shardings)


def surviving_devices(old: Sequence, new: Sequence) -> int:
    """How many of the OLD device set survive into the NEW one — the
    condition under which peer-to-peer resharding can skip the host
    round-trip (some source shards are already resident where the runtime
    can move them device-to-device)."""
    new_ids = {d.id for d in new}
    return sum(1 for d in old if d.id in new_ids)
