from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               abstract_opt_state, opt_logical_axes)
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "abstract_opt_state",
           "opt_logical_axes", "warmup_cosine"]
