"""AdamW with global-norm clipping (pure functions, fp32 moments).

Moments inherit each parameter's logical axes, so they shard exactly like the
parameter they track (ZeRO-like: with FSDP rules the optimizer state is fully
sharded too).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params) -> dict:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_logical_axes(param_axes) -> dict:
    ident = lambda a: a
    is_leaf = lambda l: isinstance(l, tuple)
    return {
        "m": jax.tree.map(ident, param_axes, is_leaf=is_leaf),
        "v": jax.tree.map(ident, param_axes, is_leaf=is_leaf),
        "count": (),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state, params, lr):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
