"""Decision-audit records: *why* the stack did what it did.

Every choice point in the scheduling stack can carry a :class:`DecisionLog`
(None by default — zero overhead when tracing is off).  A record names the
choice point, the verdict, the inputs that drove it, and the alternatives
that were considered and rejected:

====================  ======================================================
point                 emitted by
====================  ======================================================
``admit``             ``ElasticPolicy.on_new_job`` — immediate start /
                      shrink-pass / enqueue, with the dry-pass candidate list
``redistribute``      ``ElasticPolicy.on_job_complete`` — freed-slot grants
``preempt_select``    ``PreemptingPolicy.on_new_job`` — victim selection
``scale_up``          ``NodeAutoscaler._provision`` — pool preference order
                      and per-pool outcomes (budget / max_nodes)
``scale_down``        ``NodeAutoscaler.evaluate`` — drain victim + candidates
``bid_flip``          ``DemandAwareBidder.zone_quotas`` — a zone open<->closed
                      flip with the risk-vs-discount inputs that triggered it
====================  ======================================================

Records ride the same JSONL stream as the lifecycle spans (``kind:
"decision"``), so one trace file tells the whole story in time order.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional


class DecisionLog:
    """Thin adapter binding a choice point to a tracer.  Policies hold
    ``self.decisions = None`` until a traced run wires one in."""

    __slots__ = ("tracer",)

    def __init__(self, tracer):
        self.tracer = tracer

    def record(self, point: str, t: float, verdict: str, *,
               inputs: Optional[Dict[str, Any]] = None,
               alternatives: Optional[List[Dict[str, Any]]] = None) -> None:
        self.tracer.emit("decision", t=t, point=point, verdict=verdict,
                         inputs=inputs or {},
                         alternatives=alternatives or [])


def decision_records(records: Iterable[Dict[str, Any]],
                     point: Optional[str] = None) -> List[Dict[str, Any]]:
    """Filter a loaded trace down to decision records (optionally one point)."""
    return [r for r in records
            if r.get("kind") == "decision"
            and (point is None or r.get("point") == point)]
