"""Streaming statistics: P2 quantiles, counters, per-priority latency.

:class:`P2Quantile` is the Jain & Chlamtac (CACM 1985) P-squared estimator:
one quantile in O(1) memory (five markers), no sample buffer — so every
simulation can afford p50/p95/p99 of response/completion/queue-wait per
priority class, always on, without holding per-job latency arrays.

:class:`Counters` is the flat counter registry ``Simulator.run`` ticks per
event — ``events / sec`` falls out of the registry plus wall-clock, which is
what ``benchmarks/bench_simcore.py`` turns into the repo's perf trajectory.

:class:`LatencyRecorder` folds job lifecycle timestamps into the estimators
and renders them as the flat ``ScheduleMetrics.percentiles`` mapping
(``resp_p99``, ``wait_p95_prio5``, ...).
"""
from __future__ import annotations

import bisect
from typing import TYPE_CHECKING, Dict, Optional, Tuple

if TYPE_CHECKING:       # repro.core imports this module: no import cycle
    from repro.core.job import JobState


class P2Quantile:
    """Single-quantile P-squared estimator.  Exact for the first five
    observations; afterwards five markers track (min, q/2, q, (1+q)/2, max)
    with parabolic (fallback linear) height adjustment."""

    __slots__ = ("q", "_n", "_heights", "_pos", "_npos", "_dn")

    def __init__(self, q: float):
        assert 0.0 < q < 1.0, q
        self.q = q
        self._n = 0
        self._heights = []                       # type: list
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._npos = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self._dn = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)

    def observe(self, x: float) -> None:
        self._n += 1
        h = self._heights
        if self._n <= 5:
            bisect.insort(h, x)
            return
        # locate the cell, clamping the extremes
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and h[k + 1] <= x:
                k += 1
        pos, npos = self._pos, self._npos
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            npos[i] += self._dn[i]
        for i in (1, 2, 3):
            d = npos[i] - pos[i]
            if ((d >= 1.0 and pos[i + 1] - pos[i] > 1.0)
                    or (d <= -1.0 and pos[i - 1] - pos[i] < -1.0)):
                d = 1.0 if d > 0.0 else -1.0
                hp = self._parabolic(i, d)
                if not (h[i - 1] < hp < h[i + 1]):
                    hp = self._linear(i, d)
                h[i] = hp
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        return h[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, n = self._heights, self._pos
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (n[j] - n[i])

    @property
    def count(self) -> int:
        return self._n

    def value(self) -> float:
        if self._n == 0:
            return 0.0
        if self._n <= 5:                # exact empirical quantile
            idx = max(0, min(self._n - 1, int(self.q * self._n)))
            return self._heights[idx]
        return self._heights[2]


class Counters:
    """Flat monotonic counter registry."""

    __slots__ = ("_c",)

    def __init__(self):
        self._c: Dict[str, int] = {}

    def inc(self, name: str, n: int = 1) -> None:
        c = self._c
        c[name] = c.get(name, 0) + n

    def get(self, name: str) -> int:
        return self._c.get(name, 0)

    def as_dict(self) -> Dict[str, int]:
        return dict(self._c)


#: latency metrics tracked per job: response (submit -> first start),
#: completion (submit -> end), queue wait (total time spent QUEUED)
QUANTILES = (0.5, 0.95, 0.99)


class LatencyRecorder:
    """Per-priority-class streaming latency percentiles.

    ``mark_queued``/``mark_started`` bracket QUEUED episodes (initial queueing
    and preempt -> resume gaps both count as queue wait);
    ``observe_completed`` folds the finished job's response/completion/wait
    into the aggregate estimators and the job's priority-class estimators.
    """

    def __init__(self):
        # (metric, priority-or-None) -> {q: estimator}
        self._est: Dict[Tuple[str, Optional[int]],
                        Dict[float, P2Quantile]] = {}
        self._queued_at: Dict[str, float] = {}
        self._wait: Dict[str, float] = {}
        self.completed = 0

    def mark_queued(self, job_id: str, t: float) -> None:
        self._queued_at.setdefault(job_id, t)

    def mark_started(self, job_id: str, t: float) -> None:
        q = self._queued_at.pop(job_id, None)
        if q is not None:
            self._wait[job_id] = self._wait.get(job_id, 0.0) + max(0.0, t - q)

    def observe_completed(self, job: "JobState") -> None:
        from repro.core.job import completion_time, response_time
        self.completed += 1
        resp = response_time(job)
        comp = completion_time(job)
        wait = self._wait.pop(job.job_id, 0.0)
        self._queued_at.pop(job.job_id, None)
        for prio in (None, job.spec.priority):
            self._feed(("resp", prio), resp)
            self._feed(("compl", prio), comp)
            self._feed(("wait", prio), wait)

    def _feed(self, key: Tuple[str, Optional[int]],
              x: Optional[float]) -> None:
        if x is None:
            return
        ests = self._est.get(key)
        if ests is None:
            ests = self._est[key] = {q: P2Quantile(q) for q in QUANTILES}
        for est in ests.values():
            est.observe(x)

    def percentile_fields(self) -> Dict[str, float]:
        """Flat mapping for ``ScheduleMetrics.percentiles``: ``resp_p99``
        (all classes) and ``resp_p99_prio<k>`` (one priority class), for
        each of resp/compl/wait x p50/p95/p99."""
        out: Dict[str, float] = {}
        for (metric, prio) in sorted(
                self._est, key=lambda k: (k[0], k[1] is not None, k[1] or 0)):
            suffix = "" if prio is None else f"_prio{prio}"
            for q, est in self._est[(metric, prio)].items():
                out[f"{metric}_p{int(round(q * 100))}{suffix}"] = est.value()
        return out
