"""Streaming statistics: P2 quantiles, counters, per-priority latency.

:class:`P2Quantile` is the Jain & Chlamtac (CACM 1985) P-squared estimator:
one quantile in O(1) memory (five markers), no sample buffer — so every
simulation can afford p50/p95/p99 of response/completion/queue-wait per
priority class, always on, without holding per-job latency arrays.

:class:`Counters` is the flat counter registry ``Simulator.run`` ticks per
event — ``events / sec`` falls out of the registry plus wall-clock, which is
what ``benchmarks/bench_simcore.py`` turns into the repo's perf trajectory.

:class:`LatencyRecorder` folds job lifecycle timestamps into the estimators
and renders them as the flat ``ScheduleMetrics.percentiles`` mapping
(``resp_p99``, ``wait_p95_prio5``, ...).
"""
from __future__ import annotations

import bisect
from typing import TYPE_CHECKING, Dict, Optional, Tuple

if TYPE_CHECKING:       # repro.core imports this module: no import cycle
    from repro.core.job import JobState

# bound on first use by observe_completed (import cycle: repro.core.job
# imports this module at definition time)
completion_time = response_time = None


class P2Quantile:
    """Single-quantile P-squared estimator.  Exact for the first five
    observations; afterwards five markers track (min, q/2, q, (1+q)/2, max)
    with parabolic (fallback linear) height adjustment.

    ``observe`` runs 18x per completed job (3 metrics x 2 priority keys x
    3 quantiles) on the simulator hot path, where the textbook form's
    array-indexing loops were the single largest profiler line.  Two
    transformations keep it cheap without changing a single float op:

    - the five-marker update is fully unrolled — scalar slots and
      straight-line arithmetic, no marker arrays or helper calls.
      ``pos[0]``/``npos[0]`` are pinned at 1.0 by construction (marker 0
      never moves, ``dn[0] == 0``) and are folded into the constants;
    - observations land in a small bounded buffer (``observe`` is one list
      append) and are folded in batches by :meth:`_drain`, which keeps the
      whole estimator state in locals across the batch — per-observation
      attribute traffic and call dispatch amortize away.  The sequence the
      marker update sees is unchanged, so results are bit-identical to the
      one-at-a-time form.  Memory stays O(1): the buffer never exceeds
      ``_DRAIN_AT`` floats."""

    _DRAIN_AT = 64                     # buffered observations per fold

    __slots__ = ("q", "_n", "_small", "_buf",
                 "_h0", "_h1", "_h2", "_h3", "_h4",
                 "_p1", "_p2", "_p3", "_p4",
                 "_q1", "_q2", "_q3", "_q4",
                 "_d1", "_d2", "_d3")

    def __init__(self, q: float):
        assert 0.0 < q < 1.0, q
        self.q = q
        self._n = 0
        self._small = []                # first five observations, sorted
        self._buf = []                  # not-yet-folded observations
        self._p1, self._p2, self._p3, self._p4 = 2.0, 3.0, 4.0, 5.0
        self._q1 = 1.0 + 2.0 * q       # desired marker positions
        self._q2 = 1.0 + 4.0 * q
        self._q3 = 3.0 + 2.0 * q
        self._q4 = 5.0
        self._d1 = q / 2.0             # per-observation position increments
        self._d2 = q
        self._d3 = (1.0 + q) / 2.0

    def observe(self, x: float) -> None:
        buf = self._buf
        buf.append(x)
        if len(buf) >= self._DRAIN_AT:
            self._drain()

    def _drain(self) -> None:
        buf = self._buf
        if not buf:
            return
        self._buf = []
        self._absorb(buf)

    def _absorb(self, buf) -> None:
        """Fold a batch of observations (oldest first).  The caller owns
        ``buf`` and must have flushed ``_buf`` first — batches and single
        observations must land in arrival order."""
        n = self._n
        i = 0
        if n < 5:                      # exact phase: collect five, sorted
            small = self._small
            for x in buf:
                bisect.insort(small, x)
                n += 1
                i += 1
                if n == 5:
                    self._h0, self._h1, self._h2, self._h3, self._h4 = small
                    break
            if n < 5:
                self._n = n
                return
        h0, h1, h2, h3, h4 = self._h0, self._h1, self._h2, self._h3, self._h4
        p1, p2, p3, p4 = self._p1, self._p2, self._p3, self._p4
        q1, q2, q3 = self._q1, self._q2, self._q3
        d1, d2, d3 = self._d1, self._d2, self._d3
        for x in buf[i:] if i else buf:
            n += 1
            # locate the cell (clamping the extremes) and bump every marker
            # position above it
            if x < h0:
                h0 = x
                p1 += 1.0
                p2 += 1.0
                p3 += 1.0
            elif x >= h4:
                h4 = x
            elif x < h1:
                p1 += 1.0
                p2 += 1.0
                p3 += 1.0
            elif x < h2:
                p2 += 1.0
                p3 += 1.0
            elif x < h3:
                p3 += 1.0
            p4 += 1.0
            q1 += d1
            q2 += d2
            q3 += d3
            # -- marker 1 (neighbors: pos0 == 1.0, pos2) ----------------------
            d = q1 - p1
            if ((d >= 1.0 and p2 - p1 > 1.0)
                    or (d <= -1.0 and 1.0 - p1 < -1.0)):
                d = 1.0 if d > 0.0 else -1.0
                hp = h1 + d / (p2 - 1.0) * (
                    (p1 - 1.0 + d) * (h2 - h1) / (p2 - p1)
                    + (p2 - p1 - d) * (h1 - h0) / (p1 - 1.0))
                if not (h0 < hp < h2):
                    if d > 0.0:
                        hp = h1 + (h2 - h1) / (p2 - p1)
                    else:
                        hp = h1 - (h0 - h1) / (1.0 - p1)
                h1 = hp
                p1 += d
            # -- marker 2 -----------------------------------------------------
            d = q2 - p2
            if ((d >= 1.0 and p3 - p2 > 1.0)
                    or (d <= -1.0 and p1 - p2 < -1.0)):
                d = 1.0 if d > 0.0 else -1.0
                hp = h2 + d / (p3 - p1) * (
                    (p2 - p1 + d) * (h3 - h2) / (p3 - p2)
                    + (p3 - p2 - d) * (h2 - h1) / (p2 - p1))
                if not (h1 < hp < h3):
                    if d > 0.0:
                        hp = h2 + (h3 - h2) / (p3 - p2)
                    else:
                        hp = h2 - (h1 - h2) / (p1 - p2)
                h2 = hp
                p2 += d
            # -- marker 3 -----------------------------------------------------
            d = q3 - p3
            if ((d >= 1.0 and p4 - p3 > 1.0)
                    or (d <= -1.0 and p2 - p3 < -1.0)):
                d = 1.0 if d > 0.0 else -1.0
                hp = h3 + d / (p4 - p2) * (
                    (p3 - p2 + d) * (h4 - h3) / (p4 - p3)
                    + (p4 - p3 - d) * (h3 - h2) / (p3 - p2))
                if not (h2 < hp < h4):
                    if d > 0.0:
                        hp = h3 + (h4 - h3) / (p4 - p3)
                    else:
                        hp = h3 - (h2 - h3) / (p2 - p3)
                h3 = hp
                p3 += d
        self._n = n
        self._h0, self._h1, self._h2, self._h3, self._h4 = h0, h1, h2, h3, h4
        self._p1, self._p2, self._p3, self._p4 = p1, p2, p3, p4
        self._q1, self._q2, self._q3 = q1, q2, q3
        self._q4 += float(len(buf) - i)

    @property
    def count(self) -> int:
        return self._n + len(self._buf)

    def value(self) -> float:
        self._drain()
        if self._n == 0:
            return 0.0
        if self._n <= 5:                # exact empirical quantile
            idx = max(0, min(self._n - 1, int(self.q * self._n)))
            return self._small[idx]
        return self._h2


class Counters:
    """Flat monotonic counter registry."""

    __slots__ = ("_c",)

    def __init__(self):
        self._c: Dict[str, int] = {}

    def inc(self, name: str, n: int = 1) -> None:
        c = self._c
        c[name] = c.get(name, 0) + n

    def get(self, name: str) -> int:
        return self._c.get(name, 0)

    def as_dict(self) -> Dict[str, int]:
        return dict(self._c)


#: latency metrics tracked per job: response (submit -> first start),
#: completion (submit -> end), queue wait (total time spent QUEUED)
QUANTILES = (0.5, 0.95, 0.99)


class LatencyRecorder:
    """Per-priority-class streaming latency percentiles.

    ``mark_queued``/``mark_started`` bracket QUEUED episodes (initial queueing
    and preempt -> resume gaps both count as queue wait);
    ``observe_completed`` folds the finished job's response/completion/wait
    into the aggregate estimators and the job's priority-class estimators.
    """

    def __init__(self):
        # (metric, priority-or-None) -> {q: estimator}
        self._est: Dict[Tuple[str, Optional[int]],
                        Dict[float, P2Quantile]] = {}
        # priority -> ((buffer, estimators), ...) for resp/compl/wait: the
        # three quantile estimators of one metric see the SAME value stream,
        # so the hot path buffers each value once per metric and folds the
        # shared buffer into all three estimators when it fills
        self._fast: Dict[Optional[int], tuple] = {}
        self._queued_at: Dict[str, float] = {}
        self._wait: Dict[str, float] = {}
        self.completed = 0

    def mark_queued(self, job_id: str, t: float) -> None:
        self._queued_at.setdefault(job_id, t)

    def mark_started(self, job_id: str, t: float) -> None:
        q = self._queued_at.pop(job_id, None)
        if q is not None:
            self._wait[job_id] = self._wait.get(job_id, 0.0) + max(0.0, t - q)

    def observe_completed(self, job: "JobState") -> None:
        global completion_time, response_time
        if completion_time is None:     # deferred: repro.core imports us
            from repro.core.job import completion_time, response_time
        self.completed += 1
        resp = response_time(job)
        comp = completion_time(job)
        wait = self._wait.pop(job.job_id, 0.0)
        self._queued_at.pop(job.job_id, None)
        if resp is None or comp is None:    # never-started edge cases
            # single observations must not overtake buffered batches
            self._flush_pending()
            for prio in (None, job.spec.priority):
                self._feed(("resp", prio), resp)
                self._feed(("compl", prio), comp)
                self._feed(("wait", prio), wait)
            return
        for prio in (None, job.spec.priority):
            fast = self._fast.get(prio)
            if fast is None:
                per_metric = []
                for metric in ("resp", "compl", "wait"):
                    ests = self._est.get((metric, prio))
                    if ests is None:
                        ests = self._est[(metric, prio)] = {
                            q: P2Quantile(q) for q in QUANTILES}
                    per_metric.append(([], tuple(ests.values())))
                fast = self._fast[prio] = tuple(per_metric)
            (br, er), (bc, ec), (bw, ew) = fast
            br.append(resp)
            bc.append(comp)
            bw.append(wait)
            if len(br) >= 64:
                for buf, ests in fast:
                    for est in ests:
                        est._drain()    # older singles (fallback path) first
                        est._absorb(buf)
                    del buf[:]

    def _flush_pending(self) -> None:
        """Fold every buffered per-metric batch into its estimators."""
        for fast in self._fast.values():
            for buf, ests in fast:
                if buf:
                    for est in ests:
                        est._drain()
                        est._absorb(buf)
                    del buf[:]

    def _feed(self, key: Tuple[str, Optional[int]],
              x: Optional[float]) -> None:
        if x is None:
            return
        ests = self._est.get(key)
        if ests is None:
            ests = self._est[key] = {q: P2Quantile(q) for q in QUANTILES}
        for est in ests.values():
            est.observe(x)

    def percentile_fields(self) -> Dict[str, float]:
        """Flat mapping for ``ScheduleMetrics.percentiles``: ``resp_p99``
        (all classes) and ``resp_p99_prio<k>`` (one priority class), for
        each of resp/compl/wait x p50/p95/p99."""
        self._flush_pending()
        out: Dict[str, float] = {}
        for (metric, prio) in sorted(
                self._est, key=lambda k: (k[0], k[1] is not None, k[1] or 0)):
            suffix = "" if prio is None else f"_prio{prio}"
            for q, est in self._est[(metric, prio)].items():
                out[f"{metric}_p{int(round(q * 100))}{suffix}"] = est.value()
        return out
