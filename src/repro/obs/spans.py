"""Causal span graph: per-job lifecycle span trees with cause edges.

The flight recorder (:mod:`repro.obs.trace`) emits a flat, time-ordered
record stream.  This module folds that stream — offline from a loaded JSONL
trace, or online via a :class:`SpanTap` wrapped around the live tracer —
into per-job **span trees**:

.. code-block:: text

    job:j3                                  [   0.0 ..  941.2]
      queue_wait                            [   0.0 ..   60.0]
      compute                               [  60.0 ..  300.0]
      ckpt                                  [ 295.0 ..  300.0]
      outage            <- spot_kill        [ 300.0 ..  420.0]
      restore                               [ 420.0 ..  450.0]
      compute           <- outage           [ 420.0 ..  941.2]

plus infrastructure spans (``spot_kill`` blast windows, ``zone_reclaim``
batch windows, ``scale_down`` drains) and **cause edges** that stitch them
into chains the flat stream only implies:

- ``zone_reclaim -> spot_kill``: a kill whose node is in the reclaim's
  victim list, inside the reclaim's batch window;
- ``spot_kill -> preempt outage``: a job preempted inside the blast window
  of a kill whose ``residents`` include it;
- ``preempt outage -> resumed compute``: the segment that restarts a job
  after its outage;
- ``scale_down -> job_migrate``: a drain decision naming the node a later
  migration moved a job off.

:meth:`SpanGraph.longest_causal_chain` walks the cause edges — a full
``zone_reclaim -> spot_kill -> outage -> compute`` chain scores 4 — and
feeds the fleet rollups in :mod:`repro.obs.critical_path`.

Phase *durations* live in :mod:`repro.obs.critical_path` (exact partition);
this module keeps the *structure* — who caused what, in which order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


@dataclass
class Span:
    """One interval in a job's (or the infrastructure's) lifecycle.

    ``t1`` is None while the span is still open (live feeds see open spans).
    ``cause`` points at the span that made this one happen — the cause
    edges are a DAG layered over the per-job trees.
    """
    name: str
    t0: float
    t1: Optional[float] = None
    job: Optional[str] = None
    meta: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)
    cause: Optional["Span"] = None

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        end = f"{self.t1:.1f}" if self.t1 is not None else "open"
        tag = f" job={self.job}" if self.job else ""
        why = f" <-{self.cause.name}" if self.cause is not None else ""
        return f"<Span {self.name}{tag} [{self.t0:.1f}..{end}]{why}>"


class SpanGraph:
    """The assembled result: one root span per job + infrastructure spans."""

    def __init__(self):
        self.jobs: Dict[str, Span] = {}
        self.infra: List[Span] = []

    def all_spans(self) -> List[Span]:
        out: List[Span] = []

        def walk(s: Span) -> None:
            out.append(s)
            for c in s.children:
                walk(c)

        for root in self.jobs.values():
            walk(root)
        for s in self.infra:
            walk(s)
        return out

    def chain_of(self, span: Span) -> List[Span]:
        """The cause chain ending at ``span`` (root cause first)."""
        chain, seen = [], set()
        cur: Optional[Span] = span
        while cur is not None and id(cur) not in seen:
            seen.add(id(cur))
            chain.append(cur)
            cur = cur.cause
        return list(reversed(chain))

    def longest_causal_chain(self) -> int:
        """Length (in spans) of the longest cause chain in the graph."""
        return max((len(self.chain_of(s)) for s in self.all_spans()),
                   default=0)

    def job_tree(self, job_id: str) -> Optional[Span]:
        return self.jobs.get(job_id)


class SpanGraphBuilder:
    """Incremental builder: ``feed`` one record at a time (records must be
    time-ordered, as the recorder writes them).  Works identically on a
    loaded trace and on the live stream via :class:`SpanTap`."""

    #: a kill/drain can only cause a preempt/migrate this many seconds later
    CAUSE_HORIZON = 1e-6

    def __init__(self):
        self.graph = SpanGraph()
        self._open_wait: Dict[str, Span] = {}      # job -> open wait span
        self._open_seg: Dict[str, Span] = {}       # job -> open compute span
        self._open_kills: List[Span] = []          # spot_kill blast windows
        self._open_reclaims: List[Span] = []       # zone_reclaim batches
        self._drains: List[Span] = []              # scale_down decisions

    # -- record feed ---------------------------------------------------------
    def feed(self, rec: Dict[str, Any]) -> None:
        kind = rec.get("kind")
        handler = getattr(self, f"_on_{kind}", None) if kind else None
        if handler is not None:
            handler(rec)

    def build(self) -> SpanGraph:
        return self.graph

    # -- job lifecycle -------------------------------------------------------
    def _root(self, job_id: str, t: float) -> Span:
        root = self.graph.jobs.get(job_id)
        if root is None:
            root = self.graph.jobs[job_id] = Span("job", t, job=job_id)
        return root

    def _on_job_submit(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        root = Span("job", t, job=job,
                    meta={k: r[k] for k in ("priority", "min", "max")
                          if k in r})
        self.graph.jobs[job] = root
        wait = Span("queue_wait", t, job=job)
        root.children.append(wait)
        self._open_wait[job] = wait

    def _on_job_start(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        root = self._root(job, t)
        wait = self._open_wait.pop(job, None)
        if wait is not None:
            wait.t1 = t
        if r.get("resume") and r.get("overhead_s", 0.0) > 0.0:
            root.children.append(Span("restore", t, t + r["overhead_s"],
                                      job=job, cause=wait))
        seg = Span("compute", t, job=job,
                   meta={"slots": r.get("slots")},
                   cause=wait if (wait is not None
                                  and wait.name == "outage") else None)
        root.children.append(seg)
        self._open_seg[job] = seg

    def _on_job_rescale(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        self._root(job, t).children.append(
            Span("rescale", t, t + r.get("overhead_s", 0.0), job=job,
                 meta={"from": r.get("from"), "to": r.get("to")}))

    def _on_job_migrate(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        cause = self._match_drain(r.get("from_node"), t)
        self._root(job, t).children.append(
            Span("migrate", t, t + r.get("overhead_s", 0.0), job=job,
                 meta={"from_node": r.get("from_node"),
                       "moved": r.get("moved")},
                 cause=cause))

    def _on_job_preempt(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        root = self._root(job, t)
        seg = self._open_seg.pop(job, None)
        if seg is not None:
            seg.t1 = t
        ckpt_s = r.get("ckpt_s", 0.0)
        if ckpt_s > 0.0:
            root.children.append(Span("ckpt", t - ckpt_s, t, job=job))
        outage = Span("outage", t, job=job,
                      cause=self._match_kill(job, t))
        root.children.append(outage)
        self._open_wait[job] = outage

    def _on_job_fail(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        seg = self._open_seg.pop(job, None)
        if seg is not None:
            seg.t1 = t
        outage = Span("outage", t, job=job, cause=self._match_kill(job, t))
        self._root(job, t).children.append(outage)
        self._open_wait[job] = outage

    def _on_job_complete(self, r: Dict[str, Any]) -> None:
        job, t = r["job"], r.get("t", 0.0)
        seg = self._open_seg.pop(job, None)
        if seg is not None:
            seg.t1 = t
        wait = self._open_wait.pop(job, None)
        if wait is not None:
            wait.t1 = t
        root = self._root(job, t)
        root.t1 = t

    # -- infrastructure ------------------------------------------------------
    def _on_spot_kill(self, r: Dict[str, Any]) -> None:
        t = r.get("t", 0.0)
        kill = Span("spot_kill", t, job=None,
                    meta={"node": r.get("node"), "zone": r.get("zone"),
                          "residents": dict(r.get("residents") or {})},
                    cause=self._match_reclaim(r.get("node"), t))
        self.graph.infra.append(kill)
        self._open_kills.append(kill)

    def _on_kill_blast_end(self, r: Dict[str, Any]) -> None:
        node, t = r.get("node"), r.get("t", 0.0)
        for kill in self._open_kills:
            if kill.meta.get("node") == node and kill.t1 is None:
                kill.t1 = t
        self._open_kills = [k for k in self._open_kills if k.t1 is None]

    def _on_zone_reclaim(self, r: Dict[str, Any]) -> None:
        span = Span("zone_reclaim", r.get("t", 0.0),
                    meta={"zone": r.get("zone"),
                          "victims": list(r.get("victims") or [])})
        self.graph.infra.append(span)
        self._open_reclaims.append(span)

    def _on_zone_reclaim_end(self, r: Dict[str, Any]) -> None:
        zone, t = r.get("zone"), r.get("t", 0.0)
        for z in self._open_reclaims:
            if z.meta.get("zone") == zone and z.t1 is None:
                z.t1 = t
        self._open_reclaims = [z for z in self._open_reclaims
                               if z.t1 is None]

    def _on_decision(self, r: Dict[str, Any]) -> None:
        if r.get("point") != "scale_down":
            return
        inputs = r.get("inputs") or {}
        span = Span("scale_down", r.get("t", 0.0),
                    meta={"node": inputs.get("node"),
                          "verdict": r.get("verdict")})
        self.graph.infra.append(span)
        if r.get("verdict") in ("drained", "drain_started"):
            self._drains.append(span)
        elif r.get("verdict") in ("drain_complete", "drain_cancelled"):
            for d in self._drains:
                if d.meta.get("node") == inputs.get("node") \
                        and d.t1 is None:
                    d.t1 = span.t0
            self._drains = [d for d in self._drains if d.t1 is None]

    # -- cause matching ------------------------------------------------------
    def _match_kill(self, job_id: str, t: float) -> Optional[Span]:
        """The open spot-kill blast whose residents include this job (the
        recorder brackets kills as spot_kill..kill_blast_end, so displaced
        jobs preempt strictly inside the window)."""
        for kill in reversed(self._open_kills):
            if job_id in kill.meta.get("residents", {}):
                return kill
        return None

    def _match_reclaim(self, node_id: Optional[str],
                       t: float) -> Optional[Span]:
        for z in reversed(self._open_reclaims):
            if node_id in z.meta.get("victims", []):
                return z
        return None

    def _match_drain(self, node_id: Optional[str],
                     t: float) -> Optional[Span]:
        if node_id is None:
            return None
        for d in reversed(self._drains):
            if d.meta.get("node") == node_id:
                return d
        # the drain may already have closed this tick (drain_complete is
        # emitted after the migrations) — search closed decisions too
        for s in reversed(self.graph.infra):
            if s.name == "scale_down" and s.meta.get("node") == node_id:
                return s
        return None


class SpanTap:
    """Live tracer hook: quacks like a :class:`~repro.obs.trace.Tracer`,
    feeds every record into a :class:`SpanGraphBuilder`, and forwards to an
    optional delegate tracer (so a run can build spans AND write JSONL).

    ::

        tap = SpanTap(delegate=Tracer(path))
        sim = Simulator(64, cfg, tracer=tap)
        sim.run()
        graph = tap.graph()     # open spans visible mid-run, too
    """

    enabled = True

    def __init__(self, delegate=None):
        from repro.obs.trace import NULL_TRACER
        self.builder = SpanGraphBuilder()
        self.delegate = delegate if delegate is not None else NULL_TRACER

    def emit(self, kind: str, t: float = 0.0, **fields) -> None:
        rec = {"kind": kind, "t": t}
        rec.update(fields)
        self.builder.feed(rec)
        if self.delegate.enabled:
            self.delegate.emit(kind, t, **fields)

    def next_run_id(self) -> int:
        return self.delegate.next_run_id()

    def flush(self) -> None:
        self.delegate.flush()

    def close(self) -> None:
        self.delegate.close()

    def graph(self) -> SpanGraph:
        return self.builder.build()


def build_span_graph(records: Sequence[Dict[str, Any]]) -> SpanGraph:
    """Offline assembly: fold one run's records into a span graph."""
    builder = SpanGraphBuilder()
    for r in records:
        builder.feed(r)
    return builder.build()


def render_chains(graph: SpanGraph, min_len: int = 2) -> str:
    """Human-readable dump of the cause chains (longest first)."""
    chains = []
    for s in graph.all_spans():
        c = graph.chain_of(s)
        if len(c) >= min_len and s.cause is not None:
            chains.append(c)
    # keep only maximal chains (drop chains that are prefixes of longer ones)
    keyed = {tuple(id(s) for s in c): c for c in chains}
    maximal = [c for key, c in keyed.items()
               if not any(k != key and k[:len(key)] == key for k in keyed)]
    maximal.sort(key=len, reverse=True)
    lines = []
    for c in maximal:
        parts = []
        for s in c:
            tag = f"[{s.job}]" if s.job else \
                f"[{s.meta.get('node') or s.meta.get('zone') or ''}]"
            parts.append(f"{s.name}{tag}@{s.t0:.0f}")
        lines.append(" -> ".join(parts))
    return "\n".join(lines) if lines else "(no causal chains)"
