"""Perf/anomaly watchdog: diff a fresh ``BENCH_simcore.json`` against the
committed baseline, and flag metric-stream anomalies.

ROADMAP's simulator-throughput item asks for a no-regression gate before the
event-loop refactor starts.  This is it:

- **baseline diff** — every job-count rung's events/sec must be within
  ``throughput_rel_tol`` of ``benchmarks/baselines/BENCH_simcore.baseline.
  json`` (default 15%, so a 20% regression trips), peak RSS within
  ``rss_rel_tol``, ckpt save walls within ``ckpt_rel_tol``, and the
  machine-independent invariants must hold outright: composed null-tracer
  overhead < 3%, active-tracer overhead under its ceiling, delta
  checkpoints writing strictly fewer bytes than full snapshots, the async
  barrier publishing the last submitted step, schema keys present.
- **anomaly scan** — :func:`rolling_median_spikes` flags points that jump
  ``spike_factor``x above the rolling median of their trailing window;
  :func:`scan_trace` applies it to the per-completion response-time stream
  of a flight-recorder trace ("where did my p99 go?" starts here).

CI wiring (two speeds): the non-blocking ``bench`` job runs the full diff
and uploads ``BENCH_watchdog_diff.json`` as an artifact (absolute
throughput/RSS are machine-dependent — a noisy runner must not block a
merge); the blocking step runs ``--blocking-only``, which checks just the
machine-independent invariants.

CLI::

    PYTHONPATH=src python -m repro.obs.watchdog \
        --fresh BENCH_simcore.json \
        --baseline benchmarks/baselines/BENCH_simcore.baseline.json \
        [--blocking-only] [--out BENCH_watchdog_diff.json]
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence


@dataclass
class WatchdogConfig:
    #: per-rung events/sec may drop at most this fraction vs. baseline
    throughput_rel_tol: float = 0.15
    #: peak RSS may grow at most this fraction vs. baseline
    rss_rel_tol: float = 0.30
    #: fleet rows: events-retired/sec may drop at most this fraction vs. the
    #: baseline row of the same name (fleet rows run once, not best-of-N, so
    #: they carry more scheduler noise than the micro rungs)
    fleet_rel_tol: float = 0.25
    #: composed null-tracer overhead must stay under this (percent)
    null_overhead_pct_max: float = 3.0
    #: active-tracer overhead ceiling (percent); None disables the check —
    #: matches bench_simcore.ACTIVE_OVERHEAD_CEILING_PCT (recalibrated with
    #: the fleet-scale refactor: same absolute tracer cost over a ~2.3x
    #: faster untraced grid reads as ~40%, with file-write noise swinging
    #: it 37-65% run to run)
    active_overhead_pct_max: Optional[float] = 90.0
    #: ckpt save walls may grow at most this fraction vs. baseline (disk
    #: speed varies across runners far more than CPU throughput does)
    ckpt_rel_tol: float = 1.0
    #: anomaly scan: a point is a spike if > factor x rolling median
    spike_factor: float = 3.0
    spike_window: int = 9


@dataclass
class WatchdogReport:
    """Mirror of the auditor's report shape: named checks, each with
    violation strings; ``ok`` means no check drew blood."""
    checks: Dict[str, List[str]] = field(default_factory=dict)
    notes: List[str] = field(default_factory=list)

    def fail(self, check: str, msg: str) -> None:
        self.checks.setdefault(check, []).append(msg)

    def passed(self, check: str) -> None:
        self.checks.setdefault(check, [])

    @property
    def ok(self) -> bool:
        return not any(self.checks.values())

    @property
    def violations(self) -> List[str]:
        return [f"{name}: {msg}" for name, msgs in sorted(self.checks.items())
                for msg in msgs]

    def summary(self) -> str:
        lines = [f"watchdog: {'OK' if self.ok else 'REGRESSION'} "
                 f"({len(self.checks)} checks, "
                 f"{len(self.violations)} violations)"]
        for name in sorted(self.checks):
            msgs = self.checks[name]
            lines.append(f"  {'FAIL' if msgs else 'ok  '} {name}")
            lines.extend(f"       {m}" for m in msgs)
        lines.extend(f"  note {n}" for n in self.notes)
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {"ok": self.ok, "checks": self.checks, "notes": self.notes}


def diff_snapshots(fresh: Dict[str, Any], baseline: Dict[str, Any],
                   cfg: Optional[WatchdogConfig] = None, *,
                   blocking_only: bool = False) -> WatchdogReport:
    """Compare a fresh bench snapshot against the committed baseline.

    ``blocking_only`` skips the machine-dependent comparisons (absolute
    events/sec, RSS) and keeps the invariant checks that must hold on any
    machine.
    """
    cfg = cfg or WatchdogConfig()
    rep = WatchdogReport()

    # -- schema invariants (always) ------------------------------------------
    rep.passed("schema")
    for key in ("throughput", "tracing"):
        if key not in fresh:
            rep.fail("schema", f"fresh snapshot missing '{key}'")
    if fresh.get("schema", 0) >= 2:
        for key in ("profile", "peak_rss_bytes"):
            if key not in fresh:
                rep.fail("schema", f"schema>=2 snapshot missing '{key}'")
    if fresh.get("schema", 0) >= 3:
        if not fresh.get("fleet"):
            rep.fail("schema", "schema>=3 snapshot missing 'fleet' rows")
    if fresh.get("schema", 0) >= 4:
        if not fresh.get("ckpt"):
            rep.fail("schema", "schema>=4 snapshot missing 'ckpt' rows")

    # -- checkpoint fast-lane invariants (always; machine-independent) -------
    ckpt = fresh.get("ckpt")
    if ckpt:
        rep.passed("ckpt_invariants")
        if not ckpt.get("delta_bytes", 0) < ckpt.get("full_bytes", 0):
            rep.fail("ckpt_invariants",
                     f"delta checkpoint wrote {ckpt.get('delta_bytes')} bytes"
                     f" >= full snapshot {ckpt.get('full_bytes')}")
        if not ckpt.get("async_published_latest", False):
            rep.fail("ckpt_invariants",
                     "async barrier did not publish the last submitted step")

    # -- null-tracer overhead (always; machine-independent ratio) ------------
    rep.passed("null_overhead")
    tracing = fresh.get("tracing", {})
    null_pct = tracing.get("composed_null_overhead_pct")
    if null_pct is None:
        rep.fail("null_overhead", "composed_null_overhead_pct missing")
    elif null_pct >= cfg.null_overhead_pct_max:
        rep.fail("null_overhead",
                 f"composed null overhead {null_pct:.2f}% >= "
                 f"{cfg.null_overhead_pct_max:.1f}%")

    # -- active-tracer overhead ceiling (always) -----------------------------
    if cfg.active_overhead_pct_max is not None:
        rep.passed("active_overhead")
        active_pct = tracing.get("active_overhead_pct")
        if active_pct is None:
            rep.fail("active_overhead", "active_overhead_pct missing")
        elif active_pct >= cfg.active_overhead_pct_max:
            rep.fail("active_overhead",
                     f"active overhead {active_pct:.2f}% >= ceiling "
                     f"{cfg.active_overhead_pct_max:.1f}%")

    if blocking_only:
        rep.notes.append("blocking-only: throughput/RSS diffs skipped "
                         "(machine-dependent)")
        return rep

    # -- per-rung events/sec vs. baseline ------------------------------------
    rep.passed("throughput")
    base_rungs = {r["n_jobs"]: r for r in baseline.get("throughput", [])}
    fresh_rungs = {r["n_jobs"]: r for r in fresh.get("throughput", [])}
    for n_jobs, base in sorted(base_rungs.items()):
        cur = fresh_rungs.get(n_jobs)
        if cur is None:
            rep.fail("throughput", f"rung n_jobs={n_jobs} missing from "
                                   f"fresh snapshot")
            continue
        b, f = base.get("events_per_sec", 0.0), cur.get("events_per_sec", 0.0)
        if b > 0.0 and f < b * (1.0 - cfg.throughput_rel_tol):
            rep.fail("throughput",
                     f"n_jobs={n_jobs}: {f:.0f} events/s is "
                     f"{100.0 * (1.0 - f / b):.1f}% below baseline "
                     f"{b:.0f} (tol {100.0 * cfg.throughput_rel_tol:.0f}%)")

    # -- fleet replay rows vs. baseline (schema 3) ---------------------------
    # diff by row-name intersection: the smoke row is the everyday gate, the
    # month-long full row only exists in snapshots run with --fleet-full — a
    # missing full row is a note, never a failure
    base_fleet = {r["name"]: r for r in baseline.get("fleet", [])}
    fresh_fleet = {r["name"]: r for r in fresh.get("fleet", [])}
    if base_fleet:
        rep.passed("fleet")
        for name, base in sorted(base_fleet.items()):
            cur = fresh_fleet.get(name)
            if cur is None:
                rep.notes.append(f"fleet: row '{name}' not in fresh "
                                 f"snapshot (run with --fleet-full?); "
                                 f"diff skipped")
                continue
            b = base.get("events_retired_per_sec", 0.0)
            f = cur.get("events_retired_per_sec", 0.0)
            if b > 0.0 and f < b * (1.0 - cfg.fleet_rel_tol):
                rep.fail("fleet",
                         f"{name}: {f:.0f} retired events/s is "
                         f"{100.0 * (1.0 - f / b):.1f}% below baseline "
                         f"{b:.0f} (tol {100.0 * cfg.fleet_rel_tol:.0f}%)")

    # -- checkpoint save walls vs. baseline (schema 4) -----------------------
    base_ckpt = baseline.get("ckpt")
    if base_ckpt and ckpt:
        rep.passed("ckpt")
        for field_name in ("full_save_us", "delta_save_us"):
            b, f = base_ckpt.get(field_name, 0.0), ckpt.get(field_name, 0.0)
            if b > 0.0 and f > b * (1.0 + cfg.ckpt_rel_tol):
                rep.fail("ckpt",
                         f"{field_name}: {f:.0f}us is "
                         f"{100.0 * (f / b - 1.0):.1f}% above baseline "
                         f"{b:.0f}us (tol {100.0 * cfg.ckpt_rel_tol:.0f}%)")

    # -- peak RSS vs. baseline -----------------------------------------------
    rep.passed("peak_rss")
    b_rss = baseline.get("peak_rss_bytes")
    f_rss = fresh.get("peak_rss_bytes")
    if b_rss and f_rss:
        if f_rss > b_rss * (1.0 + cfg.rss_rel_tol):
            rep.fail("peak_rss",
                     f"peak RSS {f_rss / 1e6:.1f}MB is "
                     f"{100.0 * (f_rss / b_rss - 1.0):.1f}% above baseline "
                     f"{b_rss / 1e6:.1f}MB (tol "
                     f"{100.0 * cfg.rss_rel_tol:.0f}%)")
    elif b_rss and not f_rss:
        rep.fail("peak_rss", "peak_rss_bytes missing from fresh snapshot")
    else:
        rep.notes.append("peak_rss: no baseline value; diff skipped")
    return rep


# ---------------------------------------------------------------------------
# Metric-stream anomaly scan
# ---------------------------------------------------------------------------

def rolling_median_spikes(values: Sequence[float], *, window: int = 9,
                          factor: float = 3.0) -> List[int]:
    """Indices whose value exceeds ``factor`` x the median of the trailing
    ``window`` points.  Needs a full window of history, so the first
    ``window`` points are never flagged."""
    spikes = []
    for i in range(window, len(values)):
        trail = sorted(values[i - window:i])
        med = trail[window // 2]
        if med > 0.0 and values[i] > factor * med:
            spikes.append(i)
    return spikes


def scan_trace(records: Sequence[Dict[str, Any]],
               cfg: Optional[WatchdogConfig] = None) -> List[str]:
    """Flag response-time spikes in one run's trace: the per-completion
    stream (complete.t - submit.t, in completion order) is scanned against
    its own rolling median.  Returns human-readable anomaly strings."""
    cfg = cfg or WatchdogConfig()
    submits = {r["job"]: r["t"] for r in records
               if r.get("kind") == "job_submit"}
    stream = [(r["job"], r["t"] - submits[r["job"]]) for r in records
              if r.get("kind") == "job_complete" and r["job"] in submits]
    values = [v for _, v in stream]
    return [
        f"response-time spike: job {stream[i][0]} took {values[i]:.0f}s, "
        f">{cfg.spike_factor:.0f}x the rolling median"
        for i in rolling_median_spikes(values, window=cfg.spike_window,
                                       factor=cfg.spike_factor)]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a fresh BENCH_simcore.json against the committed "
                    "baseline.")
    ap.add_argument("--fresh", default="BENCH_simcore.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/"
                            "BENCH_simcore.baseline.json")
    ap.add_argument("--out", default=None,
                    help="write the diff report as JSON here")
    ap.add_argument("--blocking-only", action="store_true",
                    help="machine-independent invariants only "
                         "(null/active overhead, schema)")
    ap.add_argument("--throughput-tol", type=float, default=None)
    args = ap.parse_args(argv)

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    baseline: Dict[str, Any] = {}
    if not args.blocking_only:
        with open(args.baseline) as fh:
            baseline = json.load(fh)

    cfg = WatchdogConfig()
    if args.throughput_tol is not None:
        cfg.throughput_rel_tol = args.throughput_tol
    rep = diff_snapshots(fresh, baseline, cfg,
                         blocking_only=args.blocking_only)
    print(rep.summary())
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(rep.to_dict(), fh, indent=2)
            fh.write("\n")
    return 0 if rep.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
