"""Structured JSONL flight recorder.

One :class:`Tracer` receives every span/event record a simulator (or the live
controller) emits: job lifecycle (submit -> queue -> start -> rescale ->
preempt -> resume -> complete, with slot deltas and overhead seconds), node
lifecycle (boot / kill / cordon / drain / removal), zone reclaims, itemized
cost events, and the decision-audit records of :mod:`repro.obs.decisions`.

Records are flat JSON objects with two universal keys — ``kind`` (the record
type) and ``t`` (virtual time) — plus kind-specific fields.  The schema is
documented in README.md ("Observability") and consumed by
:mod:`repro.obs.audit` (invariant replay) and :mod:`repro.obs.timeline`
(text Gantt).

Disabled runs pay ~nothing: the default is the module-level
:data:`NULL_TRACER`, whose ``enabled`` is False so instrumented code guards
every emission with one attribute check (``if tracer.enabled: ...``).
``bench_simcore.py`` measures the residual cost of those guards on the
table1 policy grid.

Benchmarks install a tracer process-wide with::

    with install(Tracer(path)):
        run_variant(...)        # Simulator picks it up via current_tracer()

so deep call stacks (benchmark tables, replay helpers) need no per-layer
tracer threading.
"""
from __future__ import annotations

import contextlib
import json
from typing import Any, Dict, Iterator, List, Optional


class NullTracer:
    """No-op sink; ``enabled`` is False so hot paths skip record building."""

    enabled = False
    __slots__ = ()

    def emit(self, kind: str, t: float = 0.0, **fields) -> None:
        pass

    def next_run_id(self) -> int:
        return 0

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


#: process-wide default sink (see :func:`current_tracer`)
NULL_TRACER = NullTracer()


class Tracer:
    """JSONL sink.  With ``path`` records stream to disk; without one (or
    with ``keep=True``) they accumulate in ``records`` for in-process
    consumers (tests, the audit/timeline helpers).

    Emission is LAZY: the hot path appends one ``(kind, t, fields)`` tuple
    to a pending buffer; dict assembly, JSON serialization, and the file
    write happen per ``batch`` records (and at ``flush``/``close``/
    ``records`` access), amortizing the serialization cost out of the
    simulator's event loop.  Callers must therefore pass fields the caller
    will not mutate afterwards — every instrumentation site in the repo
    already passes fresh scalars/copies (``dict(victims)``, ``list(...)``).
    """

    enabled = True

    def __init__(self, path: Optional[str] = None, *,
                 keep: Optional[bool] = None, batch: int = 1024):
        self.path = path
        self._fh = open(path, "w") if path else None
        keep = keep if keep is not None else path is None
        self._records: Optional[List[Dict[str, Any]]] = [] if keep else None
        self._pending: List[tuple] = []
        self._batch = batch
        self._runs = 0

    def next_run_id(self) -> int:
        """Monotone run id so several simulations can share one file; the
        auditor/timeline split the stream on ``run_start`` records."""
        self._runs += 1
        return self._runs

    def emit(self, kind: str, t: float = 0.0, **fields) -> None:
        self._pending.append((kind, t, fields))
        if len(self._pending) >= self._batch:
            self._drain()

    def _drain(self) -> None:
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        recs: List[Dict[str, Any]] = []
        for kind, t, fields in pending:
            rec = {"kind": kind, "t": t}
            rec.update(fields)
            recs.append(rec)
        if self._fh is not None:
            dumps = json.dumps
            self._fh.write("".join(dumps(r, separators=(",", ":")) + "\n"
                                   for r in recs))
        if self._records is not None:
            self._records.extend(recs)

    @property
    def records(self) -> Optional[List[Dict[str, Any]]]:
        """Accumulated records (None when streaming to disk without
        ``keep``).  Accessing drains the pending buffer first, so in-process
        consumers always see a complete, ordered list."""
        self._drain()
        return self._records

    def flush(self) -> None:
        self._drain()
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        self._drain()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def load(path: str) -> List[Dict[str, Any]]:
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]


_CURRENT: Optional[Tracer] = None


def current_tracer():
    """The process-installed tracer, or :data:`NULL_TRACER`.  Simulators
    default to this at construction, so ``install`` wraps whole benchmark
    modules without touching their signatures."""
    return _CURRENT if _CURRENT is not None else NULL_TRACER


@contextlib.contextmanager
def install(tracer: Tracer) -> Iterator[Tracer]:
    """Make ``tracer`` the process default for the duration of the block."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer
    try:
        yield tracer
    finally:
        _CURRENT = prev
