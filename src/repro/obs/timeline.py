"""Text Gantt renderer over a flight-recorder trace.

One row per job (``#`` running, ``.`` queued, ``*`` a rescale, ``x`` a
preempt, ``>`` a migration), plus a capacity row (provisioned slots, scaled
0-9) and a kill row (``K`` spot kill, ``Z`` zone reclaim).  Consumed by
``benchmarks/fig6_timeline.py`` and ``examples/trace_replay_demo.py``; the
benchmark harness (``--trace``) writes one ``<module>.timeline.txt`` per
traced table.

The renderer needs nothing but a list of loaded records (one run); pair it
with :func:`repro.obs.audit.split_runs` for multi-run files.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.obs.audit import split_runs

_RUN, _QUEUE, _IDLE = "#", ".", " "


def _bucket(t: float, t0: float, dt: float, width: int) -> int:
    return max(0, min(width - 1, int((t - t0) / dt)))


def render(records: List[Dict[str, Any]], *, width: int = 72,
           max_jobs: int = 40) -> str:
    """Render ONE run's records as a text Gantt chart."""
    job_recs = [r for r in records
                if r.get("kind", "").startswith("job_") and "job" in r]
    if not job_recs:
        return "(no job records in trace)"
    t0 = min(r["t"] for r in job_recs)
    t1 = max(r["t"] for r in records if "t" in r)
    dt = max((t1 - t0) / width, 1e-9)

    # per-job state transitions -> row of state chars, then event markers
    jobs: List[str] = []
    seen = set()
    for r in job_recs:
        if r["job"] not in seen:
            seen.add(r["job"])
            jobs.append(r["job"])
    rows: Dict[str, List[str]] = {j: [_IDLE] * width for j in jobs}
    state: Dict[str, str] = {j: _IDLE for j in jobs}
    cursor: Dict[str, int] = {j: 0 for j in jobs}

    def advance(job: str, upto: int) -> None:
        row, c = rows[job], cursor[job]
        for i in range(c, min(upto, width)):
            row[i] = state[job]
        cursor[job] = max(c, upto)

    marks: Dict[str, Dict[int, str]] = {j: {} for j in jobs}
    for r in job_recs:
        job, kind = r["job"], r["kind"]
        b = _bucket(r["t"], t0, dt, width)
        advance(job, b)
        if kind in ("job_submit", "job_queue"):
            state[job] = _QUEUE
        elif kind == "job_start":
            state[job] = _RUN
        elif kind == "job_rescale":
            marks[job][b] = "*"
        elif kind == "job_migrate":
            marks[job][b] = ">"
        elif kind in ("job_preempt", "job_fail"):
            state[job] = _QUEUE
            marks[job][b] = "x"
        elif kind == "job_complete":
            state[job] = _IDLE
    for job in jobs:
        advance(job, width)
        for b, ch in marks[job].items():
            rows[job][b] = ch

    # capacity row: base slots + node_up/cordon/kill/removal deltas
    base = next((r.get("slots", 0) for r in records
                 if r.get("kind") == "run_start"), 0)
    cap_events: List[tuple] = []
    node_slots: Dict[str, int] = {}
    for r in records:
        kind = r.get("kind", "")
        if kind == "node_up":
            node_slots[r["node"]] = r.get("slots", 0)
            cap_events.append((r["t"], r.get("slots", 0)))
        elif kind in ("node_cordon", "spot_kill"):
            if not r.get("was_cordoned"):
                s = r.get("slots", node_slots.get(r["node"], 0))
                cap_events.append((r["t"], -s))
        elif kind == "node_uncordon":
            s = r.get("slots", node_slots.get(r["node"], 0))
            cap_events.append((r["t"], s))
    cap_row, kill_row = [" "] * width, [" "] * width
    if cap_events or base:
        cap = base
        caps = [base] * width
        for t, delta in sorted(cap_events, key=lambda e: e[0]):
            cap += delta
            b = _bucket(t, t0, dt, width)
            for i in range(b, width):
                caps[i] = cap
        peak = max(max(caps), 1)
        cap_row = [str(min(9, (9 * c) // peak)) for c in caps]
    for r in records:
        if r.get("kind") == "spot_kill":
            kill_row[_bucket(r["t"], t0, dt, width)] = "K"
        elif r.get("kind") == "zone_reclaim":
            kill_row[_bucket(r["t"], t0, dt, width)] = "Z"

    label_w = max([len(j) for j in jobs[:max_jobs]] + [8])
    label_w = min(label_w, 20)
    out = [f"timeline t0={t0:.1f}s t1={t1:.1f}s "
           f"({dt:.1f}s/col, {len(jobs)} jobs)"
           f"  [#=run .=queue *=rescale >=migrate x=preempt]"]
    for job in jobs[:max_jobs]:
        out.append(f"{job[:label_w]:>{label_w}} |{''.join(rows[job])}|")
    if len(jobs) > max_jobs:
        out.append(f"{'...':>{label_w}} |({len(jobs) - max_jobs} more jobs)")
    out.append(f"{'capacity':>{label_w}} |{''.join(cap_row)}|")
    if any(c != " " for c in kill_row):
        out.append(f"{'kills':>{label_w}} |{''.join(kill_row)}|")
    return "\n".join(out)


def render_last_run(records: List[Dict[str, Any]], **kw) -> str:
    """Render the last complete run in a (possibly multi-run) stream."""
    runs = split_runs(records)
    if not runs:
        return "(no runs in trace)"
    return render(runs[-1], **kw)
