"""Trace auditor: re-derive conservation invariants from the JSONL alone.

The flight recorder becomes a sanitizer: given nothing but the trace, replay
it through a slot/dollar ledger and check that the run could not have
violated physics.  Invariants (per run, a ``run_start`` .. ``run_end`` span):

- **slot_ownership** — a job's held slots follow its lifecycle records
  exactly (start sets, rescale moves ``from -> to``, preempt/complete/fail
  clear); total held slots never exceed the *physical* capacity (active +
  cordoned nodes), and outside kill-blast / drain windows never exceed the
  *active* capacity either.  Kill blasts are bracketed by ``spot_kill`` ..
  ``kill_blast_end`` records (and ``zone_reclaim`` .. ``zone_reclaim_end``
  for correlated batches): inside the bracket victims may transiently
  overcommit the dying node (checkpoint writes advance the clock before
  eviction lands), which is exactly the window the simulator itself allows.
- **dollar_conservation** — ``run_end.total_cost`` equals the re-derived
  capacity integral (each node's ``slots x $/slot-hour`` over its billed
  ``node_up`` .. billing-end interval) plus the itemized ``cost_transfer``
  records; ``run_end.transfer_cost`` and ``run_end.preempt_overhead_cost``
  equal their itemized sums.
- **preempt_resume** — every ``job_preempt`` is matched by a later resume
  (``job_start`` with ``resume: true``) or accounted a drop
  (``run_end.dropped``); a preempted job never completes without resuming.
- **blast_integrity** — every resident captured in a ``spot_kill`` record is
  resolved (migrated / shrunk / preempted / failed) before the matching
  ``kill_blast_end``.
- **lifecycle** — submit/complete/drop counts reconcile with ``run_end``.
- **phase_reconciliation** — the :mod:`repro.obs.critical_path`
  decomposition of every completed job sums to its observed makespan
  (complete.t - submit.t) to <0.1%: the phase attribution is a PARTITION of
  response time, not an estimate.

CLI::

    PYTHONPATH=src python -m repro.obs.audit trace1.jsonl [trace2.jsonl ...]

prints one PASS/FAIL line per run per file and exits non-zero on any FAIL
(the CI ``obs-audit`` gate).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.trace import Tracer

#: records that prove a kill-blast victim was dealt with
_RESOLUTIONS = ("job_migrate", "job_rescale", "job_preempt", "job_fail",
                "job_complete")


@dataclass
class AuditReport:
    source: str = ""
    run: int = 0
    checks: Dict[str, bool] = field(default_factory=dict)
    violations: List[str] = field(default_factory=list)
    counts: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        status = "PASS" if self.ok else "FAIL"
        checks = " ".join(f"{k}={'ok' if v else 'VIOLATED'}"
                          for k, v in sorted(self.checks.items()))
        line = (f"[{status}] {self.source} run={self.run} "
                f"records={self.counts.get('records', 0)} {checks}")
        for v in self.violations[:8]:
            line += f"\n    - {v}"
        if len(self.violations) > 8:
            line += f"\n    ... {len(self.violations) - 8} more"
        return line


def split_runs(records: List[Dict[str, Any]]) -> List[List[Dict[str, Any]]]:
    """Split a (possibly multi-run) stream on ``run_start`` boundaries."""
    runs: List[List[Dict[str, Any]]] = []
    cur: Optional[List[Dict[str, Any]]] = None
    for r in records:
        if r.get("kind") == "run_start":
            if cur is not None:
                runs.append(cur)
            cur = [r]
        elif cur is not None:
            cur.append(r)
    if cur is not None:
        runs.append(cur)
    return runs


class _RunAuditor:
    """Replays one run's records through a slot/dollar ledger."""

    def __init__(self, records: List[Dict[str, Any]], source: str = ""):
        self.records = records
        self.rep = AuditReport(source=source)
        # slot ledger
        self.base_slots = 0
        self.held: Dict[str, int] = {}           # job -> slots
        self.active: Dict[str, int] = {}         # node -> slots
        self.cordoned: Dict[str, int] = {}
        self.blast_depth = 0                     # open kill/zone windows
        self.blasts: Dict[str, set] = {}         # killed node -> unresolved
        # dollar ledger
        self.node_rate: Dict[str, float] = {}    # node -> $/s while billed
        self.bill_from: Dict[str, float] = {}    # node -> billing start
        self.capacity_dollars = 0.0
        self.transfer_dollars = 0.0
        self.overhead_dollars = 0.0
        # lifecycle
        self.submitted: set = set()
        self.completed: set = set()
        self.open_preempts: set = set()
        self.resumes = 0
        self.preempts = 0

    # -- helpers -------------------------------------------------------------
    def fail(self, check: str, msg: str) -> None:
        self.rep.checks[check] = False
        self.rep.violations.append(f"{check}: {msg}")

    def _check_capacity(self, t: float, what: str) -> None:
        used = sum(self.held.values())
        physical = self.base_slots + sum(self.active.values()) \
            + sum(self.cordoned.values())
        if used > physical:
            self.fail("slot_ownership",
                      f"t={t:.1f} {what}: {used} slots held > "
                      f"{physical} physical (double-booked)")
        elif (self.blast_depth == 0 and not self.cordoned
                and used > self.base_slots + sum(self.active.values())):
            self.fail("slot_ownership",
                      f"t={t:.1f} {what}: {used} held > active capacity "
                      f"outside any blast/drain window")

    def _set_held(self, t: float, job: str, slots: int, expect: Optional[int],
                  what: str) -> None:
        if expect is not None and self.held.get(job, 0) != expect:
            self.fail("slot_ownership",
                      f"t={t:.1f} {what} {job}: record says {expect} held "
                      f"but ledger has {self.held.get(job, 0)}")
        self.held[job] = slots
        self._check_capacity(t, what)

    def _end_billing(self, t: float, node: str) -> None:
        rate = self.node_rate.pop(node, None)
        start = self.bill_from.pop(node, None)
        if rate is not None and start is not None:
            self.capacity_dollars += rate * max(0.0, t - start)

    def _resolve_victim(self, job: str) -> None:
        for jobs in self.blasts.values():
            jobs.discard(job)

    # -- main ----------------------------------------------------------------
    def run(self) -> AuditReport:
        rep = self.rep
        for check in ("slot_ownership", "dollar_conservation",
                      "preempt_resume", "blast_integrity", "lifecycle",
                      "phase_reconciliation"):
            rep.checks.setdefault(check, True)
        rep.counts["records"] = len(self.records)
        saw_end = False
        for r in self.records:
            kind, t = r.get("kind"), r.get("t", 0.0)
            if kind == "run_start":
                rep.run = r.get("run", 0)
                self.base_slots = int(r.get("slots", 0))
            elif kind == "job_submit":
                self.submitted.add(r["job"])
            elif kind == "job_queue":
                pass
            elif kind == "job_start":
                job = r["job"]
                if r.get("resume"):
                    self.resumes += 1
                self.open_preempts.discard(job)
                self._set_held(t, job, int(r["slots"]), 0, "job_start")
                self._resolve_victim(job)
            elif kind == "job_rescale":
                job = r["job"]
                self._set_held(t, job, int(r["to"]), int(r["from"]),
                               "job_rescale")
                self._resolve_victim(job)
            elif kind == "job_preempt":
                job = r["job"]
                self.preempts += 1
                self.open_preempts.add(job)
                self._set_held(t, job, 0, int(r["slots"]), "job_preempt")
                self._resolve_victim(job)
            elif kind == "job_fail":
                job = r["job"]
                self._set_held(t, job, 0, int(r["slots"]), "job_fail")
                self._resolve_victim(job)
            elif kind == "job_migrate":
                self._resolve_victim(r["job"])
            elif kind == "job_complete":
                job = r["job"]
                if job in self.open_preempts:
                    self.fail("preempt_resume",
                              f"t={t:.1f} {job} completed while preempted "
                              f"(no resume)")
                self._set_held(t, job, 0, int(r["slots"]), "job_complete")
                self.completed.add(job)
                self._resolve_victim(job)
            elif kind == "node_up":
                node = r["node"]
                self.active[node] = int(r["slots"])
                rate = (r.get("slots", 0)
                        * r.get("price_per_slot_hour", 0.0) / 3600.0)
                self.node_rate[node] = rate
                self.bill_from[node] = t
            elif kind == "node_cordon":
                # nodes carved out of run_start.slots (the live operator's
                # fixed pool) were never node_up'd: open the drain window
                # (cordoned non-empty) without inventing capacity
                node = r["node"]
                self.cordoned[node] = self.active.pop(node, 0)
            elif kind == "node_uncordon":
                node = r["node"]
                slots = self.cordoned.pop(node, 0)
                if slots:
                    self.active[node] = slots
            elif kind == "node_removed":
                node = r["node"]
                self.active.pop(node, None)
                self.cordoned.pop(node, None)
                self._check_capacity(t, "node_removed")
            elif kind == "spot_kill":
                node = r["node"]
                if not r.get("was_cordoned"):
                    self.cordoned[node] = self.active.pop(
                        node, r.get("slots", 0))
                self.blast_depth += 1
                self.blasts[node] = set(r.get("residents", {}))
                self._end_billing(t, node)
            elif kind == "kill_blast_end":
                node = r["node"]
                self.blast_depth -= 1
                self.cordoned.pop(node, None)
                self.active.pop(node, None)
                unresolved = self.blasts.pop(node, set())
                if unresolved:
                    self.fail("blast_integrity",
                              f"t={t:.1f} kill of {node}: victims "
                              f"{sorted(unresolved)} have no "
                              f"migrate/rescale/preempt span")
                self._check_capacity(t, "kill_blast_end")
            elif kind == "node_billing_end":
                self._end_billing(t, r["node"])
            elif kind == "zone_reclaim":
                self.blast_depth += 1
            elif kind == "zone_reclaim_end":
                self.blast_depth -= 1
            elif kind == "cost_transfer":
                self.transfer_dollars += float(r.get("dollars", 0.0))
            elif kind == "cost_preempt_overhead":
                self.overhead_dollars += float(r.get("dollars", 0.0))
            elif kind == "decision":
                rep.counts["decisions"] = rep.counts.get("decisions", 0) + 1
            elif kind == "run_end":
                saw_end = True
                self._finish(r, t)
        if not saw_end:
            self.fail("lifecycle", "no run_end record (truncated trace)")
        # phase decomposition must partition every completed job's makespan
        # (audit imports critical_path; critical_path never imports audit)
        from repro.obs.critical_path import reconcile
        for msg in reconcile(self.records, rel_tol=1e-3):
            self.fail("phase_reconciliation", msg)
        rep.counts.update(
            submits=len(self.submitted), completes=len(self.completed),
            preempts=self.preempts, resumes=self.resumes)
        return rep

    def _finish(self, r: Dict[str, Any], t: float) -> None:
        # close out nodes still billing at the end of the run
        for node in list(self.node_rate):
            self._end_billing(t, node)
        expect_total = self.capacity_dollars + self.transfer_dollars
        total = float(r.get("total_cost", 0.0))
        if not math.isclose(total, expect_total,
                            rel_tol=1e-6, abs_tol=1e-6):
            self.fail("dollar_conservation",
                      f"run_end.total_cost={total:.6f} but node intervals + "
                      f"transfers re-derive {expect_total:.6f}")
        xfer = float(r.get("transfer_cost", 0.0))
        if not math.isclose(xfer, self.transfer_dollars,
                            rel_tol=1e-6, abs_tol=1e-9):
            self.fail("dollar_conservation",
                      f"run_end.transfer_cost={xfer:.6f} != itemized "
                      f"{self.transfer_dollars:.6f}")
        ovh = float(r.get("preempt_overhead_cost", 0.0))
        if not math.isclose(ovh, self.overhead_dollars,
                            rel_tol=1e-6, abs_tol=1e-9):
            self.fail("dollar_conservation",
                      f"run_end.preempt_overhead_cost={ovh:.6f} != itemized "
                      f"{self.overhead_dollars:.6f}")
        dropped = int(r.get("dropped", 0))
        if len(self.submitted) - len(self.completed) != dropped:
            self.fail("lifecycle",
                      f"{len(self.submitted)} submits - "
                      f"{len(self.completed)} completes != "
                      f"run_end.dropped={dropped}")
        # every preempt is matched by a resume or accounted a drop
        if len(self.open_preempts) > dropped:
            self.fail("preempt_resume",
                      f"{len(self.open_preempts)} preempted jobs never "
                      f"resumed but only {dropped} dropped")
        leaked = {j: s for j, s in self.held.items() if s}
        if leaked:
            self.fail("slot_ownership",
                      f"slots still held at run_end: {leaked}")


def audit_records(records: List[Dict[str, Any]],
                  source: str = "<records>") -> List[AuditReport]:
    """Audit every run in a loaded record stream."""
    return [_RunAuditor(run, source).run() for run in split_runs(records)]


def audit_file(path: str) -> List[AuditReport]:
    return audit_records(Tracer.load(path), source=path)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Replay trace JSONL files through the conservation "
                    "invariant auditor.")
    ap.add_argument("paths", nargs="+", help="trace .jsonl files")
    args = ap.parse_args(argv)
    failed = 0
    for path in args.paths:
        reports = audit_file(path)
        if not reports:
            print(f"[FAIL] {path}: no runs found")
            failed += 1
            continue
        for rep in reports:
            print(rep.summary())
            if not rep.ok:
                failed += 1
    print(f"obs-audit: {'FAIL' if failed else 'PASS'} "
          f"({failed} failing run(s))")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
