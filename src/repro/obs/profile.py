"""Deterministic zero-dep self-profiler for the simulator hot path.

The ROADMAP's event-loop refactor needs to know where simulator wall-clock
goes *before* it starts moving code: per-event-kind handler cost, heap-op
cost, metrics-tick cost, tracer-site cost.  cProfile answers that but
distorts the loop (~3-5x) and drags in pstats; this profiler is two
``perf_counter`` calls per timed region and a dict update, cheap enough to
leave on for a whole benchmark rung.

Wiring mirrors the tracer exactly:

- ``Simulator(..., profiler=SimProfiler())`` (or ``CloudSimulator``) times
  every dispatched event by kind; ``EventQueue`` picks the profiler up from
  the simulator and times heap pushes;
- :func:`install_profiler` sets a process-wide default (used by
  ``benchmarks/run.py --profile``) that simulators adopt at construction,
  so benchmark tables profile without signature changes;
- off is free: every site guards with ``if prof is not None``.

``report()`` renders the accumulators plus two micro-benchmarks (null-tracer
guard cost, active emit cost) as the ``profile`` section of
``BENCH_simcore.json``; :mod:`repro.obs.watchdog` diffs that section against
the committed baseline.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, Iterator, Optional


class SimProfiler:
    """Accumulating profiler: ``event(kind, dt)`` per dispatched event,
    ``section(name, dt)`` for named regions (heap ops, metrics ticks)."""

    __slots__ = ("_events", "_sections", "wall_s")

    def __init__(self):
        self._events: Dict[str, list] = {}     # kind -> [count, total_s]
        self._sections: Dict[str, list] = {}   # name -> [count, total_s]
        self.wall_s = 0.0                      # whole-run wall (set by runner)

    # -- hot-path accumulators (no allocation after first sight of a key) ----
    def event(self, kind: str, dt: float) -> None:
        cell = self._events.get(kind)
        if cell is None:
            cell = self._events[kind] = [0, 0.0]
        cell[0] += 1
        cell[1] += dt

    def section(self, name: str, dt: float) -> None:
        cell = self._sections.get(name)
        if cell is None:
            cell = self._sections[name] = [0, 0.0]
        cell[0] += 1
        cell[1] += dt

    @contextlib.contextmanager
    def timed(self, name: str) -> Iterator[None]:
        """Convenience for cold(ish) regions; hot paths inline the two
        ``perf_counter`` calls instead."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.section(name, time.perf_counter() - t0)

    # -- results -------------------------------------------------------------
    @staticmethod
    def _render(table: Dict[str, list]) -> Dict[str, Dict[str, float]]:
        out = {}
        for name in sorted(table, key=lambda k: -table[k][1]):
            count, total = table[name]
            out[name] = {
                "count": count,
                "total_s": round(total, 6),
                "mean_us": round(total / count * 1e6, 3) if count else 0.0,
            }
        return out

    def report(self) -> Dict[str, Any]:
        events = self._render(self._events)
        handled = sum(c[1] for c in self._events.values())
        n_events = sum(c[0] for c in self._events.values())
        return {
            "events": events,
            "sections": self._render(self._sections),
            "events_total": n_events,
            "handler_s": round(handled, 6),
            "wall_s": round(self.wall_s, 6),
            # loop overhead = wall not attributable to handlers/sections;
            # negative only if wall_s was never set
            "unattributed_s": round(
                max(0.0, self.wall_s - handled
                    - sum(c[1] for c in self._sections.values())), 6)
            if self.wall_s else 0.0,
        }

    def merge(self, other: "SimProfiler") -> None:
        """Fold another profiler's accumulators into this one (several runs
        of one benchmark rung -> one report)."""
        for kind, (count, total) in other._events.items():
            cell = self._events.setdefault(kind, [0, 0.0])
            cell[0] += count
            cell[1] += total
        for name, (count, total) in other._sections.items():
            cell = self._sections.setdefault(name, [0, 0.0])
            cell[0] += count
            cell[1] += total
        self.wall_s += other.wall_s


_CURRENT: Optional[SimProfiler] = None


def current_profiler() -> Optional[SimProfiler]:
    """The process-installed profiler, or None.  Simulators default to this
    at construction (mirroring :func:`repro.obs.trace.current_tracer`), so
    ``benchmarks/run.py --profile`` reaches every nested simulation."""
    return _CURRENT


@contextlib.contextmanager
def install_profiler(prof: SimProfiler) -> Iterator[SimProfiler]:
    """Make ``prof`` the process default for the duration of the block."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = prof
    try:
        yield prof
    finally:
        _CURRENT = prev
