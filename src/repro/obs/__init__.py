"""Flight recorder for the scheduler stack (observability layer).

- :mod:`repro.obs.trace`     structured JSONL span/event records + null tracer
- :mod:`repro.obs.decisions` decision-audit records (inputs, alternatives,
  verdict) at every policy/autoscaler/bidder choice point
- :mod:`repro.obs.stats`     streaming P2 quantiles, counters, latency recorder
- :mod:`repro.obs.audit`     trace replayer re-deriving conservation invariants
- :mod:`repro.obs.timeline`  text Gantt renderer over a trace
- :mod:`repro.obs.spans`     causal span graph (lifecycle trees + cause edges)
- :mod:`repro.obs.critical_path` per-job phase decomposition + fleet rollups
- :mod:`repro.obs.profile`   zero-dep self-profiler for the simulator hot path
- :mod:`repro.obs.watchdog`  perf baseline diff + metric-stream anomaly scan
"""
from repro.obs.critical_path import (PHASES, FleetPhases, PhaseLedger,
                                     decompose, rollup)
from repro.obs.decisions import DecisionLog, decision_records
from repro.obs.profile import SimProfiler, current_profiler, install_profiler
from repro.obs.spans import (Span, SpanGraph, SpanGraphBuilder, SpanTap,
                             build_span_graph)
from repro.obs.stats import Counters, LatencyRecorder, P2Quantile
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, current_tracer,
                             install)
from repro.obs.watchdog import (WatchdogConfig, WatchdogReport,
                                diff_snapshots, rolling_median_spikes)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "install", "current_tracer",
    "DecisionLog", "decision_records",
    "P2Quantile", "Counters", "LatencyRecorder",
    "Span", "SpanGraph", "SpanGraphBuilder", "SpanTap", "build_span_graph",
    "PHASES", "PhaseLedger", "FleetPhases", "decompose", "rollup",
    "SimProfiler", "current_profiler", "install_profiler",
    "WatchdogConfig", "WatchdogReport", "diff_snapshots",
    "rolling_median_spikes",
]
