"""Flight recorder for the scheduler stack (observability layer).

- :mod:`repro.obs.trace`     structured JSONL span/event records + null tracer
- :mod:`repro.obs.decisions` decision-audit records (inputs, alternatives,
  verdict) at every policy/autoscaler/bidder choice point
- :mod:`repro.obs.stats`     streaming P2 quantiles, counters, latency recorder
- :mod:`repro.obs.audit`     trace replayer re-deriving conservation invariants
- :mod:`repro.obs.timeline`  text Gantt renderer over a trace
"""
from repro.obs.decisions import DecisionLog, decision_records
from repro.obs.stats import Counters, LatencyRecorder, P2Quantile
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, current_tracer,
                             install)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "install", "current_tracer",
    "DecisionLog", "decision_records",
    "P2Quantile", "Counters", "LatencyRecorder",
]
