"""Per-job makespan decomposition into named phases + fleet rollups.

Where did a job's makespan go?  The paper's headline metrics (response time,
rescale overhead) are scalars; this module attributes every second between
``submit`` and ``complete`` to exactly one of the :data:`PHASES`:

==============  ============================================================
phase           seconds spent ...
==============  ============================================================
``queue_wait``  waiting for slots before the FIRST start (minus boot_wait)
``boot_wait``   part of that initial wait while cloud nodes were booting —
                capacity was coming, the job just had to outlast the boot
``ckpt``        writing the preemption checkpoint (clock advance before the
                victim's slots free up)
``outage``      kill/preempt -> resume gap: the job held nothing and made no
                progress (the paper's kill->resume outage)
``restore``     restoring the checkpoint after a resume
``rescale``     shrink/expand/migrate overhead windows (the fig5 stages)
``compute``     the remainder of every running segment — actual progress
==============  ============================================================

The phases PARTITION the makespan: for every completed job,
``sum(phases.values()) == end_time - submit_time`` exactly (this is enforced
to <0.1% by the trace auditor on table1 + fig5 traces, and by construction
here — ``compute`` is the measured remainder of the running segments, never
an independent estimate).

One engine, two feeds:

- **live**: every ``Simulator``/``CloudSimulator`` owns a
  :class:`PhaseLedger` and calls its ``on_*`` hooks from the same code paths
  that emit trace records, so every run — traced or not — lands attributed
  phase fields in :class:`~repro.core.metrics.ScheduleMetrics`
  (``phase_seconds`` / ``phase_by_priority`` / ``dominant_phase``);
- **offline**: :func:`decompose` replays a flight-recorder JSONL stream
  (one run) through the same ledger, and :func:`analyze` adds the fleet
  rollups + the longest causal chain from :mod:`repro.obs.spans`.

The overhead-window bookkeeping mirrors the simulator exactly: windows stack
(``start = max(t, overhead_until)``), a preempt clips open windows at the
segment boundary, and the backdated checkpoint window never overlaps a
stacked window, so no second is attributed twice.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

#: the named phases, in causal order; compute is always last (the remainder)
PHASES = ("queue_wait", "boot_wait", "ckpt", "outage", "restore", "rescale",
          "compute")


def merge_intervals(ivs: List[Tuple[float, float]]
                    ) -> List[Tuple[float, float]]:
    """Union of possibly-overlapping intervals (sorted, merged)."""
    out: List[Tuple[float, float]] = []
    for t0, t1 in sorted(ivs):
        if t1 <= t0:
            continue
        if out and t0 <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], t1))
        else:
            out.append((t0, t1))
    return out


def overlap(window: Tuple[float, float],
            ivs: List[Tuple[float, float]]) -> float:
    """Measure of ``window`` covered by the (merged) interval union."""
    w0, w1 = window
    return sum(max(0.0, min(w1, t1) - max(w0, t0)) for t0, t1 in ivs)


class _JobPhases:
    """Per-job raw material: wait windows, running segments, overhead
    windows.  Finalized into a phase dict once the lifecycle ends."""

    __slots__ = ("submit_t", "wait_from", "wait_kind", "seg_start",
                 "segments", "windows", "ovh_until", "end_t", "started")

    def __init__(self, submit_t: float):
        self.submit_t = submit_t
        self.wait_from: Optional[float] = submit_t
        self.wait_kind = "initial"
        self.seg_start: Optional[float] = None
        self.segments: List[Tuple[float, float]] = []
        # (phase, t0, t1) overhead windows, non-overlapping by construction
        self.windows: List[Tuple[str, float, float]] = []
        self.ovh_until = 0.0
        self.end_t: Optional[float] = None
        self.started = False

    # -- lifecycle -----------------------------------------------------------
    def start(self, t: float, restore_s: float,
              outages: List[Tuple[float, float]]) -> None:
        if self.wait_from is not None and self.wait_kind == "outage":
            outages.append((self.wait_from, t))
        self.wait_from = None
        self.seg_start = t
        self.started = True
        # mirror of Simulator create: overhead_until is ASSIGNED (not
        # stacked) on resume, 0-width for a first start
        self.ovh_until = t + restore_s
        if restore_s > 0.0:
            self.windows.append(("restore", t, t + restore_s))

    def overhead(self, phase: str, t: float, seconds: float) -> None:
        if self.seg_start is None or seconds <= 0.0:
            return
        t0 = max(t, self.ovh_until)     # mirror: max(now, overhead_until)
        self.windows.append((phase, t0, t0 + seconds))
        self.ovh_until = t0 + seconds

    def preempt(self, t: float, ckpt_s: float) -> None:
        """``t`` is the post-checkpoint emission time (the simulator advances
        the clock by ``ckpt_s`` before the record lands)."""
        if self.seg_start is None:
            return
        if ckpt_s > 0.0:
            # backdated window; its start is clipped past any stacked window
            # (they all end at ovh_until) so the partition never double-counts
            c0 = max(self.seg_start, t - ckpt_s, min(self.ovh_until, t))
            if t > c0:
                self.windows.append(("ckpt", c0, t))
        self._close_segment(t)
        self.wait_from, self.wait_kind = t, "outage"

    def fail(self, t: float) -> None:
        self._close_segment(t)
        self.wait_from, self.wait_kind = t, "outage"

    def complete(self, t: float) -> None:
        self._close_segment(t)
        self.end_t = t

    def _close_segment(self, t: float) -> None:
        if self.seg_start is not None:
            self.segments.append((self.seg_start, t))
            self.seg_start = None
        self.ovh_until = min(self.ovh_until, t)

    # -- finalize ------------------------------------------------------------
    def phases(self, outages: List[Tuple[float, float]],
               boot_windows: List[Tuple[float, float]]
               ) -> Optional[Dict[str, float]]:
        """The finalized partition, or None while the job is still live."""
        if self.end_t is None or not self.started:
            return None
        out = dict.fromkeys(PHASES, 0.0)
        first_start = self.segments[0][0] if self.segments else self.end_t
        if boot_windows:
            init = (self.submit_t, first_start)
            boot = overlap(init, merge_intervals(boot_windows))
        else:       # pure-sim run: no node boots (int 0, like sum(()))
            boot = 0
        out["boot_wait"] = boot
        out["queue_wait"] = max(0.0, (first_start - self.submit_t) - boot)
        out["outage"] = sum(t1 - t0 for t0, t1 in outages)
        running = sum(t1 - t0 for t0, t1 in self.segments)
        attributed = 0.0
        for phase, w0, w1 in self.windows:
            d = overlap((w0, w1), self.segments)
            out[phase] += d
            attributed += d
        out["compute"] = max(0.0, running - attributed)
        return out


class PhaseLedger:
    """Always-on per-job phase accumulator.  The hooks are cheap (a few dict
    ops per lifecycle action, nothing per event) — ``obs.profile`` measures
    their cost as part of the handler timings."""

    def __init__(self):
        self._jobs: Dict[str, _JobPhases] = {}
        self._outages: Dict[str, List[Tuple[float, float]]] = {}
        self._boot_windows: List[Tuple[float, float]] = []
        self._prio: Dict[str, int] = {}

    # -- hooks (called by the simulators / the offline feed) -----------------
    def on_submit(self, job_id: str, t: float,
                  priority: Optional[int] = None) -> None:
        self._jobs[job_id] = _JobPhases(t)
        self._outages[job_id] = []
        if priority is not None:
            self._prio[job_id] = priority

    def on_start(self, job_id: str, t: float, restore_s: float = 0.0) -> None:
        jp = self._jobs.get(job_id)
        if jp is not None:
            jp.start(t, restore_s, self._outages[job_id])

    def on_rescale(self, job_id: str, t: float, overhead_s: float) -> None:
        jp = self._jobs.get(job_id)
        if jp is not None:
            jp.overhead("rescale", t, overhead_s)

    # a migration pays the rescale-model overhead — same phase family
    on_migrate = on_rescale

    def on_preempt(self, job_id: str, t: float, ckpt_s: float) -> None:
        jp = self._jobs.get(job_id)
        if jp is not None:
            jp.preempt(t, ckpt_s)

    def on_fail(self, job_id: str, t: float) -> None:
        jp = self._jobs.get(job_id)
        if jp is not None:
            jp.fail(t)

    def on_complete(self, job_id: str, t: float) -> None:
        jp = self._jobs.get(job_id)
        if jp is not None:
            jp.complete(t)

    def note_boot_window(self, t0: float, t1: float) -> None:
        """A cloud node's request->up interval; overlaps with initial waits
        become ``boot_wait``.  Duplicates are fine (the union dedups)."""
        if t1 > t0:
            self._boot_windows.append((t0, t1))

    # -- results -------------------------------------------------------------
    def phases_of(self, job_id: str) -> Optional[Dict[str, float]]:
        jp = self._jobs.get(job_id)
        if jp is None:
            return None
        return jp.phases(self._outages[job_id], self._boot_windows)

    def per_job(self) -> Dict[str, Dict[str, float]]:
        """Finalized decompositions for every completed job."""
        out = {}
        for job_id in self._jobs:
            ph = self.phases_of(job_id)
            if ph is not None:
                out[job_id] = ph
        return out

    def priority_of(self, job_id: str) -> int:
        return self._prio.get(job_id, 1)


class NullPhaseLedger(PhaseLedger):
    """No-op ledger for bounded-memory fleet runs (``Simulator(...,
    track_phases=False)``): a million-job replay must not retain per-job
    phase state it will never roll up.  ``per_job()`` stays empty, so
    ``compute_metrics`` simply leaves the ``phase_*`` fields at their
    defaults."""

    def on_submit(self, job_id, t, priority=None):
        pass

    def on_start(self, job_id, t, restore_s=0.0):
        pass

    def on_rescale(self, job_id, t, overhead_s):
        pass

    on_migrate = on_rescale

    def on_preempt(self, job_id, t, ckpt_s):
        pass

    def on_fail(self, job_id, t):
        pass

    def on_complete(self, job_id, t):
        pass

    def note_boot_window(self, t0, t1):
        pass


# ---------------------------------------------------------------------------
# Offline: feed a flight-recorder stream through the same ledger
# ---------------------------------------------------------------------------

def feed_record(ledger: PhaseLedger, r: Dict[str, Any]) -> None:
    """Apply one trace record to a ledger (the offline/online shared feed)."""
    kind = r.get("kind")
    if kind is None or not kind.startswith(("job_", "node_up")):
        return
    t = r.get("t", 0.0)
    if kind == "job_submit":
        ledger.on_submit(r["job"], t, priority=r.get("priority"))
    elif kind == "job_start":
        ledger.on_start(r["job"], t,
                        restore_s=(r.get("overhead_s", 0.0)
                                   if r.get("resume") else 0.0))
    elif kind == "job_rescale":
        ledger.on_rescale(r["job"], t, r.get("overhead_s", 0.0))
    elif kind == "job_migrate":
        ledger.on_migrate(r["job"], t, r.get("overhead_s", 0.0))
    elif kind == "job_preempt":
        ledger.on_preempt(r["job"], t, r.get("ckpt_s", 0.0))
    elif kind == "job_fail":
        ledger.on_fail(r["job"], t)
    elif kind == "job_complete":
        ledger.on_complete(r["job"], t)
    elif kind == "node_up" and r.get("boot_s", 0.0) > 0.0:
        ledger.note_boot_window(t - r["boot_s"], t)


def decompose(records: Sequence[Dict[str, Any]]
              ) -> Dict[str, Dict[str, float]]:
    """Per-job phase decomposition of ONE run's records."""
    ledger = PhaseLedger()
    for r in records:
        feed_record(ledger, r)
    return ledger.per_job()


# ---------------------------------------------------------------------------
# Fleet rollups
# ---------------------------------------------------------------------------

@dataclass
class FleetPhases:
    """Fleet-level rollup of per-job decompositions."""
    jobs: int = 0
    #: priority-weighted mean seconds per phase; sums to the weighted mean
    #: completion time of the covered jobs
    phase_seconds: Dict[str, float] = field(default_factory=dict)
    #: plain mean seconds per phase within one priority class, flattened as
    #: ``prio<k>.<phase>``
    phase_by_priority: Dict[str, float] = field(default_factory=dict)
    #: jobs whose single largest phase is <phase>
    dominant_phase: Dict[str, int] = field(default_factory=dict)
    #: longest cause-edge chain in the run's span graph (offline only)
    longest_causal_chain: int = 0

    def shares(self) -> Dict[str, float]:
        total = sum(self.phase_seconds.values())
        if total <= 0.0:
            return {}
        return {p: s / total for p, s in self.phase_seconds.items()}


def rollup(per_job: Dict[str, Dict[str, float]],
           priorities: Dict[str, int]) -> FleetPhases:
    """Aggregate per-job phase dicts (priority-weighted, like WMCT)."""
    if not per_job:
        return FleetPhases()
    wsum = sum(priorities.get(j, 1) for j in per_job) or 1.0
    agg = {p: 0.0 for p in PHASES}
    by_prio: Dict[int, Dict[str, float]] = {}
    counts: Dict[int, int] = {}
    dominant: Dict[str, int] = {}
    for job_id, ph in per_job.items():
        w = priorities.get(job_id, 1)
        cls = by_prio.setdefault(w, dict.fromkeys(PHASES, 0.0))
        counts[w] = counts.get(w, 0) + 1
        top, top_v = None, -1.0     # first maximal phase, like max(PHASES)
        for p in PHASES:
            v = ph.get(p, 0.0)
            agg[p] += w * v
            cls[p] += v
            if v > top_v:
                top, top_v = p, v
        dominant[top] = dominant.get(top, 0) + 1
    flat = {}
    for k in sorted(by_prio):
        for p in PHASES:
            flat[f"prio{k}.{p}"] = by_prio[k][p] / counts[k]
    return FleetPhases(
        jobs=len(per_job),
        phase_seconds={p: agg[p] / wsum for p in PHASES},
        phase_by_priority=flat,
        dominant_phase=dict(sorted(dominant.items())),
    )


def analyze(records: Sequence[Dict[str, Any]]) -> FleetPhases:
    """Offline fleet report for ONE run's records: decomposition rollup plus
    the longest causal chain from the span graph."""
    from repro.obs.spans import build_span_graph
    per_job = decompose(records)
    prio = {r["job"]: r.get("priority", 1) for r in records
            if r.get("kind") == "job_submit"}
    fleet = rollup(per_job, prio)
    fleet.longest_causal_chain = build_span_graph(records) \
        .longest_causal_chain()
    return fleet


def reconcile(records: Sequence[Dict[str, Any]], rel_tol: float = 1e-3
              ) -> List[str]:
    """Check that every completed job's phase sum equals its makespan to
    ``rel_tol`` (<0.1% by default).  Returns violation strings (empty = OK).
    Used by :mod:`repro.obs.audit` as the ``phase_reconciliation`` check."""
    submits = {r["job"]: r["t"] for r in records
               if r.get("kind") == "job_submit"}
    ends = {r["job"]: r["t"] for r in records
            if r.get("kind") == "job_complete"}
    violations = []
    for job_id, ph in decompose(records).items():
        if job_id not in submits or job_id not in ends:
            continue
        makespan = ends[job_id] - submits[job_id]
        total = sum(ph.values())
        if abs(total - makespan) > max(1e-6, rel_tol * abs(makespan)):
            violations.append(
                f"{job_id}: phases sum to {total:.3f}s but makespan is "
                f"{makespan:.3f}s")
    return violations
