"""yi-6b — llama-architecture dense GQA model.

[arXiv:2403.04652; hf]  32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import FF_SWIGLU, ModelConfig, register


@register("yi-6b")
def yi_6b() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11_008,
        vocab_size=64_000,
        ff_kind=FF_SWIGLU,
        rope_theta=10_000.0,
        expected_params=6.1e9,
        source="arXiv:2403.04652",
    )
