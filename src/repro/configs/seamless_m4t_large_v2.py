"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio) backbone.

[arXiv:2308.11596; hf]  24 encoder + 24 decoder layers, d_model=1024,
16H (kv=16), d_ff=8192, vocab=256206.  The speech frontend (w2v-BERT feature
extractor) is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings of shape (batch, frames, d_model).
"""
from repro.configs.base import FF_GELU, ModelConfig, register


@register("seamless-m4t-large-v2")
def seamless_m4t_large_v2() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=24,          # decoder layers
        enc_layers=24,          # encoder layers
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256_206,
        ff_kind=FF_GELU,
        frontend="audio",
        tie_embeddings=True,
        rope_theta=10_000.0,
        expected_params=1.45e9,  # transformer backbone only (frontend stubbed)
        source="arXiv:2308.11596",
    )
