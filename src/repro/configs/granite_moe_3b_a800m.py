"""granite-moe-3b-a800m — MoE transformer, 40 routed experts, top-8.

[hf:ibm-granite/granite-3.0-*-base family; hf]  32L d_model=1536 24H (GQA kv=8)
expert d_ff=512, vocab=49155, MoE 40e top-8, every layer MoE (no dense FFN).

NOTE: the assignment line reads "MoE 40e top-8" while its provenance note says
"32 experts top-8"; we implement the primary spec (40 experts) and record the
discrepancy here.
"""
from repro.configs.base import FF_SWIGLU, ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def granite_moe_3b_a800m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=49_155,
        ff_kind=FF_SWIGLU,
        moe=MoEConfig(num_experts=40, experts_per_token=8,
                      num_shared_experts=0, d_ff_expert=512,
                      moe_every=1, moe_offset=0, ff_kind=FF_SWIGLU),
        tie_embeddings=True,
        rope_theta=10_000.0,
        expected_params=3.3e9,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base (scaled spec per assignment)",
    )
