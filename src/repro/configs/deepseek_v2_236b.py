"""deepseek-v2-236b — MLA + fine-grained MoE.

[arXiv:2405.04434; hf]  60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536,
qk_nope=128, qk_rope=64, v=128), MoE: 2 shared + 160 routed top-6,
expert d_ff=1536, first layer dense (d_ff=12288), vocab=102400.
"""
from repro.configs.base import (FF_SWIGLU, ModelConfig, MLAConfig, MoEConfig,
                                register)


@register("deepseek-v2-236b")
def deepseek_v2_236b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,       # MLA: logical kv heads == q heads
        head_dim=128,           # v head dim (roofline bookkeeping)
        d_ff=12_288,            # dense FFN used in layer 0 only
        vocab_size=102_400,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        ff_kind=FF_SWIGLU,
        moe=MoEConfig(num_experts=160, experts_per_token=6,
                      num_shared_experts=2, d_ff_expert=1536,
                      moe_every=1, moe_offset=0, first_dense=1,
                      ff_kind=FF_SWIGLU),
        rope_theta=10_000.0,
        expected_params=236e9,
        source="arXiv:2405.04434",
    )
