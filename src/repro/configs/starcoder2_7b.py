"""starcoder2-7b — dense GQA + RoPE code model.

[arXiv:2402.19173; hf]  32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, non-gated GELU MLP, rope_theta=1e5.
"""
from repro.configs.base import FF_GELU, ModelConfig, register


@register("starcoder2-7b")
def starcoder2_7b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        d_ff=18_432,
        vocab_size=49_152,
        ff_kind=FF_GELU,
        rope_theta=100_000.0,
        expected_params=7.4e9,
        source="arXiv:2402.19173",
    )
