"""mamba2-1.3b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  48L d_model=2048, d_ff=0, vocab=50280,
ssm_state=128. d_inner = 2*d_model = 4096, head_dim=64 -> 64 SSD heads.
"""
from repro.configs.base import (FF_NONE, SSM, ModelConfig, SSMConfig, register)


@register("mamba2-1.3b")
def mamba2_1_3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50_280,
        default_mixer=SSM,
        attn_every=0,  # never attention
        ff_kind=FF_NONE,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, num_groups=1,
                      conv_width=4, chunk=128),
        tie_embeddings=True,
        supports_long_context=True,
        expected_params=1.35e9,
        source="arXiv:2405.21060",
    )
