"""Base configuration system.

Every assigned architecture is described by a :class:`ModelConfig`. Configs are
plain frozen dataclasses so they hash, compare, and serialize trivially; they
are consumed by ``repro.models.model`` (pure functions) and by the launcher.

Input *shapes* (train_4k / prefill_32k / decode_32k / long_500k) live here too,
as :class:`ShapeConfig`, so the (arch x shape) grid is a first-class object.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Layer mixer kinds.
ATTN = "attn"          # softmax attention (GQA / MHA)
MLA = "mla"            # multi-head latent attention (DeepSeek-V2)
SSM = "ssm"            # Mamba-2 SSD block

# Feed-forward kinds.
FF_SWIGLU = "swiglu"   # gated SiLU (llama family)
FF_GELU = "gelu"       # plain 2-matrix GELU MLP (starcoder2)
FF_RELU2 = "relu2"     # squared-ReLU non-gated (nemotron/minitron)
FF_MOE = "moe"         # mixture-of-experts (uses moe_* fields)
FF_NONE = "none"       # no FFN in this layer (mamba2 blocks)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    experts_per_token: int = 0      # top-k
    num_shared_experts: int = 0     # always-on shared experts (DeepSeek-V2)
    d_ff_expert: int = 0            # per-expert hidden dim
    # which layers are MoE: layer i is MoE iff i % moe_every == moe_offset
    # and i >= first_dense (DeepSeek first_k_dense_replace).
    moe_every: int = 1
    moe_offset: int = 0
    first_dense: int = 0
    router_aux_weight: float = 0.01  # load-balancing loss weight
    ff_kind: str = FF_SWIGLU         # activation inside each expert
    capacity_factor: float = 1.25    # per-expert token capacity multiplier


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0            # 0 => full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128              # N
    head_dim: int = 64              # P
    num_heads: int = 0              # H; 0 => d_inner // head_dim
    expand: int = 2                 # d_inner = expand * d_model
    num_groups: int = 1             # G (B/C groups, GQA-analog)
    conv_width: int = 4
    chunk: int = 128                # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                       # dense-FFN hidden dim (0 if no dense FFN)
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads

    # Mixer layout: default every layer is `default_mixer`; hybrids override
    # with attn_every/attn_offset (layer i uses ATTN iff i % attn_every == attn_offset).
    default_mixer: str = ATTN
    attn_every: int = 1
    attn_offset: int = 0

    ff_kind: str = FF_SWIGLU
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # enc-dec (seamless): if enc_layers > 0 the model is encoder-decoder and
    # `num_layers` counts decoder layers.
    enc_layers: int = 0

    # Modality frontend stub: "none" (token ids), "audio" or "vision"
    # (precomputed frame/patch embeddings are an alternative input).
    frontend: str = "none"

    # embedding/lm-head tables are padded up to a multiple of this so the
    # vocab dim shards evenly (MaxText-style); logits beyond vocab_size are
    # masked in the loss and sliced off in serving.
    vocab_pad_to: int = 256
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    qk_norm: bool = False           # chameleon-style per-head q/k RMSNorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # expected parameter count (for sanity tests); 0 to skip the check.
    expected_params: float = 0.0
    # paper-source provenance string.
    source: str = ""
    # archs that may run the long_500k shape (sub-quadratic mixing).
    supports_long_context: bool = False

    # --- derived helpers ---------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = max(1, self.vocab_pad_to)
        return -(-self.vocab_size // p) * p

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def mixer_at(self, i: int) -> str:
        if self.default_mixer == ATTN:
            return ATTN if self.mla is None else MLA
        # attn_every == 0 encodes "no attention layers at all" (pure SSM).
        if self.attn_every and i % self.attn_every == self.attn_offset:
            return ATTN
        return self.default_mixer

    def ff_at(self, i: int) -> str:
        m = self.moe
        if m is not None and m.num_experts > 0:
            if i >= m.first_dense and i % m.moe_every == m.moe_offset:
                return FF_MOE
        return self.ff_kind

    def layer_period(self) -> int:
        """Smallest k such that layers i and i+k are structurally identical
        (used to stack params for lax.scan)."""
        period = 1
        if self.default_mixer != ATTN and self.attn_every > 1:
            period = self.attn_every
        if self.moe is not None and self.moe.num_experts > 0:
            period = _lcm(period, self.moe.moe_every)
        return period

    def scan_layers(self) -> Tuple[int, int]:
        """(num_prefix_layers, num_scanned_layers).

        Layers < first_dense boundary that break homogeneity are kept out of
        the scan (DeepSeek's first dense layer)."""
        prefix = 0
        if self.moe is not None and self.moe.first_dense > 0:
            prefix = self.moe.first_dense
        period = self.layer_period()
        rem = (self.num_layers - prefix) % period
        prefix += rem  # keep non-multiple tail in the prefix for simplicity
        return prefix, self.num_layers - prefix

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell, with a reason when not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: O(L^2) attention at 524k skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(arch_id: str):
    """Decorator factory: register ``arch_id`` -> config factory."""
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch: str) -> ModelConfig:
    from repro import configs  # noqa: F401  (populate registry)
    if arch in _REGISTRY:
        return _REGISTRY[arch]()
    key = arch.lower().replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def list_archs():
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Parameter counting (analytic; used by sanity tests and roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count matching models/model.init_params exactly is
    asserted in tests; this version is closed-form for speed."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    total = cfg.padded_vocab * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * d  # lm head
    total += d  # final norm

    def ff_params(kind: str) -> int:
        if kind == FF_SWIGLU:
            return 3 * d * cfg.d_ff
        if kind in (FF_GELU, FF_RELU2):
            return 2 * d * cfg.d_ff
        if kind == FF_NONE:
            return 0
        raise ValueError(kind)

    def moe_params() -> int:
        m = cfg.moe
        per_expert = 3 * d * m.d_ff_expert if m.ff_kind == FF_SWIGLU else 2 * d * m.d_ff_expert
        total_m = m.num_experts * per_expert + m.num_shared_experts * per_expert
        total_m += d * m.num_experts  # router
        return total_m

    def attn_params() -> int:
        q = d * cfg.num_heads * hd
        kv = 2 * d * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * d
        return q + kv + o

    def mla_params() -> int:
        a = cfg.mla
        nh = cfg.num_heads
        p = 0
        if a.q_lora_rank:
            p += d * a.q_lora_rank + a.q_lora_rank  # down + norm
            p += a.q_lora_rank * nh * (a.qk_nope_head_dim + a.qk_rope_head_dim)
        else:
            p += d * nh * (a.qk_nope_head_dim + a.qk_rope_head_dim)
        p += d * (a.kv_lora_rank + a.qk_rope_head_dim)  # kv down (+ shared rope key)
        p += a.kv_lora_rank  # kv norm
        p += a.kv_lora_rank * nh * (a.qk_nope_head_dim + a.v_head_dim)  # kv up
        p += nh * a.v_head_dim * d  # o proj
        return p

    def ssm_params() -> int:
        s = cfg.ssm
        d_inner = s.expand * d
        nh = s.num_heads or d_inner // s.head_dim
        conv_dim = d_inner + 2 * s.num_groups * s.d_state
        p = d * (2 * d_inner + 2 * s.num_groups * s.d_state + nh)  # in_proj (z,x,B,C,dt)
        p += s.conv_width * conv_dim + conv_dim  # conv weight + bias
        p += nh * 3  # A_log, D, dt_bias
        p += d_inner  # pre-out norm
        p += d_inner * d  # out_proj
        return p

    def layer_params(i: int) -> int:
        mixer = cfg.mixer_at(i)
        p = d  # pre-mixer norm
        if mixer == ATTN:
            p += attn_params()
            if cfg.qk_norm:
                p += 2 * hd
        elif mixer == MLA:
            p += mla_params()
        elif mixer == SSM:
            p += ssm_params()
        ff = cfg.ff_at(i)
        if ff != FF_NONE:
            p += d  # pre-ff norm
            p += moe_params() if ff == FF_MOE else ff_params(ff)
        return p

    for i in range(cfg.num_layers):
        total += layer_params(i)

    if cfg.enc_layers:
        # encoder: self-attn + dense ffn per layer, plus cross-attn params in
        # each decoder layer and a final encoder norm.
        enc_layer = 2 * d + attn_params() + ff_params(cfg.ff_kind)
        total += cfg.enc_layers * enc_layer + d
        total += cfg.num_layers * (d + attn_params())  # decoder cross-attn + norm
    return total


def count_active_params(cfg: ModelConfig) -> int:
    """Active (per-token) params for MoE rooflines: replace num_experts with
    experts_per_token + shared."""
    if cfg.moe is None or cfg.moe.num_experts == 0:
        return count_params(cfg)
    active_moe = dataclasses.replace(
        cfg.moe, num_experts=cfg.moe.experts_per_token)
    return count_params(cfg.with_(moe=active_moe))
