"""Architecture configs (one module per assigned architecture).

``get_config(arch)`` returns the full published config; ``smoke_config(arch)``
returns a structurally identical but tiny variant for CPU smoke tests — same
family, mixer layout, MoE/MLA/SSM structure, but small widths / few layers /
tiny vocab.  Full configs are only ever lowered via ShapeDtypeStructs
(launch/dryrun.py); they are never materialized on this container.
"""
import dataclasses

from repro.configs.base import (ATTN, FF_GELU, FF_MOE, FF_NONE, FF_RELU2,
                                FF_SWIGLU, MLA, SSM, MLAConfig, ModelConfig,
                                MoEConfig, SHAPES, ShapeConfig, SSMConfig,
                                count_active_params, count_params, get_config,
                                list_archs, register, shape_applicable)

# populate the registry
from repro.configs import (chameleon_34b, deepseek_v2_236b,  # noqa: F401
                           granite_moe_3b_a800m, jamba_v0_1_52b, mamba2_1_3b,
                           minitron_4b, seamless_m4t_large_v2, starcoder2_7b,
                           yi_6b, yi_9b)

ALL_ARCHS = list_archs()


def smoke_config(arch: str, *, layers_per_period: int = 1) -> ModelConfig:
    """Tiny structurally-faithful variant of ``arch`` for CPU smoke tests."""
    cfg = get_config(arch)
    period = cfg.layer_period()
    num_layers = max(2, period * layers_per_period)
    prefix = cfg.moe.first_dense if cfg.moe else 0
    num_layers += prefix

    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        expected_params=0.0,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
                  head_dim=16)
    if cfg.mla is not None:
        kw.update(mla=MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                qk_nope_head_dim=16, qk_rope_head_dim=8,
                                v_head_dim=16),
                  num_heads=4, num_kv_heads=4, head_dim=16)
    if cfg.moe is not None:
        kw.update(moe=dataclasses.replace(
            cfg.moe, num_experts=4,
            experts_per_token=min(2, cfg.moe.experts_per_token),
            d_ff_expert=32))
    if cfg.ssm is not None:
        kw.update(ssm=dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, num_groups=1, chunk=8))
    if cfg.enc_layers:
        kw.update(enc_layers=2)
    return cfg.with_(**kw)


__all__ = [
    "ATTN", "MLA", "SSM", "FF_SWIGLU", "FF_GELU", "FF_RELU2", "FF_MOE",
    "FF_NONE", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "ShapeConfig", "SHAPES", "ALL_ARCHS", "get_config", "smoke_config",
    "list_archs", "count_params", "count_active_params", "shape_applicable",
    "register",
]
