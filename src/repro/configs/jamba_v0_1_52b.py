"""jamba-v0.1-52b — hybrid Mamba + attention (1:7) with MoE (16e top-2).

[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, attention at 1 of every 8 layers (offset 3 within each block),
MoE at every other layer (16 experts, top-2), Mamba elsewhere.

Hardware adaptation note (DESIGN.md §2): Jamba v0.1 uses Mamba-1 selective
scan; we use the Mamba-2 SSD block (d_state=16 as in Jamba) so both SSM archs
share the TPU-native chunked-SSD kernel. Parameter count is preserved to ~2%.
"""
from repro.configs.base import (FF_SWIGLU, SSM, ModelConfig, MoEConfig,
                                SSMConfig, register)


@register("jamba-v0.1-52b")
def jamba_v0_1_52b() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=65_536,
        default_mixer=SSM,
        attn_every=8,
        attn_offset=3,
        ff_kind=FF_SWIGLU,
        moe=MoEConfig(num_experts=16, experts_per_token=2,
                      num_shared_experts=0, d_ff_expert=14_336,
                      moe_every=2, moe_offset=1, ff_kind=FF_SWIGLU),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, num_groups=1,
                      conv_width=4, chunk=128),
        supports_long_context=True,
        rope_theta=10_000.0,
        expected_params=51.5e9,
        source="arXiv:2403.19887",
    )
