"""chameleon-34b — early-fusion VLM backbone (VQ image tokens), qk-norm.

[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 (text + VQ image codes).  The VQ-VAE image tokenizer is a STUB per
the assignment: the backbone consumes token ids (or precomputed patch
embeddings via the ``inputs_embeds`` path).
"""
from repro.configs.base import FF_SWIGLU, ModelConfig, register


@register("chameleon-34b")
def chameleon_34b() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22_016,
        vocab_size=65_536,
        ff_kind=FF_SWIGLU,
        qk_norm=True,
        frontend="vision",
        rope_theta=10_000.0,
        expected_params=34.3e9,
        source="arXiv:2405.09818",
    )
