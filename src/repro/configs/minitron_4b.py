"""minitron-4b — pruned Nemotron dense model (squared-ReLU MLP).

[arXiv:2407.14679; hf]  32L d_model=3072 24H (GQA kv=8, head_dim=128)
d_ff=9216 vocab=256000.
"""
from repro.configs.base import FF_RELU2, ModelConfig, register


@register("minitron-4b")
def minitron_4b() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256_000,
        ff_kind=FF_RELU2,
        rope_theta=10_000.0,
        expected_params=4.2e9,
        source="arXiv:2407.14679",
    )
