"""Parameter specs: one tree describing shape / logical axes / init for every
parameter of every architecture.  ``init_params`` and ``logical_axes`` and the
dry-run's ShapeDtypeStructs all derive from this tree, so they can never drift.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, FF_GELU, FF_MOE, FF_NONE, FF_RELU2,
                                FF_SWIGLU, MLA, SSM, ModelConfig)


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | ssm_a | dt_bias | uniform_conv
    fan_in: int = 0                   # for normal init scale (0 => shape[0])

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# Spec builders per component
# ---------------------------------------------------------------------------

def _attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "qk")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "qk")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "qk")),
        "wo": ParamSpec((h, hd, d), ("heads", "qk", "embed"), fan_in=h * hd),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        s["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return s


def _mla_specs(cfg: ModelConfig) -> dict:
    a, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk_dim = a.qk_nope_head_dim + a.qk_rope_head_dim
    s = {}
    if a.q_lora_rank:
        s["wq_a"] = ParamSpec((d, a.q_lora_rank), ("embed", "lora"))
        s["q_norm"] = ParamSpec((a.q_lora_rank,), (None,), init="ones")
        s["wq_b"] = ParamSpec((a.q_lora_rank, h, qk_dim), ("lora", "heads", "qk"),
                              fan_in=a.q_lora_rank)
    else:
        s["wq"] = ParamSpec((d, h, qk_dim), ("embed", "heads", "qk"))
    # kv down-projection also produces the shared rope key
    s["wkv_a"] = ParamSpec((d, a.kv_lora_rank + a.qk_rope_head_dim),
                           ("embed", "lora"))
    s["kv_norm"] = ParamSpec((a.kv_lora_rank,), (None,), init="ones")
    s["wkv_b"] = ParamSpec((a.kv_lora_rank, h, a.qk_nope_head_dim + a.v_head_dim),
                           ("lora", "heads", "qk"), fan_in=a.kv_lora_rank)
    s["wo"] = ParamSpec((h, a.v_head_dim, d), ("heads", "qk", "embed"),
                        fan_in=h * a.v_head_dim)
    return s


def _ssm_specs(cfg: ModelConfig) -> dict:
    ss, d = cfg.ssm, cfg.d_model
    d_inner = ss.expand * d
    nh = ss.num_heads or d_inner // ss.head_dim
    gn = ss.num_groups * ss.d_state
    conv_dim = d_inner + 2 * gn
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (gn), C (gn), dt (nh)]
        "in_proj": ParamSpec((d, 2 * d_inner + 2 * gn + nh), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((ss.conv_width, conv_dim), (None, "ssm_inner"),
                            init="uniform_conv", fan_in=ss.conv_width),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="ssm_a"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="dt_bias"),
        "out_norm": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed"), fan_in=d_inner),
    }


def _ffn_specs(cfg: ModelConfig, kind: str, d_ff: int) -> dict:
    d = cfg.d_model
    if kind == FF_SWIGLU:
        return {
            "w_gate": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_down": ParamSpec((d_ff, d), ("ffn", "embed"), fan_in=d_ff),
        }
    if kind in (FF_GELU, FF_RELU2):
        return {
            "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
            "w_down": ParamSpec((d_ff, d), ("ffn", "embed"), fan_in=d_ff),
        }
    raise ValueError(kind)


def _moe_specs(cfg: ModelConfig) -> dict:
    m, d = cfg.moe, cfg.d_model
    e, f = m.num_experts, m.d_ff_expert
    s = {"router": ParamSpec((d, e), ("embed", "experts"))}
    if m.ff_kind == FF_SWIGLU:
        s["w_gate"] = ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"), fan_in=d)
        s["w_up"] = ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"), fan_in=d)
        s["w_down"] = ParamSpec((e, f, d), ("experts", "expert_ffn", "embed"), fan_in=f)
    else:
        s["w_up"] = ParamSpec((e, d, f), ("experts", "embed", "expert_ffn"), fan_in=d)
        s["w_down"] = ParamSpec((e, f, d), ("experts", "expert_ffn", "embed"), fan_in=f)
    if m.num_shared_experts:
        s["shared"] = _ffn_specs(cfg, m.ff_kind, m.num_shared_experts * m.d_ff_expert)
    return s


def _layer_specs(cfg: ModelConfig, i: int, *, cross_attn: bool = False) -> dict:
    d = cfg.d_model
    mixer = cfg.mixer_at(i)
    s = {"mixer_norm": ParamSpec((d,), ("embed",), init="ones")}
    if mixer == ATTN:
        s["mixer"] = _attn_specs(cfg)
    elif mixer == MLA:
        s["mixer"] = _mla_specs(cfg)
    elif mixer == SSM:
        s["mixer"] = _ssm_specs(cfg)
    else:
        raise ValueError(mixer)
    if cross_attn:
        s["cross_norm"] = ParamSpec((d,), ("embed",), init="ones")
        s["cross"] = _attn_specs(cfg)
    ff = cfg.ff_at(i)
    if ff != FF_NONE:
        s["ff_norm"] = ParamSpec((d,), ("embed",), init="ones")
        s["ff"] = _moe_specs(cfg) if ff == FF_MOE else _ffn_specs(cfg, ff, cfg.d_ff)
    return s


def _stack(tree, n: int):
    """Prefix every leaf spec with a scanned 'layers' axis of length n."""
    return jax.tree.map(
        lambda p: ParamSpec((n,) + p.shape, ("layers",) + p.axes, p.init,
                            p.fan_in or p.shape[0]),
        tree, is_leaf=is_spec)


def _decoder_specs(cfg: ModelConfig, *, cross_attn: bool) -> dict:
    prefix_n, scan_n = cfg.scan_layers()
    period = cfg.layer_period()
    s = {}
    if prefix_n:
        s["prefix"] = {f"layer{i}": _layer_specs(cfg, i, cross_attn=cross_attn)
                       for i in range(prefix_n)}
    if scan_n:
        n_blocks = scan_n // period
        block = {f"sub{j}": _layer_specs(cfg, prefix_n + j, cross_attn=cross_attn)
                 for j in range(period)}
        s["blocks"] = _stack(block, n_blocks)
    return s


def _encoder_layer_specs(cfg: ModelConfig) -> dict:
    """Encoder layer: bidirectional self-attention + dense FFN."""
    d = cfg.d_model
    return {
        "mixer_norm": ParamSpec((d,), ("embed",), init="ones"),
        "mixer": _attn_specs(cfg),
        "ff_norm": ParamSpec((d,), ("embed",), init="ones"),
        "ff": _ffn_specs(cfg, cfg.ff_kind, cfg.d_ff),
    }


def param_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = {
        "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed"), fan_in=d),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "decoder": _decoder_specs(cfg, cross_attn=cfg.enc_layers > 0),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.enc_layers:
        enc_block = _stack(_encoder_layer_specs(cfg), cfg.enc_layers)
        s["encoder"] = {"blocks": enc_block,
                        "final_norm": ParamSpec((d,), ("embed",), init="ones")}
    return s


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------

def _init_leaf(spec: ParamSpec, key, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # A in [1, 16) -> a_log = log(A); standard mamba2 init
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":
        # dt in [1e-3, 1e-1] -> bias = softplus^-1(dt)
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    fan = spec.fan_in or spec.shape[0]
    if spec.init == "uniform_conv":
        lim = 1.0 / math.sqrt(fan)
        return jax.random.uniform(key, spec.shape, jnp.float32, -lim, lim).astype(dtype)
    assert spec.init == "normal", spec.init
    scale = 1.0 / math.sqrt(fan)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key) -> dict:
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.dtype)
    arrs = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct tree — used by the dry-run (never allocates)."""
    specs = param_specs(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        specs, is_leaf=is_spec)


def logical_axes(cfg: ModelConfig) -> dict:
    return jax.tree.map(lambda s: s.axes, param_specs(cfg), is_leaf=is_spec)


def param_count(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))
