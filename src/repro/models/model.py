"""Public model API.

Pure functions over (config, params, batch):

- ``loss_fn`` / ``forward_hidden`` — training forward.
- ``prefill`` — build a KV/SSM cache from a prompt; returns last-token logits.
- ``decode_step`` — one token for the whole batch against a fixed-size cache.
- ``input_specs`` / ``abstract_cache`` — ShapeDtypeStruct stand-ins for the
  multi-pod dry-run (weak-type-correct, shardable, never allocated).

Batch conventions (all archs):
    tokens  (B, S) int32      labels (B, S) int32 (-1 = masked)
    enc-dec adds enc_embeds (B, S_enc, d_model)  [frontend stub output]
Decode:
    tokens (B, 1) int32, pos () int32, cache pytree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, FF_NONE, MLA, SSM, ModelConfig,
                                ShapeConfig)
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import chunked_softmax_xent, rmsnorm
from repro.models.params import (abstract_params, init_params, logical_axes,
                                 param_count, param_specs)
from repro.sharding import shard_constraint

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    return shard_constraint(x, "batch", "seq", "embed")


def _head_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T          # (D, V)
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelConfig, params, batch, *, mode: str = "train"):
    """Embeds, runs encoder (if any) + decoder; returns (hidden, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    enc_out = None
    if cfg.enc_layers:
        enc_in = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
        enc_pos = jnp.arange(enc_in.shape[1])
        enc_out = tfm.encoder(cfg, params["encoder"], enc_in,
                              positions=enc_pos, mode=mode)
    x = _embed(cfg, params, tokens)
    x, _, aux = tfm.decoder(cfg, params["decoder"], x, positions=positions,
                            mode=mode, cache=None, pos=None, enc_out=enc_out)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, dict]:
    hidden, aux = forward_hidden(cfg, params, batch, mode="train")
    w_head = _head_weight(cfg, params)
    loss_sum, weight = chunked_softmax_xent(
        hidden, w_head, batch["labels"],
        chunk=min(LOSS_CHUNK, hidden.shape[1]),
        valid_vocab=cfg.vocab_size)
    xent = loss_sum / jnp.maximum(weight, 1.0)
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux, "tokens": weight}


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, i: int, batch: int, max_len: int, dtype,
                 abstract: bool, enc_len: int = 0):
    mixer = cfg.mixer_at(i)
    c = {}
    if mixer in (ATTN,):
        fn = attn_mod.abstract_kv_cache if abstract else attn_mod.init_kv_cache
        c["kv"] = fn(cfg, batch, max_len, dtype)
    elif mixer == MLA:
        fn = attn_mod.abstract_mla_cache if abstract else attn_mod.init_mla_cache
        c["kv"] = fn(cfg, batch, max_len, dtype)
    elif mixer == SSM:
        fn = ssm_mod.abstract_ssm_cache if abstract else ssm_mod.init_ssm_cache
        c["ssm"] = fn(cfg, batch, dtype)
    if cfg.enc_layers:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (batch, enc_len, kv, hd)
        if abstract:
            s = jax.ShapeDtypeStruct(shape, dtype)
            c["cross"] = {"ck": s, "cv": s}
        else:
            c["cross"] = {"ck": jnp.zeros(shape, dtype),
                          "cv": jnp.zeros(shape, dtype)}
    return c


def _stack_cache(leaves: list):
    """list of per-block cache pytrees -> stacked pytree (leading axis)."""
    return jax.tree.map(lambda *xs: (
        jax.ShapeDtypeStruct((len(xs),) + xs[0].shape, xs[0].dtype)
        if isinstance(xs[0], jax.ShapeDtypeStruct)
        else jnp.stack(xs)), *leaves)


def make_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               abstract: bool = False, enc_len: int = 0,
               dtype: Optional[jnp.dtype] = None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    prefix_n, scan_n = cfg.scan_layers()
    period = cfg.layer_period()
    cache = {}
    if prefix_n:
        cache["prefix"] = {
            f"layer{i}": _layer_cache(cfg, i, batch, max_len, dtype, abstract,
                                      enc_len)
            for i in range(prefix_n)}
    if scan_n:
        n_blocks = scan_n // period
        block = {f"sub{j}": _layer_cache(cfg, prefix_n + j, batch, max_len,
                                         dtype, abstract, enc_len)
                 for j in range(period)}
        cache["blocks"] = _stack_cache([block] * n_blocks)
    return cache


def _layer_cache_axes(cfg: ModelConfig, i: int) -> dict:
    """Logical axes mirroring _layer_cache (for dry-run input shardings)."""
    mixer = cfg.mixer_at(i)
    c = {}
    if mixer == ATTN:
        kv = ("cache_batch", "cache_seq", "kv_heads", None)
        c["kv"] = {"k": kv, "v": kv}
    elif mixer == MLA:
        c["kv"] = {"ckv": ("cache_batch", "cache_seq", None),
                   "krope": ("cache_batch", "cache_seq", None)}
    elif mixer == SSM:
        c["ssm"] = {"conv": ("cache_batch", None, "ssm_inner"),
                    "h": ("cache_batch", "ssm_heads", None, None)}
    if cfg.enc_layers:
        kv = ("cache_batch", None, "kv_heads", None)
        c["cross"] = {"ck": kv, "cv": kv}
    return c


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical-axis tree matching make_cache's structure."""
    prefix_n, scan_n = cfg.scan_layers()
    period = cfg.layer_period()
    axes = {}
    if prefix_n:
        axes["prefix"] = {f"layer{i}": _layer_cache_axes(cfg, i)
                          for i in range(prefix_n)}
    if scan_n:
        block = {f"sub{j}": _layer_cache_axes(cfg, prefix_n + j)
                 for j in range(period)}
        axes["blocks"] = jax.tree.map(
            lambda t: ("layers",) + t, block,
            is_leaf=lambda l: isinstance(l, tuple) and all(
                a is None or isinstance(a, str) for a in l))
    return axes


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, batch):
    """Run the prompt; returns (cache_at_prompt_len, last_token_logits).

    The returned KV caches have sequence length == prompt length; the serving
    driver pads them to the serving window before calling decode_step.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S)
    enc_out = None
    if cfg.enc_layers:
        enc_in = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
        enc_out = tfm.encoder(cfg, params["encoder"], enc_in,
                              positions=jnp.arange(enc_in.shape[1]),
                              mode="prefill")
    x = _embed(cfg, params, tokens)
    x, cache, _ = tfm.decoder(cfg, params["decoder"], x, positions=positions,
                              mode="prefill", cache=None, pos=None,
                              enc_out=enc_out)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], _head_weight(cfg, params))
    logits = shard_constraint(logits, "batch", "vocab")
    return cache, logits[:, :cfg.vocab_size].astype(jnp.float32)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B,1) int32; pos: () int32 current length."""
    positions = pos + jnp.arange(1)
    x = _embed(cfg, params, tokens)
    x, new_cache, _ = tfm.decoder(cfg, params["decoder"], x,
                                  positions=positions, mode="decode",
                                  cache=cache, pos=pos, enc_out=None)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, _head_weight(cfg, params))
    logits = shard_constraint(logits, "batch", None, "vocab")
    return logits[:, 0, :cfg.vocab_size].astype(jnp.float32), new_cache


def pad_cache(cfg: ModelConfig, cache, prompt_len: int, max_len: int):
    """Grow prefill KV caches (seq dim == prompt_len) to the serving window.

    Only self-attention KV leaves (under a ``kv`` key) are padded; SSM states,
    conv windows, and cross-attention KV keep their shapes.  Leaves under
    ``blocks`` carry a leading stacked-layers axis, shifting the seq axis by 1.
    """
    if max_len == prompt_len:
        return cache

    def _pad_leaf(path, x):
        names = [str(getattr(p, "key", "")) for p in path]
        if "kv" not in names:
            return x
        axis = 2 if "blocks" in names else 1
        if x.shape[axis] != prompt_len:
            return x
        pad_width = [(0, 0)] * x.ndim
        pad_width[axis] = (0, max_len - prompt_len)
        return jnp.pad(x, pad_width)

    return jax.tree_util.tree_map_with_path(_pad_leaf, cache)


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.enc_layers:
            spec["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.enc_layers:
            spec["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        return spec
    assert shape.kind == "decode"
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": make_cache(cfg, B, S, abstract=True,
                            enc_len=S if cfg.enc_layers else 0),
    }


__all__ = [
    "loss_fn", "forward_hidden", "prefill", "decode_step", "make_cache",
    "input_specs", "init_params", "abstract_params", "logical_axes",
    "param_specs", "param_count", "pad_cache",
]
