"""Mixture-of-experts layer.

Two implementations sharing one router:

- ``dense``: every expert computes every token, combined by top-k weights.
  Exact (no token dropping), simple, used as the correctness oracle and on
  tiny smoke configs.  FLOPs = num_experts/top_k x the active compute.
- ``gather`` (default at scale): capacity-bounded dropless-ish dispatch via
  sort + gather into an (E, C, D) buffer, grouped einsum per expert, and
  scatter-add combine.  FLOPs ~ active compute x capacity_factor.  Pure
  GSPMD-friendly ops (sort/gather/einsum/scatter) — the expert axis shards
  over 'model' (expert parallelism), and XLA inserts the token exchange
  collectives.  §Perf compares an explicit shard_map all-to-all variant.

Expert weights are stacked (E, D, F); the layer is fully differentiable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FF_SWIGLU, ModelConfig
from repro.models.layers import apply_ffn
from repro.sharding import shard_constraint

_IMPL = {"impl": "gather"}  # module switch: "gather" | "dense"


def set_moe_impl(impl: str):
    assert impl in ("gather", "dense")
    _IMPL["impl"] = impl


def router_probs(p: dict, x) -> jax.Array:
    """x: (B,S,D) -> fp32 probs (B,S,E)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs, expert_ids, num_experts: int) -> jax.Array:
    """Switch-transformer aux loss: E * sum_e f_e * P_e (fp32 scalar).

    Counts via per-row bincount — a (B*S*k, E) one-hot would cost 4 GB on
    deepseek train_4k (see EXPERIMENTS.md §Perf)."""
    pe = jnp.mean(probs.reshape(-1, num_experts), axis=0)
    B = expert_ids.shape[0]
    ids2 = expert_ids.reshape(B, -1)
    counts = jax.vmap(
        lambda e: jnp.bincount(e, length=num_experts))(ids2)
    counts = jnp.sum(counts.astype(jnp.float32), axis=0)
    fe = counts / jnp.maximum(counts.sum(), 1.0)
    return num_experts * jnp.sum(fe * pe)


def _expert_ffn_batched(xg, p, ff_kind: str):
    """xg: (B, E, C, D) grouped tokens -> (B, E, C, D)."""
    if ff_kind == FF_SWIGLU:
        g = jnp.einsum("becd,edf->becf", xg, p["w_gate"])
        u = jnp.einsum("becd,edf->becf", xg, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    else:
        u = jnp.einsum("becd,edf->becf", xg, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(xg.dtype)
    h = shard_constraint(h, "batch", "experts", "expert_cap", "expert_ffn")
    return jnp.einsum("becf,efd->becd", h, p["w_down"])


def _moe_dense(cfg: ModelConfig, p: dict, x, probs, weights, ids):
    """All-experts path: (B,S,E) combine weights, exact."""
    m = cfg.moe
    B, S, D = x.shape
    k = m.experts_per_token
    comb = jnp.sum(jax.nn.one_hot(ids, m.num_experts, dtype=jnp.float32)
                   * weights[..., None].astype(jnp.float32), axis=2)  # (B,S,E)
    if m.ff_kind == FF_SWIGLU:
        g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
        u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    return jnp.einsum("bsed,bse->bsd", y, comb.astype(x.dtype))


def _moe_gather(cfg: ModelConfig, p: dict, x, probs, weights, ids):
    """Capacity-bounded dispatch, *per sequence* (GShard-style groups).

    Routing/sort/scatter happen independently per batch row, so under GSPMD
    the whole dispatch shards over ('data' on batch, 'model' on experts) with
    no global collectives — the only cross-shard traffic is the token
    exchange implied by the gather (batch-sharded x -> expert-sharded xg),
    which XLA lowers to the all-to-all-like pattern expert parallelism needs.
    Per-sequence capacity C = ceil(S * k * capacity_factor / E).
    """
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.experts_per_token
    N = S * k

    exp_ids = ids.reshape(B, N).astype(jnp.int32)                 # (B, N)
    w_flat = weights.reshape(B, N)
    tok_ids = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)[None, :]  # (1, N)
    tok_ids = jnp.broadcast_to(tok_ids, (B, N))

    # per-row stable sort by expert; position-within-expert via group starts
    order = jnp.argsort(exp_ids, axis=-1, stable=True)            # (B, N)
    exp_sorted = jnp.take_along_axis(exp_ids, order, axis=-1)
    onehot_counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(exp_ids)
    starts = jnp.cumsum(onehot_counts, axis=-1) - onehot_counts   # (B, E)
    pos_sorted = jnp.arange(N, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(starts, exp_sorted, axis=-1).astype(jnp.int32)
    # un-sort the positions back to assignment order
    pos = jnp.zeros((B, N), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(pos_sorted)

    cap = int(max(4, -(-N * m.capacity_factor // E)))             # ceil
    cap = min(cap, S)
    valid = pos < cap
    scatter_pos = jnp.where(valid, pos, cap)                      # cap = OOB
    bidx = jnp.arange(B)[:, None]

    # expert-parallel padding: when E doesn't divide the 'experts' mesh axes
    # (granite: 40 experts on a 16-way axis), pad the dispatch AND the expert
    # weights to the next multiple so the (B,E,C,D) tensors shard.  Padded
    # experts hold only sentinel slots and zero weights. (§Perf: the
    # unsharded dispatch cost 4 GB/buffer on granite train_4k.)
    from repro.sharding import rule_axis_size
    ep = rule_axis_size("experts")
    E_pad = -(-E // ep) * ep if ep > 1 else E
    p_eff = p
    if E_pad != E:
        padw = ((0, E_pad - E), (0, 0), (0, 0))
        p_eff = dict(p)
        for kname in ("w_gate", "w_up", "w_down"):
            if kname in p:
                p_eff[kname] = jnp.pad(p[kname], padw)

    idx = jnp.full((B, E_pad, cap), S, jnp.int32)                 # S = sentinel
    idx = idx.at[bidx, exp_ids, scatter_pos].set(tok_ids, mode="drop")
    wtab = jnp.zeros((B, E_pad, cap), w_flat.dtype)
    wtab = wtab.at[bidx, exp_ids, scatter_pos].set(w_flat, mode="drop")
    idx = shard_constraint(idx, "batch", "experts", "expert_cap")
    wtab = shard_constraint(wtab, "batch", "experts", "expert_cap")

    # gather via clamp+mask — a sentinel row (concatenate to S+1) makes the
    # seq dim indivisible and GSPMD replicates the FULL global batch in f32
    # (21.5 GB/device/buffer on deepseek train_4k — EXPERIMENTS.md §Perf)
    idx_flat = idx.reshape(B, E_pad * cap)
    occupied = idx_flat < S                                       # (B, E*C)
    idx_safe = jnp.minimum(idx_flat, S - 1)
    xg = jnp.take_along_axis(x, idx_safe[:, :, None], axis=1)
    xg = jnp.where(occupied[:, :, None], xg, 0).reshape(B, E_pad, cap, D)
    xg = shard_constraint(xg, "batch", "experts", "expert_cap", "embed")
    yg = _expert_ffn_batched(xg, p_eff, m.ff_kind)                # (B,E,C,D)
    yg = yg * wtab[..., None].astype(yg.dtype)
    # scatter-add combine; masked entries contribute zeros at row S-1
    yg_flat = jnp.where(occupied[:, :, None],
                        yg.reshape(B, E_pad * cap, D), 0)
    y = jnp.zeros((B, S, D), x.dtype).at[bidx, idx_safe, :].add(yg_flat)
    return y


def moe_forward(cfg: ModelConfig, p: dict, x) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss). x: (B,S,D)."""
    m = cfg.moe
    probs = router_probs(p, x)                                        # fp32
    weights, ids = jax.lax.top_k(probs, m.experts_per_token)          # (B,S,k)
    weights = (weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)
    aux = load_balance_loss(probs, ids, m.num_experts) * m.router_aux_weight

    impl = _IMPL["impl"]
    if impl == "dense":
        y = _moe_dense(cfg, p, x, probs, weights, ids)
    else:
        y = _moe_gather(cfg, p, x, probs, weights, ids)

    if m.num_shared_experts:
        y = y + apply_ffn(p["shared"], x, m.ff_kind)
    return y, aux
