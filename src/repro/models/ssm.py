"""Mamba-2 SSD (state-space duality) block.

The SSD recurrence per head (state N, head dim P):
    h_t = a_t * h_{t-1} + dt_t * (B_t outer x_t)     h in R^{P x N}
    y_t = h_t @ C_t + D * x_t                        a_t = exp(A * dt_t), A < 0

Training/prefill uses the *chunked* algorithm: quadratic attention-like
computation inside chunks of Q tokens (MXU-friendly) plus a cheap inter-chunk
state recurrence — this is the TPU-native adaptation of the paper's GPU scan
(DESIGN.md §2).  ``repro.kernels.ssd_scan`` is the Pallas version of the
chunked core; this module is the jnp path (identical math) used on CPU and by
the dry-run.

Decode keeps (conv window, h state) per layer in the cache pytree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import gated_rmsnorm
from repro.sharding import shard_constraint


def _dims(cfg: ModelConfig):
    ss = cfg.ssm
    d_inner = ss.expand * cfg.d_model
    nh = ss.num_heads or d_inner // ss.head_dim
    gn = ss.num_groups * ss.d_state
    conv_dim = d_inner + 2 * gn
    return ss, d_inner, nh, gn, conv_dim


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    ss, d_inner, nh, gn, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, ss.conv_width - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, nh, ss.head_dim, ss.d_state), jnp.float32),
    }


def abstract_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    ss, d_inner, nh, gn, conv_dim = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, ss.conv_width - 1, conv_dim), dtype),
        "h": jax.ShapeDtypeStruct((batch, nh, ss.head_dim, ss.d_state), jnp.float32),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv. u: (B,L,C); w: (W,C); b: (C,)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    y = jnp.zeros_like(u)
    for i in range(W):  # W == 4: unrolled shifts beat conv_general on TPU
        y = y + pad[:, i:i + u.shape[1], :] * w[i]
    return y + b


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int):
    """Chunked SSD (jnp path; same math as kernels/ssd_scan.py).

    x: (B,L,H,P)  dt: (B,L,H) post-softplus  a_log: (H,)
    b, c: (B,L,G,N) with G dividing H.  Returns y: (B,L,H,P).

    Chunks are processed by a sequential ``lax.scan`` carrying the (B,H,P,N)
    state — only ONE chunk's quadratic (B,Q,Q,H) tensors are ever live
    (materializing all chunks at once costs O(L*Q) memory: 34 TB global on
    mamba2 train_4k — see EXPERIMENTS.md §Perf).  The chunk body is
    checkpointed so the backward pass recomputes those tensors per chunk.
    """
    from repro.models.transformer import _SCAN  # unroll flag (cost lowers)

    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    A = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) negative
    f32 = jnp.float32
    # (nc, B, Q, ...) scan layout
    xc = x.astype(f32).reshape(B, nc, Q, H, P).swapaxes(0, 1)
    dtc = dt.astype(f32).reshape(B, nc, Q, H).swapaxes(0, 1)
    bc = b.astype(f32).reshape(B, nc, Q, G, N).swapaxes(0, 1)
    cc = c.astype(f32).reshape(B, nc, Q, G, N).swapaxes(0, 1)
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h_prev, inp):
        xq, dtq, bq, cq = inp           # (B,Q,H,P) (B,Q,H) (B,Q,G,N) (B,Q,G,N)
        # group->head expansion erases sharding; re-constrain onto heads
        bh = shard_constraint(jnp.repeat(bq, rep, axis=2),
                              "batch", None, "ssm_heads", None)
        ch = shard_constraint(jnp.repeat(cq, rep, axis=2),
                              "batch", None, "ssm_heads", None)
        cum = jnp.cumsum(dtq * A, axis=1)                    # (B,Q,H)
        # intra-chunk quadratic term
        diff = cum[:, :, None, :] - cum[:, None, :, :]       # (B,Q,Q,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        decay = shard_constraint(decay, "batch", None, None, "ssm_heads")
        cb = jnp.einsum("bqhs,bkhs->bqkh", ch, bh)
        scores = cb * decay * dtq[:, None, :, :]             # (B,Q,K,H)
        y = jnp.einsum("bqkh,bkhp->bqhp", scores, xq)
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bqh,bqhs,bhps->bqhp", jnp.exp(cum), ch, h_prev)
        # state update
        tail = jnp.exp(cum[:, -1:, :] - cum)                 # (B,Q,H)
        sstate = jnp.einsum("bqh,bqhs,bqhp->bhps", tail * dtq, bh, xq)
        h = h_prev * jnp.exp(cum[:, -1, :])[..., None, None] + sstate
        return h, y

    body = jax.checkpoint(body)
    h0 = jnp.zeros((B, H, P, N), f32)
    _, ys = jax.lax.scan(body, h0, (xc, dtc, bc, cc),
                         unroll=nc if _SCAN["unroll"] else 1)
    y = ys.swapaxes(0, 1).reshape(B, L, H, P)
    return y.astype(x.dtype)


def ssd_final_state(x, dt, a_log, b, *, chunk: int):
    """Final h state after processing the sequence (for prefill -> decode)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    A = -jnp.exp(a_log.astype(jnp.float32))
    dt = dt.astype(jnp.float32)
    dA = (dt * A)
    cum = jnp.cumsum(dA, axis=1)                             # (B,L,H)
    tail = jnp.exp(cum[:, -1:, :] - cum)                     # (B,L,H)
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    h = jnp.einsum("blh,blhn,blhp->bhpn", tail * dt, bh, x.astype(jnp.float32))
    return h                                                  # (B,H,P,N)


def ssm_forward(cfg: ModelConfig, p: dict, xin, *, mode: str,
                cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba-2 block. xin: (B,L,D). Returns (y, new_cache)."""
    ss, d_inner, nh, gn, conv_dim = _dims(cfg)
    B, L, D = xin.shape
    zxbcdt = jnp.einsum("bld,de->ble", xin, p["in_proj"])
    zxbcdt = shard_constraint(zxbcdt, "batch", None, "ssm_inner")
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim:]

    if mode == "decode":
        assert cache is not None and L == 1
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,conv)
        new_conv = window[:, 1:, :]
        w = p["conv_w"]
        xbc_c = jnp.einsum("bwc,wc->bc", window, w)[:, None, :] + p["conv_b"]
    else:
        new_conv = None
        xbc_c = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        if mode == "prefill":
            pad = jnp.pad(xbc, ((0, 0), (ss.conv_width - 1, 0), (0, 0)))
            new_conv = pad[:, L:L + ss.conv_width - 1, :]  # last W-1 inputs
    xbc_c = jax.nn.silu(xbc_c.astype(jnp.float32)).astype(xin.dtype)

    xs = xbc_c[..., :d_inner].reshape(B, L, nh, ss.head_dim)
    b = xbc_c[..., d_inner:d_inner + gn].reshape(B, L, ss.num_groups, ss.d_state)
    c = xbc_c[..., d_inner + gn:].reshape(B, L, ss.num_groups, ss.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))    # (B,L,H)

    if mode == "decode":
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        a_t = jnp.exp(dt[:, 0] * A)                           # (B,H)
        rep = nh // ss.num_groups
        bh = jnp.repeat(b[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
        ch = jnp.repeat(c[:, 0], rep, axis=1).astype(jnp.float32)
        xf = xs[:, 0].astype(jnp.float32)                     # (B,H,P)
        h = cache["h"] * a_t[..., None, None] + \
            (dt[:, 0, :, None] * xf)[..., None] * bh[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ch)[:, None]       # (B,1,H,P)
        new_cache = {"conv": new_conv, "h": h}
    else:
        from repro.kernels import ops as kops
        if kops.pallas_enabled():
            y = kops.ssd(xs, dt, p["a_log"], b, c, chunk=ss.chunk)
        else:
            y = ssd_chunked(xs, dt, p["a_log"], b, c, chunk=ss.chunk)
        if mode == "prefill":
            h = ssd_final_state(xs, dt, p["a_log"], b, chunk=ss.chunk)
            new_cache = {"conv": new_conv, "h": h}
        else:
            new_cache = None

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None].astype(y.dtype) \
        * xs.astype(y.dtype)
    y = y.reshape(B, L, d_inner).astype(xin.dtype)
    y = gated_rmsnorm(y, z, p["out_norm"], cfg.norm_eps)
    y = shard_constraint(y, "batch", None, "ssm_inner")
    return jnp.einsum("ble,ed->bld", y, p["out_proj"]), new_cache
