"""Decoder/encoder stacks.

Homogeneous runs of layers execute under ``jax.lax.scan`` over stacked params
(period-k blocks for hybrids like Jamba), keeping HLO size and compile time
bounded at 60-layer/512-device scale.  Training remats each scanned block.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, FF_MOE, FF_NONE, MLA, SSM, ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import rmsnorm
from repro.sharding import shard_constraint

_REMAT = {"policy": "full"}   # none | full | dots  (§Perf knob)
_MLA_ABSORB = {"decode": True, "prefill": False, "train": False}
_SCAN = {"unroll": False}     # True: unroll layer scan (cost-composition lowers)


def set_remat(policy: str):
    assert policy in ("none", "full", "dots")
    _REMAT["policy"] = policy


def set_scan_unroll(unroll: bool):
    _SCAN["unroll"] = unroll


def set_mla_absorb(mode: str, value: bool):
    _MLA_ABSORB[mode] = value


def _maybe_remat(fn, mode: str):
    if mode != "train" or _REMAT["policy"] == "none":
        return fn
    if _REMAT["policy"] == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, p: dict, x, layer_idx: int, *, positions,
                mode: str, cache: Optional[dict], pos, enc_out):
    """Returns (x, new_cache, aux_loss)."""
    mixer = cfg.mixer_at(layer_idx)
    ff = cfg.ff_at(layer_idx)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    c_in = cache or {}

    h = rmsnorm(x, p["mixer_norm"], cfg.norm_eps)
    if mixer == ATTN:
        y, kvc = attn_mod.attn_forward(
            cfg, p["mixer"], h, positions=positions, mode=mode,
            cache=c_in.get("kv"), pos=pos, causal=True)
        new_cache["kv"] = kvc
    elif mixer == MLA:
        y, kvc = attn_mod.mla_forward(
            cfg, p["mixer"], h, positions=positions, mode=mode,
            cache=c_in.get("kv"), pos=pos, absorb=_MLA_ABSORB[mode])
        new_cache["kv"] = kvc
    elif mixer == SSM:
        y, sc = ssm_mod.ssm_forward(cfg, p["mixer"], h, mode=mode,
                                    cache=c_in.get("ssm"))
        new_cache["ssm"] = sc
    else:
        raise ValueError(mixer)
    x = x + y
    x = shard_constraint(x, "batch", "seq", "embed")

    if "cross" in p:
        h = rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        if mode == "decode":
            ck = c_in["cross"]
            kv = (ck["ck"], ck["cv"])
            new_cache["cross"] = ck
        else:
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
            kv = (k, v)
            if mode == "prefill":
                new_cache["cross"] = {"ck": k, "cv": v}
        y, _ = attn_mod.attn_forward(
            cfg, p["cross"], h, positions=positions, mode=mode,
            kv_override=kv, causal=False)
        x = x + y
        x = shard_constraint(x, "batch", "seq", "embed")

    if ff != FF_NONE:
        h = rmsnorm(x, p["ff_norm"], cfg.norm_eps)
        if ff == FF_MOE:
            y, aux = moe_mod.moe_forward(cfg, p["ff"], h)
        else:
            from repro.models.layers import apply_ffn
            y = apply_ffn(p["ff"], h, ff)
        x = x + y
        x = shard_constraint(x, "batch", "seq", "embed")

    new_cache = {k: v for k, v in new_cache.items() if v is not None}
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Decoder stack (prefix loop + scanned blocks)
# ---------------------------------------------------------------------------

def decoder(cfg: ModelConfig, dparams: dict, x, *, positions, mode: str,
            cache: Optional[dict], pos, enc_out=None):
    prefix_n, scan_n = cfg.scan_layers()
    period = cfg.layer_period()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {}

    if prefix_n:
        new_cache["prefix"] = {}
        for i in range(prefix_n):
            name = f"layer{i}"
            c = cache["prefix"][name] if cache else None
            x, nc, aux = apply_layer(cfg, dparams["prefix"][name], x, i,
                                     positions=positions, mode=mode,
                                     cache=c, pos=pos, enc_out=enc_out)
            aux_total = aux_total + aux
            if nc is not None:
                new_cache["prefix"][name] = nc
        if not new_cache["prefix"]:
            del new_cache["prefix"]

    if scan_n:
        # hybrids (period > 1) remat each SUB-layer: rematting the whole
        # 8-layer Jamba block keeps all 8 layers' intermediates live during
        # its backward (150 GB/chip before this — EXPERIMENTS.md §Perf)
        def sub_fn(x, lp, c, j):
            return apply_layer(cfg, lp, x, prefix_n + j, positions=positions,
                               mode=mode, cache=c, pos=pos, enc_out=enc_out)

        if period > 1:
            # close over the static sub-layer index (it selects layer kind)
            sub_fns = [_maybe_remat(
                (lambda j: lambda x, lp, c: sub_fn(x, lp, c, j))(j), mode)
                for j in range(period)]
        else:
            sub_fns = [lambda x, lp, c: sub_fn(x, lp, c, 0)]

        def block_fn(x, block_params, block_cache):
            block_new_cache = {}
            aux_b = jnp.zeros((), jnp.float32)
            for j in range(period):
                name = f"sub{j}"
                c = block_cache[name] if block_cache else None
                x, nc, aux = sub_fns[j](x, block_params[name], c)
                aux_b = aux_b + aux
                if nc is not None:
                    block_new_cache[name] = nc
            return x, (block_new_cache or None), aux_b

        if period == 1:
            block_fn = _maybe_remat(block_fn, mode)

        def scan_body(carry, xs):
            x, aux_acc = carry
            bp, bc = xs
            x, bnc, aux_b = block_fn(x, bp, bc)
            return (x, aux_acc + aux_b), bnc

        bc0 = cache["blocks"] if cache else None
        unroll = (scan_n // period) if _SCAN["unroll"] else 1
        if bc0 is None:
            (x, aux_total), blocks_cache = jax.lax.scan(
                lambda c, bp: scan_body(c, (bp, None)),
                (x, aux_total), dparams["blocks"], unroll=unroll)
        else:
            (x, aux_total), blocks_cache = jax.lax.scan(
                scan_body, (x, aux_total), (dparams["blocks"], bc0),
                unroll=unroll)
        if blocks_cache is not None:
            new_cache["blocks"] = blocks_cache

    return x, (new_cache or None), aux_total


# ---------------------------------------------------------------------------
# Encoder stack (bidirectional, scanned)
# ---------------------------------------------------------------------------

def encoder(cfg: ModelConfig, eparams: dict, x, *, positions, mode: str):
    def layer_fn(x, lp):
        h = rmsnorm(x, lp["mixer_norm"], cfg.norm_eps)
        y, _ = attn_mod.attn_forward(cfg, lp["mixer"], h, positions=positions,
                                     mode="train", causal=False)
        x = x + y
        h = rmsnorm(x, lp["ff_norm"], cfg.norm_eps)
        from repro.models.layers import apply_ffn
        x = x + apply_ffn(lp["ff"], h, cfg.ff_kind)
        return shard_constraint(x, "batch", "seq", "embed")

    layer_fn = _maybe_remat(layer_fn, mode)
    n = jax.tree.leaves(eparams["blocks"])[0].shape[0]
    x, _ = jax.lax.scan(lambda c, lp: (layer_fn(c, lp), None),
                        x, eparams["blocks"],
                        unroll=n if _SCAN["unroll"] else 1)
    return rmsnorm(x, eparams["final_norm"], cfg.norm_eps)
