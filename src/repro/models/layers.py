"""Shared layer primitives (pure functions, fp32-stable where it matters)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FF_GELU, FF_RELU2, FF_SWIGLU
from repro.sharding import shard_constraint


def rmsnorm(x, weight, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def gated_rmsnorm(x, gate, weight, eps: float):
    """Mamba-2 output norm: rmsnorm(x * silu(gate))."""
    return rmsnorm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype),
                   weight, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast over the heads axis: (..., S, 1, hd/2)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFNs
# ---------------------------------------------------------------------------

def apply_ffn(p: dict, x, kind: str):
    if kind == FF_SWIGLU:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif kind == FF_GELU:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    elif kind == FF_RELU2:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jnp.square(jax.nn.relu(u))
    else:
        raise ValueError(kind)
    h = shard_constraint(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_softmax_xent(hidden, w_head, labels, *, chunk: int = 1024,
                         valid_vocab: int = 0):
    """Cross-entropy over a large vocab without materializing (B,S,V).

    hidden: (B,S,D); w_head: (D,Vp); labels: (B,S) int32, -1 = masked.
    Scans over sequence chunks with a rematerialized body, so only ONE
    chunk's logits are ever live (fwd AND bwd).  ``valid_vocab`` masks
    padded vocab columns (w_head may be padded for shardability).
    Returns (total_loss_sum, total_weight).
    """
    B, S, D = hidden.shape
    Vp = w_head.shape[-1]
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    hid = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)      # (n,B,c,D)
    lab = labels.reshape(B, n, chunk).swapaxes(0, 1)         # (n,B,c)

    @jax.checkpoint
    def body(carry, xs):
        h, l = xs
        logits = jnp.einsum("bcd,dv->bcv", h, w_head).astype(jnp.float32)
        logits = shard_constraint(logits, "batch", None, "vocab")
        if valid_vocab and valid_vocab < Vp:
            pad_mask = jnp.arange(Vp) < valid_vocab
            logits = jnp.where(pad_mask[None, None, :], logits,
                               jnp.finfo(jnp.float32).min)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - tgt) * mask)
        return (carry[0] + loss, carry[1] + jnp.sum(mask)), None

    (loss_sum, weight), _ = jax.lax.scan(body, (0.0, 0.0), (hid, lab))
    return loss_sum, weight
