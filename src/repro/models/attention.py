"""Attention mixers: GQA softmax attention and DeepSeek-V2 MLA.

All entry points are pure functions of (config, params, activations, cache).
KV caches are plain pytrees so they checkpoint/reshard like parameters
(the elastic runtime treats them identically).

Decode assumes a uniform position across the batch (scalar ``pos``), matching
the serving driver's synchronous batched decode loop.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.sharding import can_shard, shard_constraint


def _use_flash(cfg: ModelConfig, mode: str) -> bool:
    from repro.kernels import ops as kops
    return kops.pallas_enabled() and mode in ("train", "prefill")


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    s = jax.ShapeDtypeStruct((batch, max_len, kv, hd), dtype)
    return {"k": s, "v": s}


def _grouped_attention(q, k, v, *, causal: bool, q_pos0, scale: float,
                       kv_len: Optional[jax.Array] = None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd). GQA without materializing repeated KV.

    q_pos0: absolute position of q[0] (for causal masking against the cache).
    kv_len: if set, keys at index >= kv_len are masked (decode: cache tail).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    Sk = k.shape[1]
    tpos = jnp.arange(Sk)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        spos = q_pos0 + jnp.arange(Sq)
        mask = spos[:, None] >= tpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, neg)
    if kv_len is not None:
        scores = jnp.where((tpos < kv_len)[None, None, None, None], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def attn_forward(cfg: ModelConfig, p: dict, x, *, positions, mode: str,
                 cache: Optional[dict] = None, pos=None,
                 kv_override=None, causal: bool = True):
    """Returns (out, new_cache).

    kv_override: (k, v) already projected — used for cross-attention where the
    encoder-side KV is computed once at prefill.
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    # head-parallel attention only when KV heads divide the model axis;
    # otherwise leave activations on the residual (sequence-parallel) layout
    # and let GSPMD propagate (blocked attention regroups H -> (KV, G), so a
    # head-sharding that KV cannot carry would replicate the score tiles).
    head_par = can_shard(KV, "kv_heads") and mode != "decode"
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if head_par:
        q = shard_constraint(q, "batch", None, "heads", None)

    if kv_override is not None:
        k, v = kv_override
        new_cache = cache
        q = apply_rope(q, positions, cfg.rope_theta) if causal else q
        kv_len = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if head_par:
            k = shard_constraint(k, "batch", None, "kv_heads", None)
            v = shard_constraint(v, "batch", None, "kv_heads", None)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if mode == "decode":
            assert cache is not None and pos is not None
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            kv_len = pos + S
        else:
            if mode == "prefill":
                new_cache = {"k": k, "v": v}   # caller pads/places into cache
            else:
                new_cache = None
            kv_len = None

    scale = hd ** -0.5
    if kv_override is None and _use_flash(cfg, mode) and causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, scale=scale)
    elif mode == "decode":
        out = _grouped_attention(q, k, v, causal=causal, q_pos0=pos,
                                 scale=scale, kv_len=kv_len)
    else:
        # blocked flash-style path: O(block) memory instead of O(S^2)
        from repro.kernels.blocked import blocked_attention
        out = blocked_attention(q, k, v, causal, scale)
    if head_par:
        out = shard_constraint(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    a = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, a.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype),
    }


def abstract_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    a = cfg.mla
    return {
        "ckv": jax.ShapeDtypeStruct((batch, max_len, a.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, max_len, a.qk_rope_head_dim), dtype),
    }


def mla_forward(cfg: ModelConfig, p: dict, x, *, positions, mode: str,
                cache: Optional[dict] = None, pos=None,
                absorb: bool = False):
    """Multi-head latent attention. The cache stores only the compressed
    per-token latent (kv_lora_rank + rope_dim floats) — MLA's memory win.

    absorb=True uses the W_UK-absorption decode path (beyond-paper §Perf
    optimization): scores are computed directly against the latent cache
    without expanding per-head keys/values.
    """
    a = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    nope, rope_d, vd = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim

    # --- queries ---
    if a.q_lora_rank:
        cq = rmsnorm(jnp.einsum("bsd,dl->bsl", x, p["wq_a"]), p["q_norm"],
                     cfg.norm_eps)
        q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = shard_constraint(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- latent kv ---
    ckv_kr = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    ckv, krope = ckv_kr[..., :a.kv_lora_rank], ckv_kr[..., a.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    # shared (single-head) rope key
    krope = apply_rope(krope[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]

    if mode == "decode":
        assert cache is not None and pos is not None
        ckv_c = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope, pos, axis=1)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        ckv_all, krope_all = ckv_c, kr_c
        kv_len = pos + S
        q_pos0 = pos
    else:
        new_cache = {"ckv": ckv, "krope": krope} if mode == "prefill" else None
        ckv_all, krope_all = ckv, krope
        kv_len = None
        q_pos0 = 0

    scale = (nope + rope_d) ** -0.5
    Sk = ckv_all.shape[1]
    tpos = jnp.arange(Sk)
    neg = jnp.finfo(jnp.float32).min
    w_uk = p["wkv_b"][..., :nope]          # (lora, H, nope)
    w_uv = p["wkv_b"][..., nope:]          # (lora, H, vd)

    if mode != "decode":
        # train/prefill: expand per-head K/V (linear in S) and run the
        # blocked flash path — never materializes (S,S) scores.
        from repro.kernels.blocked import blocked_attention
        k_nope = jnp.einsum("btl,lhn->bthn", ckv_all, w_uk)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                      (*k_nope.shape[:3], rope_d))], axis=-1)
        k_full = shard_constraint(k_full, "batch", None, "heads", None)
        v_full = jnp.einsum("btl,lhv->bthv", ckv_all, w_uv)
        v_full = shard_constraint(v_full, "batch", None, "heads", None)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = blocked_attention(q_full, k_full, v_full, True, scale)
        out = shard_constraint(out, "batch", None, "heads", None)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
        return y, new_cache

    if absorb:
        # fold W_UK into the query; score directly against the latent cache
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)
        scores = (jnp.einsum("bshl,btl->bhst", q_lat, ckv_all) +
                  jnp.einsum("bshr,btr->bhst", q_rope, krope_all))
    else:
        k_nope = jnp.einsum("btl,lhn->bthn", ckv_all, w_uk)
        scores = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope) +
                  jnp.einsum("bshr,btr->bhst", q_rope, krope_all))
    scores = scores.astype(jnp.float32) * scale
    if mode != "decode" or True:  # causal always (decode masks cache tail too)
        spos = q_pos0 + jnp.arange(S)
        mask = spos[:, None] >= tpos[None, :]
        scores = jnp.where(mask[None, None], scores, neg)
    if kv_len is not None:
        scores = jnp.where((tpos < kv_len)[None, None, None], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    if absorb:
        ctx_lat = jnp.einsum("bhst,btl->bshl", probs, ckv_all)
        out = jnp.einsum("bshl,lhv->bshv", ctx_lat, w_uv)
    else:
        vfull = jnp.einsum("btl,lhv->bthv", ckv_all, w_uv)
        out = jnp.einsum("bhst,bthv->bshv", probs, vfull)
    out = shard_constraint(out, "batch", None, "heads", None)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache
