from repro.models import model
from repro.models.model import (abstract_params, decode_step, forward_hidden,
                                init_params, input_specs, logical_axes,
                                loss_fn, make_cache, pad_cache, param_count,
                                prefill)

__all__ = ["model", "loss_fn", "forward_hidden", "prefill", "decode_step",
           "make_cache", "pad_cache", "input_specs", "init_params",
           "abstract_params", "logical_axes", "param_count"]
