"""Batched serving driver: prefill a batch of prompts, then decode N tokens
synchronously (greedy).  Works on any --arch (use --smoke on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.models import (decode_step, init_params, pad_cache, prefill)

    cfg = (smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).with_(dtype="float32")
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S0 = args.batch, args.prompt_len
    max_len = S0 + args.gen
    prompts = jax.random.randint(key, (B, S0), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.enc_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, S0, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    cache, logits = prefill(cfg, params, batch)
    cache = pad_cache(cfg, cache, S0, max_len)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{S0}: {t_prefill:.3f}s "
          f"({B * S0 / t_prefill:.0f} tok/s)")

    dstep = jax.jit(lambda c, t, p: decode_step(cfg, params, c, t, p))
    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.perf_counter()
    for t in range(S0, max_len - 1):
        logits, cache = dstep(cache, toks, jnp.int32(t))
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_dec = time.perf_counter() - t0
    n = len(out) - 1
    print(f"[serve] decoded {n} steps x {B} seqs: {t_dec:.3f}s "
          f"({B * n / max(t_dec, 1e-9):.0f} tok/s)")
    gen = jnp.concatenate(out, axis=1)
    print("[serve] sample generations (token ids):")
    for b in range(min(B, 4)):
        print("  ", gen[b, :12].tolist())


if __name__ == "__main__":
    main()
