import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs.  (The XLA_FLAGS line above MUST
precede any jax import — jax locks the device count at first init.)

Per cell:
  1. full-model lower+compile on the requested mesh (layer stacks as rolled
     ``lax.scan``): proves the sharding config is coherent and yields
     ``compiled.memory_analysis()`` (per-device bytes: fits / doesn't fit).
  2. collective schedule: parsed from the compiled (post-SPMD) HLO
     (utils/hlo.py).  Collectives inside while bodies are counted once by the
     text parse, so ops in loop-like computations are multiplied by the layer
     trip count (the layer scan is the dominant loop; nested scans hold no
     collectives by construction — mixer-internal tensors are resharded
     OUTSIDE the inner scans).
  3. FLOPs / HBM traffic: analytic models (utils/flops.py).  XLA's
     cost_analysis counts while bodies ONCE regardless of trips (verified —
     a 10-step scanned matmul reports the flops of one), so compiled counts
     cannot cost scan-structured models; the compiled aggregate is still
     recorded as ``xla_cost`` for reference.

Results accumulate in a JSON file (default results/dryrun.json), resumable
via --skip-existing; EXPERIMENTS.md tables are generated from it.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-also] [--skip-existing]
"""
import argparse
import json
import time
import traceback


def _cell_key(arch: str, shape: str, mesh_name: str, rules: str = "") -> str:
    return f"{arch}|{shape}|{mesh_name}" + (f"|{rules}" if rules else "")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules_name=None, rule_overrides=None) -> dict:
    import jax
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch.cells import make_cell, train_rules_name, \
        decode_rules_name
    from repro.launch.mesh import chips_in, make_production_mesh
    from repro.utils.flops import cell_flops, cell_hbm_bytes
    from repro.utils.hlo import collective_bytes
    from repro.utils.roofline import (normalize_cost_analysis,
                                      roofline_from_analysis)

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    chips = chips_in(mesh)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    eff_rules = rules_name or (train_rules_name(arch) if shape.kind == "train"
                               else decode_rules_name(arch, shape))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": chips, "rules": eff_rules, "status": "ok"}

    t0 = time.time()
    cell = make_cell(arch, shape_name, mesh, rules_name=rules_name,
                     rule_overrides=rule_overrides)
    lowered = cell.lower()
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
    }
    rec["fits_hbm"] = rec["memory"]["peak_bytes"] <= 16e9
    ca = normalize_cost_analysis(compiled.cost_analysis())
    rec["xla_cost"] = {"flops": ca.get("flops", 0.0),
                       "bytes": ca.get("bytes accessed", 0.0)}

    # collective schedule: per-device bytes; loop-like computations x layers
    n_blocks = cell.scan_trips["while"]
    hlo = compiled.as_text()
    rec["collectives_once"] = collective_bytes(hlo)
    rec["collectives"] = collective_bytes(
        hlo, body_multipliers={"while": n_blocks, "body": n_blocks,
                               "region": 1})
    del compiled, lowered

    flops_global = cell_flops(cell.cfg, shape)
    hbm_global = cell_hbm_bytes(cell.cfg, shape)
    terms = roofline_from_analysis(
        {"flops": flops_global / chips, "bytes accessed": hbm_global / chips},
        rec["collectives"].get("total", 0.0),
        cell.model_flops, chips)
    rec["model_flops"] = cell.model_flops
    rec["analytic"] = {"flops_global": flops_global,
                       "hbm_bytes_global": hbm_global}
    rec["roofline"] = terms.as_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--rules", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-also", action="store_true",
                    help="run each cell on both meshes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    import jax
    jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)

    from repro.launch.cells import all_cells

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    if args.all:
        targets = [(a, s) for a, s, ok, _ in all_cells() if ok]
    else:
        targets = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.multi_pod_also else [False, True]

    for a, s, ok, why in all_cells():
        if not ok:
            results[_cell_key(a, s, "skipped")] = {
                "arch": a, "shape": s, "status": "skipped", "reason": why}

    for arch, shape in targets:
        for mp in meshes:
            mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
            key = _cell_key(arch, shape, mesh_name, args.rules or "")
            if args.skip_existing and results.get(key, {}).get("status") == "ok":
                print(f"[skip] {key}", flush=True)
                continue
            print(f"[run ] {key}", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               rules_name=args.rules)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {key}: {e!r}", flush=True)
            rec["wall_s"] = round(time.time() - t0, 1)
            results[key] = rec
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, sort_keys=True)
            if rec.get("status") == "ok":
                mem = rec.get("memory", {})
                rl = rec.get("roofline", {})
                print(f"   ok mem={mem.get('peak_bytes', 0)/1e9:.2f}GB/chip "
                      f"fits={rec.get('fits_hbm')} "
                      f"bottleneck={rl.get('bottleneck', '?')} "
                      f"useful={rl.get('useful_flops_fraction', 0):.2f} "
                      f"mfu_bound={rl.get('mfu_bound', 0):.3f} "
                      f"({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
