"""Cell definitions: (architecture x input shape) -> lowerable step functions
with shardings, plus per-arch sharding-rule selection and MODEL_FLOPS.

This module is the single source of truth used by the dry-run, the roofline
benchmarks, and the §Perf hillclimbing (which swaps `rules` / knobs here).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, shape_applicable
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, \
    count_active_params, count_params
from repro.models import model as M
from repro.optim import AdamWConfig, abstract_opt_state, adamw_update, \
    opt_logical_axes, warmup_cosine
from repro.sharding import AxisRules, RULE_SETS, axis_rules, \
    make_param_shardings

# ---------------------------------------------------------------------------
# Per-arch sharding rules (baseline; §Perf iterates these)
# ---------------------------------------------------------------------------

# FSDP for archs whose optimizer state cannot replicate over 'data'
_FSDP_ARCHS = {"deepseek-v2-236b", "jamba-v0.1-52b", "chameleon-34b",
               "yi-9b"}
# sequence parallelism applies to all archs: mixer-internal constraints force
# seq gathered / features sharded (Megatron-style SP boundaries)
_NO_SP_ARCHS = set()

# per-arch logical->mesh overrides applied on top of the rule set
ARCH_OVERRIDES: Dict[str, Dict[str, object]] = {
    # granite's 40 experts pad to 48 inside the MoE dispatch (moe.py) and
    # shard over 'model' like every other MoE arch
    # >30B params cannot replicate over 'data' even when serving: keep the
    # FSDP embed sharding in decode/prefill rules too
    "deepseek-v2-236b": {"embed": ("pod", "data")},
    "jamba-v0.1-52b": {"embed": ("pod", "data")},
    "chameleon-34b": {"embed": ("pod", "data")},
}


def train_rules_name(arch: str) -> str:
    fsdp = arch in _FSDP_ARCHS
    sp = arch not in _NO_SP_ARCHS
    return {
        (False, False): "tp",
        (False, True): "tp_sp",
        (True, False): "tp_fsdp",
        (True, True): "tp_fsdp_sp",
    }[(fsdp, sp)]


def decode_rules_name(arch: str, shape: ShapeConfig) -> str:
    return "decode_long" if shape.name == "long_500k" else "decode"


def make_rules(arch: str, mesh: Mesh, name: str,
               extra_overrides: Optional[dict] = None) -> AxisRules:
    rules = RULE_SETS[name]()
    rules.update(ARCH_OVERRIDES.get(arch, {}))
    rules.update(extra_overrides or {})
    return AxisRules(mesh=mesh, rules=rules)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, adamw: AdamWConfig = AdamWConfig(),
                     total_steps: int = 10_000) -> Callable:
    def train_step(params, opt_state, batch, step):
        def lf(p):
            return M.loss_fn(cfg, p, batch)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        lr = warmup_cosine(step, peak_lr=3e-4, warmup_steps=500,
                           total_steps=total_steps)
        params, opt_state, om = adamw_update(adamw, grads, opt_state, params,
                                             lr)
        return params, opt_state, dict(metrics, **om)
    return train_step


def build_prefill(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)
    return prefill_step


def build_decode_step(cfg: ModelConfig) -> Callable:
    def decode(params, cache, tokens, pos):
        logits, new_cache = M.decode_step(cfg, params, cache, tokens, pos)
        return logits, new_cache
    return decode


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------

@dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    shape: ShapeConfig
    rules: AxisRules
    fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    donate: Tuple[int, ...]
    model_flops: float          # MODEL_FLOPS for one step of this cell
    scan_trips: Dict[str, int]  # while-body name fragment -> trip count

    def lower(self):
        with axis_rules(self.rules):
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.abstract_args)


def _model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    # 6*N_active*D (train) / 2*N_active*D (inference); for enc-dec, D counts
    # decoder tokens only (each token passes through ~half the params, so
    # counting both sides with N_total would overstate MODEL_FLOPS).
    n_active = count_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch      # one token per sequence


def _batch_sharding(rules: AxisRules, spec_tree):
    def sh(s):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(rules.mesh, rules.spec_for(axes, tuple(s.shape)))
    return jax.tree.map(sh, spec_tree)


def make_cell(arch: str, shape_name: str, mesh: Mesh, *,
              rules_name: Optional[str] = None,
              rule_overrides: Optional[dict] = None,
              cfg_override: Optional[ModelConfig] = None) -> Cell:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {why}")

    prefix_n, scan_n = cfg.scan_layers()
    period = cfg.layer_period()
    trips = {"while": max(1, scan_n // period)}

    if shape.kind == "train":
        rname = rules_name or train_rules_name(arch)
        rules = make_rules(arch, mesh, rname, rule_overrides)
        axes = M.logical_axes(cfg)
        abstract_p = M.abstract_params(cfg)
        abstract_o = abstract_opt_state(abstract_p)
        p_sh = make_param_shardings(rules, axes, abstract_p)
        o_sh = make_param_shardings(rules, opt_logical_axes(axes), abstract_o)
        batch_spec = M.input_specs(cfg, shape)
        b_sh = _batch_sharding(rules, batch_spec)
        scalar_sh = NamedSharding(mesh, P())
        fn = build_train_step(cfg)
        return Cell(arch, cfg, shape, rules, fn,
                    (abstract_p, abstract_o, batch_spec,
                     jax.ShapeDtypeStruct((), jnp.int32)),
                    (p_sh, o_sh, b_sh, scalar_sh), (0, 1),
                    _model_flops(cfg, shape), trips)

    rname = rules_name or decode_rules_name(arch, shape)
    rules = make_rules(arch, mesh, rname, rule_overrides)
    axes = M.logical_axes(cfg)
    abstract_p = M.abstract_params(cfg)
    p_sh = make_param_shardings(rules, axes, abstract_p)

    if shape.kind == "prefill":
        batch_spec = M.input_specs(cfg, shape)
        b_sh = _batch_sharding(rules, batch_spec)
        fn = build_prefill(cfg)
        return Cell(arch, cfg, shape, rules, fn,
                    (abstract_p, batch_spec), (p_sh, b_sh), (),
                    _model_flops(cfg, shape), trips)

    # decode
    spec = M.input_specs(cfg, shape)
    c_axes = M.cache_axes(cfg)
    c_sh = make_param_shardings(rules, c_axes, spec["cache"])
    tok_sh = NamedSharding(
        mesh, rules.spec_for(("batch", None), tuple(spec["tokens"].shape)))
    scalar_sh = NamedSharding(mesh, P())
    fn = build_decode_step(cfg)
    return Cell(arch, cfg, shape, rules, fn,
                (abstract_p, spec["cache"], spec["tokens"], spec["pos"]),
                (p_sh, c_sh, tok_sh, scalar_sh), (1,),
                _model_flops(cfg, shape), trips)


def all_cells() -> list:
    """All runnable (arch x shape) pairs with skip annotations."""
    out = []
    from repro.configs import ALL_ARCHS
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for sname in SHAPES:
            ok, why = shape_applicable(cfg, SHAPES[sname])
            out.append((arch, sname, ok, why))
    return out
