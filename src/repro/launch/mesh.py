"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device state
(the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); multi-pod extends data parallelism
    across 2 pods (512 chips) via the leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def chips_in(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
