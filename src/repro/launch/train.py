"""End-to-end training driver.

Runs a real training job for any ``--arch`` on the local device(s), with the
elastic runtime underneath: the job can be rescaled on the fly (via
``--rescale-at step:replicas``), checkpoints to disk for fault tolerance, and
resumes with ``--restart``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --global-batch 8 --seq-len 64
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --smoke \
      --steps 20 --rescale-at 10:2
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--devices", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--virtual-devices", type=int, default=0,
                    help="force N virtual host devices (set before jax init)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--rescale-at", action="append", default=[],
                    help="step:new_replica_count (repeatable)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--restart", action="store_true",
                    help="resume from the latest disk checkpoint")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.virtual_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.virtual_devices}")

    import jax
    from repro.checkpoint import DiskCheckpointStore
    from repro.configs import get_config, smoke_config
    from repro.core.elastic import ElasticTrainer, TrainJobConfig

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    devices = jax.devices()
    if args.devices:
        devices = devices[:args.devices]

    job = TrainJobConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                         total_steps=args.steps, seed=args.seed,
                         peak_lr=args.lr, dtype=args.dtype)
    trainer = ElasticTrainer(cfg, job, devices)
    print(f"[train] arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(trainer.params)):,} "
          f"replicas={trainer.replicas} startup={trainer.startup_time:.2f}s")

    store = None
    if args.checkpoint_dir:
        store = DiskCheckpointStore(args.checkpoint_dir)
        if args.restart:
            try:
                step = trainer.restore_disk(store, cfg.name)
                print(f"[train] restarted from disk checkpoint at step {step}")
            except FileNotFoundError:
                print("[train] no checkpoint found; starting fresh")

    rescales = {}
    for spec in args.rescale_at:
        s, r = spec.split(":")
        rescales[int(s)] = int(r)

    while not trainer.done:
        if trainer.step_idx in rescales:
            new_r = rescales[trainer.step_idx]
            t = trainer.rescale(devices[:new_r])
            print(f"[train] rescale -> {new_r} replicas: "
                  + " ".join(f"{k}={v:.3f}s" for k, v in t.as_dict().items()))
        m = trainer.step()
        if trainer.step_idx % args.log_every == 0 or trainer.done:
            print(f"[train] step {m['step']:5d} loss={m['loss']:.4f} "
                  f"grad_norm={m['grad_norm']:.3f} replicas={m['replicas']}")
        if store and args.checkpoint_every and \
                trainer.step_idx % args.checkpoint_every == 0:
            dt = trainer.save_disk(store, cfg.name)
            print(f"[train] disk checkpoint @ step {trainer.step_idx} "
                  f"({dt:.2f}s)")

    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
