"""Cloud provider simulation: node pools, pricing, boot/teardown latency, and
spot-market preemption.

The provider owns *node lifecycle* only; it never touches the scheduler.  It
communicates with the simulator exclusively by pushing events into the shared
:class:`~repro.core.events.EventQueue`:

    request_node()  --boot_latency-->   "node_up"       (capacity attaches)
    release_node()  --teardown_delay--> "node_down"     (billing stops)
    spot fate drawn at request time --> "spot_kill"     (capacity yanked NOW)
    Poisson process per zone        --> "zone_reclaim"  (correlated burst)

Topology: every pool lives in a ``region``/``zone`` (zone names are globally
unique, AWS-style ``us-east-1a``).  Regions price capacity differently —
``region_price_multipliers`` scales each pool's ``price_per_slot_hour`` at
registration — and checkpoint data crossing a region boundary on restore is
billed at ``transfer_price_per_gb`` (see CostAccountant).

Spot reclaims happen at two scales, layered:

- *independent*: each spot node keeps its private Exp(mean) lifetime fate,
  drawn at request time (the background churn of one market);
- *correlated*: when ``zone_reclaim_interval`` is set, each zone hosting
  spot capacity carries a memoryless Poisson event stream; every event
  reclaims ``zone_reclaim_fraction`` of that zone's UP spot nodes AT ONCE
  (the capacity crunch real clouds exhibit — cf. Kub, arXiv:2410.10655).
  On-demand nodes and other zones are bystanders by construction.

Billing semantics (documented in README §Cloud): a node is billed from the
moment it comes UP until it goes DOWN (normal teardown or spot kill).  Boot
time is not billed — the cloud charges for running instances, but the
*scheduler* still feels the boot latency as provisioning lag.  A DRAINING
node (released, awaiting teardown) no longer offers capacity but still bills,
which is exactly the wasted-teardown money a real cluster pays.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.events import EventQueue

ON_DEMAND = "on_demand"
SPOT = "spot"


class NodeState(Enum):
    PROVISIONING = "provisioning"   # requested, booting
    UP = "up"                       # offering capacity, billing
    DRAINING = "draining"           # released: no capacity, still billing
    DOWN = "down"                   # gone, billing stopped


@dataclass(frozen=True)
class NodePool:
    """One instance type / market / zone combination (e.g. c5.2xlarge
    on-demand in us-east-1a)."""
    name: str
    slots_per_node: int = 8
    price_per_slot_hour: float = 0.048     # $/slot-hour (~c5.2xlarge / 8 vCPU)
    market: str = ON_DEMAND
    boot_latency: float = 120.0            # request -> capacity available (s)
    teardown_delay: float = 30.0           # release -> billing stops (s)
    max_nodes: int = 64
    initial_nodes: int = 0                 # provisioned (UP) at t=0, free
    # spot only: mean node lifetime before the market reclaims it; the fate
    # is drawn once per node from Exp(mean) at request time (memoryless)
    spot_lifetime_mean: float = 3600.0
    # topology: zone names are globally unique (AWS-style "us-east-1a"), so
    # the zone alone identifies a correlated-reclaim blast domain
    region: str = "default"
    zone: str = "default-a"

    def __post_init__(self):
        assert self.market in (ON_DEMAND, SPOT), self.market
        assert self.slots_per_node >= 1
        assert self.price_per_slot_hour >= 0.0

    @property
    def price_per_node_hour(self) -> float:
        return self.price_per_slot_hour * self.slots_per_node


@dataclass
class Node:
    node_id: str
    pool: NodePool
    state: NodeState = NodeState.PROVISIONING
    requested_at: float = 0.0
    up_at: Optional[float] = None
    billing_ends_at: Optional[float] = None
    kill_at: Optional[float] = None        # spot reclaim fate; None = safe

    @property
    def slots(self) -> int:
        return self.pool.slots_per_node

    def billed_hours(self, now: float) -> float:
        if self.up_at is None:
            return 0.0
        end = self.billing_ends_at if self.billing_ends_at is not None else now
        return max(0.0, end - self.up_at) / 3600.0


class CloudProvider:
    """Node pools + lifecycle.  All state transitions are driven by the
    simulator popping the events this class pushes."""

    def __init__(self, pools: Iterable[NodePool], seed: int = 0, *,
                 region_price_multipliers: Optional[Dict[str, float]] = None,
                 zone_reclaim_interval: Optional[
                     float | Dict[str, float]] = None,
                 zone_reclaim_fraction: float = 0.5,
                 transfer_price_per_gb: float = 0.02):
        # fold the region multiplier into each pool's price at registration
        # so every downstream consumer (billing, autoscaler preference,
        # budget commitment) sees the regionally-adjusted rate for free
        mult = region_price_multipliers or {}
        self.pools: Dict[str, NodePool] = {
            p.name: dataclasses.replace(
                p, price_per_slot_hour=(p.price_per_slot_hour
                                        * mult.get(p.region, 1.0)))
            for p in pools}
        self.nodes: Dict[str, Node] = {}
        self._ids = itertools.count()
        self.rng = np.random.default_rng(seed)
        #: mean seconds between correlated reclaim events PER ZONE hosting
        #: spot capacity (None disables the process); each event reclaims
        #: ceil(fraction * UP spot nodes) of that zone at once.  A dict maps
        #: zone -> interval so markets can differ per blast domain (zones
        #: absent from the dict carry no stream) — the one-hot and skewed
        #: reclaim regimes the demand-aware bidder is judged against
        self.zone_reclaim_interval = zone_reclaim_interval
        self.zone_reclaim_fraction = zone_reclaim_fraction
        assert 0.0 < zone_reclaim_fraction <= 1.0, zone_reclaim_fraction
        #: $/GB billed when a checkpoint is restored in a different REGION
        #: than it was written in (intra-region restores are free)
        self.transfer_price_per_gb = transfer_price_per_gb
        # when the Poisson stream fires next, per zone: an injected
        # (deterministic) reclaim event landing BEFORE it must not re-arm,
        # or the zone ends up with two live streams at double the rate
        self._next_fire: Dict[str, float] = {}

    def region_of(self, node_id: str) -> str:
        return self.nodes[node_id].pool.region

    def zone_of(self, node_id: str) -> str:
        return self.nodes[node_id].pool.zone

    # -- queries -------------------------------------------------------------
    def nodes_in(self, *states: NodeState) -> List[Node]:
        return [n for n in self.nodes.values() if n.state in states]

    def up_nodes(self) -> List[Node]:
        return self.nodes_in(NodeState.UP)

    def pending_slots(self) -> int:
        """Slots already requested but still booting."""
        return sum(n.slots for n in self.nodes_in(NodeState.PROVISIONING))

    def pool_census(self, pool_name: str) -> int:
        """Nodes of a pool that exist or are coming (counts vs. max_nodes)."""
        return sum(1 for n in self.nodes.values()
                   if n.pool.name == pool_name and n.state in (
                       NodeState.PROVISIONING, NodeState.UP,
                       NodeState.DRAINING))

    def theoretical_max_slots(self) -> int:
        """Ceiling on total capacity with every pool at max_nodes — a job
        whose min_replicas exceeds this can never run here."""
        return sum(p.max_nodes * p.slots_per_node for p in self.pools.values())

    def market_slots(self, market: str) -> int:
        return sum(n.slots for n in self.nodes.values()
                   if n.pool.market == market and n.state in (
                       NodeState.PROVISIONING, NodeState.UP))

    def spot_zones(self) -> List[str]:
        """Zones hosting spot pools — the correlated-reclaim blast domains."""
        return sorted({p.zone for p in self.pools.values()
                       if p.market == SPOT})

    def zone_slots(self, zone: str, market: Optional[str] = None) -> int:
        """Provisioned (booting + UP) slots in a zone, optionally by market
        — the autoscaler's per-zone spot-share denominator/numerator."""
        return sum(n.slots for n in self.nodes.values()
                   if n.pool.zone == zone
                   and (market is None or n.pool.market == market)
                   and n.state in (NodeState.PROVISIONING, NodeState.UP))

    # -- lifecycle -----------------------------------------------------------
    def bootstrap(self, queue: EventQueue) -> List[Node]:
        """Instantiate each pool's ``initial_nodes`` as already UP at t=0
        (the cluster you start the experiment with)."""
        out = []
        for pool in self.pools.values():
            for _ in range(pool.initial_nodes):
                node = self._new_node(pool, now=0.0, boots=False)
                node.state = NodeState.UP
                node.up_at = 0.0
                if node.kill_at is not None:
                    queue.push(node.kill_at, "spot_kill", node.node_id)
                out.append(node)
        return out

    def request_node(self, pool_name: str, now: float,
                     queue: EventQueue) -> Optional[Node]:
        """Ask for one node; returns None when the pool is at max_nodes.
        Capacity arrives via the "node_up" event after boot_latency."""
        pool = self.pools[pool_name]
        if self.pool_census(pool_name) >= pool.max_nodes:
            return None
        node = self._new_node(pool, now)
        queue.push(now + pool.boot_latency, "node_up", node.node_id)
        if node.kill_at is not None:
            queue.push(node.kill_at, "spot_kill", node.node_id)
        return node

    def release_node(self, node_id: str, now: float,
                     queue: EventQueue) -> Node:
        """Voluntary decommission.  The caller removes the capacity from the
        cluster NOW; billing continues through teardown_delay."""
        node = self.nodes[node_id]
        assert node.state == NodeState.UP, (node_id, node.state)
        node.state = NodeState.DRAINING
        queue.push(now + node.pool.teardown_delay, "node_down", node.node_id)
        return node

    def on_node_up(self, node_id: str, now: float) -> Optional[Node]:
        node = self.nodes[node_id]
        if node.state is not NodeState.PROVISIONING:
            return None                    # stale (already killed)
        node.state = NodeState.UP
        node.up_at = now
        return node

    def on_node_down(self, node_id: str, now: float) -> Optional[Node]:
        node = self.nodes[node_id]
        if node.state is not NodeState.DRAINING:
            return None                    # stale (spot-killed while draining)
        node.state = NodeState.DOWN
        node.billing_ends_at = now
        return node

    def on_spot_kill(self, node_id: str, now: float
                     ) -> Tuple[Optional[Node], bool]:
        """Returns (node, was_offering_capacity).  Stale kills (node already
        DOWN, or still booting) return (None, False) / end billing quietly."""
        node = self.nodes[node_id]
        if node.state is NodeState.PROVISIONING:
            # killed before it ever booted: it never billed, never served
            node.state = NodeState.DOWN
            node.billing_ends_at = None
            return None, False
        if node.state is NodeState.DOWN:
            return None, False
        was_up = node.state is NodeState.UP
        node.state = NodeState.DOWN
        node.billing_ends_at = now
        return node, was_up

    def inject_spot_kill(self, node_id: str, t: float,
                         queue: EventQueue) -> None:
        """Deterministic kill for tests/demos (bypasses the Exp(mean) draw)."""
        self.nodes[node_id].kill_at = t
        queue.push(t, "spot_kill", node_id)

    # -- correlated zone reclaims --------------------------------------------
    def reclaim_interval_of(self, zone: str) -> Optional[float]:
        """The zone's correlated-reclaim mean interval (None = no stream)."""
        zi = self.zone_reclaim_interval
        if isinstance(zi, dict):
            return zi.get(zone)
        return zi

    def schedule_zone_reclaims(self, queue: EventQueue) -> None:
        """Arm each spot zone's Poisson reclaim stream (first arrival per
        zone).  No-op unless ``zone_reclaim_interval`` is configured; with a
        per-zone dict, only the listed zones carry a stream."""
        if self.zone_reclaim_interval is None:
            return
        for zone in self.spot_zones():
            if self.reclaim_interval_of(zone) is not None:
                self._push_next_zone_reclaim(zone, 0.0, queue)

    def _push_next_zone_reclaim(self, zone: str, now: float,
                                queue: EventQueue) -> None:
        t = now + float(self.rng.exponential(self.reclaim_interval_of(zone)))
        self._next_fire[zone] = t
        queue.push(t, "zone_reclaim", zone)

    def on_zone_reclaim(self, zone: str, now: float,
                        queue: EventQueue) -> List[str]:
        """One correlated reclaim event: pick ceil(fraction x UP spot nodes)
        victims in the zone and re-arm the stream (memoryless).  Returns the
        victim node ids; the caller replays each through the node-exact
        spot-kill path, so on-demand nodes and other zones are bystanders by
        construction."""
        up = sorted(n.node_id for n in self.nodes.values()
                    if n.state is NodeState.UP and n.pool.market == SPOT
                    and n.pool.zone == zone)
        victims: List[str] = []
        if up:
            k = math.ceil(self.zone_reclaim_fraction * len(up))
            picked = self.rng.choice(len(up), size=k, replace=False)
            victims = [up[i] for i in sorted(picked)]
        # re-arm only when THIS event is the armed stream's own firing — an
        # injected event (arriving ahead of the pending stream event, or on
        # a zone that was never armed at all) must not start a new stream
        if (self.reclaim_interval_of(zone) is not None
                and zone in self._next_fire
                and now >= self._next_fire[zone]):
            self._push_next_zone_reclaim(zone, now, queue)
        return victims

    def inject_zone_reclaim(self, zone: str, t: float,
                            queue: EventQueue) -> None:
        """Deterministic correlated reclaim for tests/demos (the event still
        draws its victims via ``zone_reclaim_fraction``)."""
        queue.push(t, "zone_reclaim", zone)

    # -- internals -----------------------------------------------------------
    def _new_node(self, pool: NodePool, now: float,
                  boots: bool = True) -> Node:
        node = Node(node_id=f"{pool.name}-{next(self._ids)}", pool=pool,
                    requested_at=now)
        if pool.market == SPOT:
            # the Exp(mean) lifetime clock starts when the node comes UP —
            # bootstrap nodes (boots=False) are up at ``now`` already
            up_at = now + (pool.boot_latency if boots else 0.0)
            node.kill_at = up_at + float(
                self.rng.exponential(pool.spot_lifetime_mean))
        self.nodes[node.node_id] = node
        return node
