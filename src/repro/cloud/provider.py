"""Cloud provider simulation: node pools, pricing, boot/teardown latency, and
spot-market preemption.

The provider owns *node lifecycle* only; it never touches the scheduler.  It
communicates with the simulator exclusively by pushing events into the shared
:class:`~repro.core.events.EventQueue`:

    request_node()  --boot_latency-->   "node_up"     (capacity attaches)
    release_node()  --teardown_delay--> "node_down"   (billing stops)
    spot fate drawn at request time --> "spot_kill"   (capacity yanked NOW)

Billing semantics (documented in README §Cloud): a node is billed from the
moment it comes UP until it goes DOWN (normal teardown or spot kill).  Boot
time is not billed — the cloud charges for running instances, but the
*scheduler* still feels the boot latency as provisioning lag.  A DRAINING
node (released, awaiting teardown) no longer offers capacity but still bills,
which is exactly the wasted-teardown money a real cluster pays.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.events import EventQueue

ON_DEMAND = "on_demand"
SPOT = "spot"


class NodeState(Enum):
    PROVISIONING = "provisioning"   # requested, booting
    UP = "up"                       # offering capacity, billing
    DRAINING = "draining"           # released: no capacity, still billing
    DOWN = "down"                   # gone, billing stopped


@dataclass(frozen=True)
class NodePool:
    """One instance type / market combination (e.g. c5.2xlarge on-demand)."""
    name: str
    slots_per_node: int = 8
    price_per_slot_hour: float = 0.048     # $/slot-hour (~c5.2xlarge / 8 vCPU)
    market: str = ON_DEMAND
    boot_latency: float = 120.0            # request -> capacity available (s)
    teardown_delay: float = 30.0           # release -> billing stops (s)
    max_nodes: int = 64
    initial_nodes: int = 0                 # provisioned (UP) at t=0, free
    # spot only: mean node lifetime before the market reclaims it; the fate
    # is drawn once per node from Exp(mean) at request time (memoryless)
    spot_lifetime_mean: float = 3600.0

    def __post_init__(self):
        assert self.market in (ON_DEMAND, SPOT), self.market
        assert self.slots_per_node >= 1
        assert self.price_per_slot_hour >= 0.0

    @property
    def price_per_node_hour(self) -> float:
        return self.price_per_slot_hour * self.slots_per_node


@dataclass
class Node:
    node_id: str
    pool: NodePool
    state: NodeState = NodeState.PROVISIONING
    requested_at: float = 0.0
    up_at: Optional[float] = None
    billing_ends_at: Optional[float] = None
    kill_at: Optional[float] = None        # spot reclaim fate; None = safe

    @property
    def slots(self) -> int:
        return self.pool.slots_per_node

    def billed_hours(self, now: float) -> float:
        if self.up_at is None:
            return 0.0
        end = self.billing_ends_at if self.billing_ends_at is not None else now
        return max(0.0, end - self.up_at) / 3600.0


class CloudProvider:
    """Node pools + lifecycle.  All state transitions are driven by the
    simulator popping the events this class pushes."""

    def __init__(self, pools: Iterable[NodePool], seed: int = 0):
        self.pools: Dict[str, NodePool] = {p.name: p for p in pools}
        self.nodes: Dict[str, Node] = {}
        self._ids = itertools.count()
        self.rng = np.random.default_rng(seed)

    # -- queries -------------------------------------------------------------
    def nodes_in(self, *states: NodeState) -> List[Node]:
        return [n for n in self.nodes.values() if n.state in states]

    def up_nodes(self) -> List[Node]:
        return self.nodes_in(NodeState.UP)

    def pending_slots(self) -> int:
        """Slots already requested but still booting."""
        return sum(n.slots for n in self.nodes_in(NodeState.PROVISIONING))

    def pool_census(self, pool_name: str) -> int:
        """Nodes of a pool that exist or are coming (counts vs. max_nodes)."""
        return sum(1 for n in self.nodes.values()
                   if n.pool.name == pool_name and n.state in (
                       NodeState.PROVISIONING, NodeState.UP,
                       NodeState.DRAINING))

    def theoretical_max_slots(self) -> int:
        """Ceiling on total capacity with every pool at max_nodes — a job
        whose min_replicas exceeds this can never run here."""
        return sum(p.max_nodes * p.slots_per_node for p in self.pools.values())

    def market_slots(self, market: str) -> int:
        return sum(n.slots for n in self.nodes.values()
                   if n.pool.market == market and n.state in (
                       NodeState.PROVISIONING, NodeState.UP))

    # -- lifecycle -----------------------------------------------------------
    def bootstrap(self, queue: EventQueue) -> List[Node]:
        """Instantiate each pool's ``initial_nodes`` as already UP at t=0
        (the cluster you start the experiment with)."""
        out = []
        for pool in self.pools.values():
            for _ in range(pool.initial_nodes):
                node = self._new_node(pool, now=0.0, boots=False)
                node.state = NodeState.UP
                node.up_at = 0.0
                if node.kill_at is not None:
                    queue.push(node.kill_at, "spot_kill", node.node_id)
                out.append(node)
        return out

    def request_node(self, pool_name: str, now: float,
                     queue: EventQueue) -> Optional[Node]:
        """Ask for one node; returns None when the pool is at max_nodes.
        Capacity arrives via the "node_up" event after boot_latency."""
        pool = self.pools[pool_name]
        if self.pool_census(pool_name) >= pool.max_nodes:
            return None
        node = self._new_node(pool, now)
        queue.push(now + pool.boot_latency, "node_up", node.node_id)
        if node.kill_at is not None:
            queue.push(node.kill_at, "spot_kill", node.node_id)
        return node

    def release_node(self, node_id: str, now: float,
                     queue: EventQueue) -> Node:
        """Voluntary decommission.  The caller removes the capacity from the
        cluster NOW; billing continues through teardown_delay."""
        node = self.nodes[node_id]
        assert node.state == NodeState.UP, (node_id, node.state)
        node.state = NodeState.DRAINING
        queue.push(now + node.pool.teardown_delay, "node_down", node.node_id)
        return node

    def on_node_up(self, node_id: str, now: float) -> Optional[Node]:
        node = self.nodes[node_id]
        if node.state is not NodeState.PROVISIONING:
            return None                    # stale (already killed)
        node.state = NodeState.UP
        node.up_at = now
        return node

    def on_node_down(self, node_id: str, now: float) -> Optional[Node]:
        node = self.nodes[node_id]
        if node.state is not NodeState.DRAINING:
            return None                    # stale (spot-killed while draining)
        node.state = NodeState.DOWN
        node.billing_ends_at = now
        return node

    def on_spot_kill(self, node_id: str, now: float
                     ) -> Tuple[Optional[Node], bool]:
        """Returns (node, was_offering_capacity).  Stale kills (node already
        DOWN, or still booting) return (None, False) / end billing quietly."""
        node = self.nodes[node_id]
        if node.state is NodeState.PROVISIONING:
            # killed before it ever booted: it never billed, never served
            node.state = NodeState.DOWN
            node.billing_ends_at = None
            return None, False
        if node.state is NodeState.DOWN:
            return None, False
        was_up = node.state is NodeState.UP
        node.state = NodeState.DOWN
        node.billing_ends_at = now
        return node, was_up

    def inject_spot_kill(self, node_id: str, t: float,
                         queue: EventQueue) -> None:
        """Deterministic kill for tests/demos (bypasses the Exp(mean) draw)."""
        self.nodes[node_id].kill_at = t
        queue.push(t, "spot_kill", node_id)

    # -- internals -----------------------------------------------------------
    def _new_node(self, pool: NodePool, now: float,
                  boots: bool = True) -> Node:
        node = Node(node_id=f"{pool.name}-{next(self._ids)}", pool=pool,
                    requested_at=now)
        if pool.market == SPOT:
            # the Exp(mean) lifetime clock starts when the node comes UP —
            # bootstrap nodes (boots=False) are up at ``now`` already
            up_at = now + (pool.boot_latency if boots else 0.0)
            node.kill_at = up_at + float(
                self.rng.exponential(pool.spot_lifetime_mean))
        self.nodes[node.node_id] = node
        return node
