"""CLUES-style node power manager (cf. the CLUES/indigo orchestrators and
Kub, arXiv:2410.10655): watch queue pressure and idle time, provision and
decommission whole nodes with hysteresis and a budget cap.

This is *node-level* elasticity, orthogonal to the paper's *job-level*
elasticity: the scheduling policy shrinks/expands jobs inside the provisioned
capacity, while the autoscaler decides how much capacity to pay for.

Scale-up:   unmet demand = queued min_replicas + headroom - free - booting.
            Provision when positive, at most every ``scale_up_cooldown`` s,
            never past ``budget_cap`` dollars, preferring spot pools while
            their ZONE's share of provisioned slots is below its per-zone
            quota (``spot_fraction`` split evenly across spot zones, or the
            :class:`~repro.cloud.bidding.DemandAwareBidder`'s risk-adjusted
            shares when ``cfg.bidder`` is set), least-saturated zone first —
            correlated zone reclaims make spot concentration in one zone the
            expensive failure mode, so the share check that used to be
            global is counted per zone (a global check would keep
            over-provisioning the one cheapest zone until the GLOBAL share
            hit target, parking the whole spot fleet in a single blast
            domain).
Scale-down: only after the cluster has been continuously idle enough to free
            a whole node for ``idle_timeout`` s AND ``scale_down_cooldown``
            has passed since the last release (hysteresis against thrash).
            Drain-aware: the victim is the node with the FEWEST resident
            slots whose residents fit on free capacity elsewhere (ties break
            toward the most expensive node); residents are migrated off via
            :meth:`CloudSimulator.begin_drain`, retried every tick until the
            node empties (migrate-or-wait), and the drain is cancelled if
            queue pressure returns.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Optional, Set

from repro.cloud.provider import (ON_DEMAND, SPOT, CloudProvider, Node,
                                  NodePool, NodeState)

if TYPE_CHECKING:       # avoid a runtime import cycle with cloud.bidding
    from repro.cloud.bidding import DemandAwareBidder


@dataclass(frozen=True)
class AutoscalerConfig:
    tick_interval: float = 30.0         # evaluation period (s)
    scale_up_cooldown: float = 60.0
    scale_down_cooldown: float = 240.0
    idle_timeout: float = 300.0         # continuous idleness before release
    headroom_slots: int = 0             # keep this many free slots warm
    # stop provisioning when accrued spend + a COMMIT_HOURS charge for every
    # booting/new node would exceed this ($) — the commitment term is what
    # makes the cap bite during boot windows, before billing has started
    budget_cap: float = math.inf
    spot_fraction: float = 0.0          # target share of slots from spot
    max_horizon: float = 7 * 24 * 3600.0  # stop ticking past this sim time
    #: per-zone share strategy: None keeps the static even split of
    #: ``spot_fraction`` across open spot zones; a
    #: :class:`~repro.cloud.bidding.DemandAwareBidder` instead emits each
    #: zone's quota from its observed risk-cost rate vs. its spot discount
    bidder: Optional["DemandAwareBidder"] = None

    def __post_init__(self):
        assert self.tick_interval > 0.0
        assert 0.0 <= self.spot_fraction <= 1.0


#: canonical alias: the config belongs to the NodeAutoscaler
NodeAutoscalerConfig = AutoscalerConfig


class NodeAutoscaler:
    def __init__(self, provider: CloudProvider,
                 cfg: AutoscalerConfig = AutoscalerConfig()):
        self.provider = provider
        self.cfg = cfg
        self._last_up = -math.inf
        self._last_down = -math.inf
        self._idle_since: Optional[float] = None
        self._draining: Optional[str] = None     # node mid-drain (cordoned)
        self.scale_ups = 0
        self.scale_downs = 0
        # decision-audit sink (repro.obs); None records nothing
        self.decisions = None

    def _decide(self, point: str, now: float, verdict: str,
                inputs=None, alternatives=None) -> None:
        if self.decisions is not None:
            self.decisions.record(point, now, verdict, inputs=inputs,
                                  alternatives=alternatives)

    # -- main entry (called from the autoscale_tick event) -------------------
    def evaluate(self, sim, now: float) -> None:
        # the bidder re-evaluates every tick (decay moves the estimates even
        # when no scale-up runs this tick) — otherwise a zone would only be
        # reclassified at the next provisioning attempt, long after the
        # evidence crossed the band.  ALL spot zones are classified, not
        # just the growable ones: a zone parked at max_nodes still takes
        # kills, and its state must be current by the time it can grow
        # again.  (Static split: nothing to refresh.)
        if self.cfg.bidder is not None:
            zones = self.provider.spot_zones()
            if zones:
                self.cfg.bidder.zone_quotas(zones, now, self.provider,
                                            self.cfg.spot_fraction)
        cluster = sim.cluster
        queued = cluster.queued_jobs()
        pending = self.provider.pending_slots()
        # only satisfiable jobs create demand: a min_replicas beyond what the
        # pools could EVER provide must not trigger provisioning (it would
        # thrash provision/release cycles forever)
        max_slots = self.provider.theoretical_max_slots()

        def _demand() -> int:
            return (sum(j.spec.min_replicas for j in queued
                        if j.spec.min_replicas <= max_slots)
                    + self.cfg.headroom_slots
                    - max(0, cluster.free_slots) - pending)
        demand = _demand()
        if self._draining is not None:
            if self._draining not in cluster.nodes():
                self._draining = None     # spot market removed it mid-drain:
                #                           not a voluntary scale-down
            elif demand > 0:
                # pressure returned mid-drain: put the capacity back; the
                # restored free slots may satisfy the demand outright, so
                # recompute before the scale-up logic below sees it
                self._decide("scale_down", now, "drain_cancelled",
                             inputs={"node": self._draining,
                                     "demand": demand})
                sim.cancel_drain(self._draining)
                self._draining = None
                demand = _demand()
            elif sim.begin_drain(self._draining):     # migrate-or-wait
                self._decide("scale_down", now, "drain_complete",
                             inputs={"node": self._draining})
                self._draining = None
                self._last_down = now
                self.scale_downs += 1
                return
            else:
                return                                # keep waiting
        stranded = False
        if demand > 0:
            if now - self._last_up < self.cfg.scale_up_cooldown:
                self._idle_since = None
                return
            if self._provision(sim, now, demand):
                self._last_up = now
                self._idle_since = None
                return
            # demand exists but nothing could be provisioned (pools at
            # max_nodes / budget cap): the queued jobs are STRANDED — fall
            # through so capacity they can never use is still released
            # instead of billing idle until the horizon
            stranded = True

        if (queued or pending) and not stranded:
            # work is waiting on capacity already on its way: not idle
            self._idle_since = None
            return

        victim = self._removable(cluster)
        if victim is None:
            self._idle_since = None
            return
        if self._idle_since is None:
            self._idle_since = now
            return
        if (now - self._idle_since >= self.cfg.idle_timeout
                and now - self._last_down >= self.cfg.scale_down_cooldown):
            self._idle_since = None     # restart the idle clock
            drained = sim.begin_drain(victim.node_id)
            self._decide(
                "scale_down", now,
                "drained" if drained else "drain_started",
                inputs={"node": victim.node_id,
                        "residents": cluster.resident_count(victim.node_id)
                        if not drained else 0,
                        "free": cluster.free_slots})
            if drained:
                self._last_down = now
                self.scale_downs += 1
            else:
                # residents could not all migrate this tick: keep the node
                # cordoned and retry next tick (migrate-or-wait)
                self._draining = victim.node_id

    # -- scale-up ------------------------------------------------------------
    #: every held node is assumed to bill at least this many hours in total
    #: (the classic cloud billing quantum); the unbilled remainder counts
    #: against budget_cap — otherwise the cap check is loop- and tick-
    #: invariant during boot windows (billing starts at node_up) and a burst
    #: could commit spend far past the cap
    COMMIT_HOURS = 1.0

    def _provision(self, sim, now: float, demand: int) -> bool:
        committed = sum(
            max(0.0, self.COMMIT_HOURS - n.billed_hours(now))
            * n.pool.price_per_node_hour
            for n in self.provider.nodes_in(NodeState.PROVISIONING,
                                            NodeState.UP))
        attempts = [] if self.decisions is not None else None
        demand0 = demand
        provisioned = False
        while demand > 0:
            node = None
            for pool in self._pool_preference(now):
                commit = pool.price_per_node_hour * self.COMMIT_HOURS
                if (sim.accountant.spend_through(now) + committed + commit
                        > self.cfg.budget_cap):
                    if attempts is not None:
                        attempts.append({"pool": pool.name,
                                         "zone": pool.zone,
                                         "outcome": "over_budget"})
                    continue            # this pool would bust the budget
                node = self.provider.request_node(pool.name, now, sim.queue)
                if node is not None:
                    committed += commit
                    if attempts is not None:
                        attempts.append({"pool": pool.name,
                                         "zone": pool.zone,
                                         "market": pool.market,
                                         "outcome": "requested",
                                         "slots": node.slots})
                    break
                if attempts is not None:
                    attempts.append({"pool": pool.name, "zone": pool.zone,
                                     "outcome": "at_max_nodes"})
            if node is None:
                break                   # every pool at max_nodes or over cap
            demand -= node.slots
            provisioned = True
            self.scale_ups += 1
        if self.decisions is not None:
            cap = self.cfg.budget_cap
            self.decisions.record(
                "scale_up", now,
                "provisioned" if provisioned else "blocked",
                inputs={"demand": demand0, "unmet": max(0, demand),
                        "spend": sim.accountant.spend_through(now),
                        "budget_cap": None if math.isinf(cap) else cap,
                        "preference": [p.name
                                       for p in self._pool_preference(now)]},
                alternatives=attempts)
        return provisioned

    def _pool_preference(self, now: float) -> List[NodePool]:
        """Zone-aware spot preference: a spot pool comes first while its
        zone's share of ALL provisioned slots is below the zone's quota
        (static even split of ``spot_fraction``, or the bidder's
        demand-aware share), least-saturated (then cheapest) zone first, so
        provisioning diversifies across blast domains instead of draining
        the single cheapest pool.  On-demand pools follow by ascending
        $/slot-hour; quota-filled spot pools come last.  With one spot zone
        and no bidder this reduces exactly to the old global share check."""
        pools = sorted(self.provider.pools.values(),
                       key=lambda p: p.price_per_slot_hour)
        spot = [p for p in pools if p.market == SPOT]
        on_demand = [p for p in pools if p.market != SPOT]
        total = self.provider.market_slots(SPOT) + \
            self.provider.market_slots(ON_DEMAND)
        spot_share = self.provider.market_slots(SPOT) / total if total else 0.0
        open_zones = self._open_spot_zones()
        quotas = self._zone_quotas(open_zones, now)

        def zone_share(pool: NodePool) -> float:
            return (self.provider.zone_slots(pool.zone, SPOT) / total
                    if total else 0.0)
        preferred = sorted(
            (p for p in spot
             if p.zone in open_zones
             and spot_share < self.cfg.spot_fraction
             and zone_share(p) < quotas.get(p.zone, 0.0)),
            key=lambda p: (zone_share(p), p.price_per_slot_hour))
        saturated = [p for p in spot if p not in preferred]
        return preferred + on_demand + saturated

    def _open_spot_zones(self) -> Set[str]:
        """Spot zones that can still GROW: a zone whose pools all sit at
        max_nodes must not strand its slice of the configured spot share
        (the global gate keeps the redistribution from overshooting it)."""
        return {p.zone for p in self.provider.pools.values()
                if p.market == SPOT
                and self.provider.pool_census(p.name) < p.max_nodes}

    def _zone_quotas(self, open_zones: Set[str],
                     now: float) -> Dict[str, float]:
        """Per-zone spot-slot-share quotas.  Zero open zones yields zero
        quotas — a fully saturated (or cordoned) spot fleet must not
        produce a phantom even-split (the old ``max(1, len(open_zones))``
        denominator quietly treated no zones as one zone)."""
        if not open_zones:
            return {}
        if self.cfg.bidder is None:
            quota = self.cfg.spot_fraction / len(open_zones)
            return {z: quota for z in open_zones}
        return self.cfg.bidder.zone_quotas(sorted(open_zones), now,
                                           self.provider,
                                           self.cfg.spot_fraction)

    # -- scale-down ----------------------------------------------------------
    def _removable(self, cluster) -> Optional[Node]:
        """The min-residency node whose residents (if any) fit on free
        capacity elsewhere, so a drain can empty it without displacing work
        below min_replicas.  Ties break toward the most expensive node."""
        surplus = cluster.free_slots - self.cfg.headroom_slots
        candidates = []
        for n in self.provider.up_nodes():
            if n.node_id not in cluster.nodes() or \
                    cluster.is_cordoned(n.node_id):
                continue
            resident = cluster.resident_count(n.node_id)
            node_free = n.slots - resident
            # removing the node takes its own free slots with it; the
            # residents then need `resident` slots on OTHER nodes
            if surplus - node_free >= resident:
                candidates.append((resident, -n.pool.price_per_slot_hour,
                                   n.node_id, n))
        if not candidates:
            return None
        return min(candidates)[3]
