"""Demand-aware per-zone spot bidding: turn observed reclaim pain into
provisioning decisions.

The autoscaler's static ``spot_fraction`` buys the same spot mix no matter
what the market does to it.  This module closes the measure-then-adapt loop
(cf. arXiv:2602.17318 — measured-adaptive dominates static policies — and
arXiv:2603.14630 — the adaptation must live in the runtime):

- :class:`SpotRiskLedger` folds every spot kill / correlated zone reclaim
  into a per-zone exponentially-decayed estimate of the *preemption cost
  actually paid*: checkpoint write + restore time at each victim's slot
  count (priced at the accountant's blended rate), cross-region checkpoint
  ``transfer_cost`` dollars, and lost-work seconds (the outage window
  between kill and resume, in victim slot-seconds).  Undecayed audit totals
  ride along so tests can reconcile the ledger against the raw blast
  records.
- :class:`DemandAwareBidder` compares, per zone, the ledger's observed
  risk-cost rate ($/s, exponentially weighted) against the spot discount
  that zone's capacity buys ($/s saved vs. the cheapest on-demand rate).
  Zones whose risk outruns their discount are closed (their share goes to
  zero and the freed share redistributes to the surviving zones); zones
  whose risk decays back below break-even reopen.  A Schmitt-trigger
  hysteresis band keeps estimates from flapping the share: the ratio must
  cross ``1 + hysteresis`` to close and fall below ``1 - hysteresis`` to
  reopen.

The bidder plugs into :class:`~repro.cloud.node_autoscaler.AutoscalerConfig`
via the ``bidder=`` slot; with ``bidder=None`` the autoscaler keeps the
static even split (behaviorally identical to the pre-bidder code).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.cloud.provider import SPOT, CloudProvider, NodePool

LN2 = math.log(2.0)


@dataclass
class ZoneRisk:
    """Per-zone ledger state: exponentially-decayed estimates plus undecayed
    audit totals (the latter must always equal the sum of ingested records,
    whatever the interleaving — see tests/test_bidding_properties.py)."""
    last_update: float = 0.0
    # decayed estimators (half-life = ledger.half_life)
    decayed_kills: float = 0.0
    decayed_dollars: float = 0.0
    decayed_lost_s: float = 0.0
    # undecayed audit totals
    kills: int = 0                  # node kills attributed to this zone
    dollars: float = 0.0            # non-transfer preemption dollars
    transfer_dollars: float = 0.0   # cross-region checkpoint transfer
    lost_s: float = 0.0             # victim slot-seconds of lost work

    @property
    def total_dollars(self) -> float:
        return self.dollars + self.transfer_dollars


class SpotRiskLedger:
    """Fold kill/reclaim observables into per-zone decayed risk estimates.

    The decay is continuous-time exponential with the given half-life: a
    recorded dollar counts for half as much ``half_life`` seconds later.
    ``cost_rate`` converts the decayed tally into an exponentially-weighted
    $/s (a window of time-constant ``half_life / ln 2`` holds
    ``decayed_dollars`` dollars, so the rate is ``decayed * ln2 /
    half_life``)."""

    def __init__(self, half_life: float = 1800.0):
        assert half_life > 0.0, half_life
        self.half_life = half_life
        self._lambda = LN2 / half_life
        self.zones: Dict[str, ZoneRisk] = {}

    # -- ingestion -----------------------------------------------------------
    def _state(self, zone: str, now: float) -> ZoneRisk:
        s = self.zones.get(zone)
        if s is None:
            s = self.zones[zone] = ZoneRisk(last_update=now)
        else:
            self._advance(s, now)
        return s

    def _advance(self, s: ZoneRisk, now: float) -> None:
        dt = now - s.last_update
        if dt > 0.0:
            f = math.exp(-self._lambda * dt)
            s.decayed_kills *= f
            s.decayed_dollars *= f
            s.decayed_lost_s *= f
            s.last_update = now
        # out-of-order records (property tests shuffle events) fold in at
        # the current decay level instead of decaying negatively

    def record_kill(self, zone: str, now: float, *, nodes: int = 1,
                    dollars: float = 0.0, lost_seconds: float = 0.0) -> None:
        """One (or a batch of) node kill(s) in ``zone`` plus the preemption
        cost its victims paid up front (checkpoint writes at their slot
        counts, priced by the accountant)."""
        s = self._state(zone, now)
        s.decayed_kills += nodes
        s.decayed_dollars += dollars
        s.decayed_lost_s += lost_seconds
        s.kills += nodes
        s.dollars += dollars
        s.lost_s += lost_seconds

    def record_cost(self, zone: str, now: float, *, dollars: float = 0.0,
                    lost_seconds: float = 0.0,
                    transfer_dollars: float = 0.0) -> None:
        """Follow-up cost of an earlier kill (restore-from-disk at resume
        time, outage lost-work, cross-region transfer) attributed back to
        the zone that caused it.  Does not count as a new kill."""
        s = self._state(zone, now)
        s.decayed_dollars += dollars + transfer_dollars
        s.decayed_lost_s += lost_seconds
        s.dollars += dollars
        s.transfer_dollars += transfer_dollars
        s.lost_s += lost_seconds

    # -- queries -------------------------------------------------------------
    def observed(self, zone: str) -> bool:
        return self.zones.get(zone) is not None and self.zones[zone].kills > 0

    def kill_rate(self, zone: str, now: float) -> float:
        """Exponentially-weighted kills/s for the zone (0 with no history)."""
        s = self.zones.get(zone)
        if s is None:
            return 0.0
        self._advance(s, now)
        return s.decayed_kills * self._lambda

    def cost_rate(self, zone: str, now: float) -> float:
        """Exponentially-weighted preemption $/s attributed to the zone."""
        s = self.zones.get(zone)
        if s is None:
            return 0.0
        self._advance(s, now)
        return s.decayed_dollars * self._lambda

    def decayed_kills(self, zone: str, now: float) -> float:
        """Exponentially-decayed kill count — the evidence mass behind the
        zone's estimates (the bidder's ``min_evidence_kills`` gate)."""
        s = self.zones.get(zone)
        if s is None:
            return 0.0
        self._advance(s, now)
        return s.decayed_kills

    def totals(self, zone: str) -> ZoneRisk:
        return self.zones.get(zone, ZoneRisk())


@dataclass(frozen=True)
class BidderConfig:
    half_life: float = 1800.0     # ledger decay half-life (s)
    hysteresis: float = 0.25      # Schmitt band around break-even ratio 1.0
    #: assumed risk/discount ratio for zones with NO kill history — below
    #: 1 - hysteresis (the default) a fresh zone starts open at the static
    #: split; a cautious operator can set it above 1 + hysteresis to make
    #: zones earn their way in
    prior_ratio: float = 0.0
    #: per-zone ceiling on the emitted share (of total provisioned slots) —
    #: redistribution away from closed zones never concentrates more than
    #: this in one blast domain
    spot_fraction_max: float = 1.0
    #: multiplier on the observed risk-cost rate: >1 weights realized
    #: preemption pain more than raw dollars (the classic risk-aversion
    #: coefficient of the bidding literature)
    risk_aversion: float = 1.0
    #: decayed kill count below which a zone's estimates are not trusted and
    #: the prior applies — one catastrophic wipe is an anecdote, a cadence
    #: of kills is evidence (kills single-event variance in quiet markets)
    min_evidence_kills: float = 0.0

    def __post_init__(self):
        assert 0.0 <= self.hysteresis < 1.0, self.hysteresis
        assert 0.0 < self.spot_fraction_max <= 1.0, self.spot_fraction_max
        assert self.risk_aversion > 0.0
        assert self.min_evidence_kills >= 0.0


class DemandAwareBidder:
    """Per-zone spot share from observed risk vs. discount, with hysteresis.

    Each evaluation (one per ``autoscale_tick``) classifies every open spot
    zone as *open* (risk below break-even: worth its discount) or *closed*
    (risk above: the reclaims cost more than the discount saves) and splits
    the global ``spot_fraction`` evenly over the open zones, capped at
    ``spot_fraction_max`` per zone.  Every open<->closed flip counts as one
    ``adjustment`` (surfaced as ``ScheduleMetrics.bid_adjustments``)."""

    def __init__(self, cfg: BidderConfig = BidderConfig(),
                 ledger: Optional[SpotRiskLedger] = None):
        self.cfg = cfg
        self.ledger = ledger if ledger is not None \
            else SpotRiskLedger(cfg.half_life)
        self._open: Dict[str, bool] = {}
        self.adjustments = 0
        self.last_shares: Dict[str, float] = {}
        # decision-audit sink (repro.obs); None records nothing
        self.decisions = None

    # -- risk model ----------------------------------------------------------
    def _zone_spot_pools(self, zone: str,
                         provider: CloudProvider) -> List[NodePool]:
        return [p for p in provider.pools.values()
                if p.market == SPOT and p.zone == zone]

    def savings_rate(self, zone: str, provider: CloudProvider) -> float:
        """$/s the zone's spot capacity saves vs. buying the cheapest
        on-demand rate instead, over max(current zone spot slots, one
        node) — the floor keeps the comparison marginal: even an empty zone
        is judged on what its NEXT node would save."""
        pools = self._zone_spot_pools(zone, provider)
        if not pools:
            return 0.0
        cheapest = min(pools, key=lambda p: p.price_per_slot_hour)
        od = [p.price_per_slot_hour for p in provider.pools.values()
              if p.market != SPOT]
        # no on-demand reference: judge the discount against the priciest
        # pool anywhere (an all-spot fleet still prefers its safer zones)
        ref = min(od) if od else max(
            p.price_per_slot_hour for p in provider.pools.values())
        discount = ref - cheapest.price_per_slot_hour
        if discount <= 0.0:
            return 0.0
        slots = max(provider.zone_slots(zone, SPOT), cheapest.slots_per_node)
        return discount * slots / 3600.0

    def kill_cost_floor(self, zone: str, provider: CloudProvider) -> float:
        """Minimum dollars one kill is worth: the replacement boot burn
        (node-hour price x boot latency).  Every kill forces a replacement
        boot during which the fleet misses capacity it provisioned for a
        reason — so a cadence of kills carries risk even when the individual
        wipes happened to hit empty nodes (the hot-zone self-limiting case:
        nodes die before work lands on them)."""
        pools = self._zone_spot_pools(zone, provider)
        if not pools:
            return 0.0
        cheapest = min(pools, key=lambda p: p.price_per_slot_hour)
        return cheapest.price_per_node_hour * cheapest.boot_latency / 3600.0

    def risk_ratio(self, zone: str, now: float,
                   provider: CloudProvider) -> Optional[float]:
        """Observed risk-cost rate / spot-discount rate.  >1 means the
        zone's reclaims cost more than its discount saves (past its
        break-even).  Zones with NO kill history return the configured
        prior; zones whose decayed evidence has fallen below
        ``min_evidence_kills`` return None — "not enough evidence to
        reclassify", so the zone HOLDS its current state (a closed zone
        with no remaining exposure generates no new kills and must not
        snap back to the prior).  The risk-cost rate is the larger of the
        realized rate (ledger dollars) and the kill-frequency floor
        (kills/s x replacement boot burn)."""
        if not self.ledger.observed(zone):
            return self.cfg.prior_ratio
        if self.ledger.decayed_kills(zone, now) < self.cfg.min_evidence_kills:
            return None
        floor = self.ledger.kill_rate(zone, now) * \
            self.kill_cost_floor(zone, provider)
        cost = max(self.ledger.cost_rate(zone, now), floor) * \
            self.cfg.risk_aversion
        savings = self.savings_rate(zone, provider)
        if savings <= 0.0:
            return math.inf if cost > 0.0 else self.cfg.prior_ratio
        return cost / savings

    # -- share emission ------------------------------------------------------
    def zone_quotas(self, zones: List[str], now: float,
                    provider: CloudProvider,
                    spot_fraction: float) -> Dict[str, float]:
        """Per-zone spot-slot-share quotas over the given open zones.  Each
        emitted share lies in ``[0, spot_fraction_max]`` and the shares sum
        to at most ``spot_fraction`` (the global cap the autoscaler still
        enforces independently)."""
        h = self.cfg.hysteresis
        for z in zones:
            r = self.risk_ratio(z, now, provider)
            was_open = self._open.get(z, True)
            is_open = was_open
            if r is None:
                pass                    # insufficient evidence: hold state
            elif was_open and r > 1.0 + h:
                is_open = False
            elif not was_open and r < 1.0 - h:
                is_open = True
            if is_open is not was_open:
                self.adjustments += 1
                if self.decisions is not None:
                    self.decisions.record(
                        "bid_flip", now, "open" if is_open else "close",
                        inputs={
                            "zone": z,
                            "risk_ratio": (None if r is None or math.isinf(r)
                                           else r),
                            "risk_cost_rate": self.ledger.cost_rate(z, now),
                            "kill_rate": self.ledger.kill_rate(z, now),
                            "kill_cost_floor": self.kill_cost_floor(
                                z, provider),
                            "savings_rate": self.savings_rate(z, provider),
                            "evidence_kills": self.ledger.decayed_kills(
                                z, now),
                            "risk_aversion": self.cfg.risk_aversion,
                            "close_above": 1.0 + h,
                            "open_below": 1.0 - h})
            self._open[z] = is_open
        n_open = sum(1 for z in zones if self._open[z])
        if n_open == 0:
            shares = {z: 0.0 for z in zones}
        else:
            per = min(self.cfg.spot_fraction_max, spot_fraction / n_open)
            shares = {z: (per if self._open[z] else 0.0) for z in zones}
        self.last_shares = dict(shares)
        return shares

    def is_open(self, zone: str) -> bool:
        return self._open.get(zone, True)
