"""Discrete-event simulator over a :class:`CloudProvider`: dynamic capacity,
spot preemption, node autoscaling, and cost accounting.

Extends :class:`repro.core.simulator.Simulator` with five event kinds:

- ``node_up``        capacity attaches; queued jobs get a Fig.-3 offer pass
- ``node_down``      a drained node's billing stops
- ``spot_kill``      a spot node vanishes NOW; placement makes the blast set
                     exact: only the jobs RESIDENT on the killed node are
                     displaced — their workers migrate to free slots
                     elsewhere when any exist, else shrink toward
                     min_replicas (lowest priority first), else checkpoint-
                     to-disk preempt via the same ``Actions.preempt`` path
                     PreemptingPolicy uses (victims requeue and later resume
                     with progress intact)
- ``zone_reclaim``   a correlated burst: the provider picks a fraction of a
                     zone's UP spot nodes and this sim replays them as a
                     BATCH of node-exact kills — every victim node is
                     cordoned up front (one event, one blast domain), so a
                     displaced worker is never migrated onto a node dying in
                     the same burst; on-demand nodes and other zones are
                     bystanders
- ``autoscale_tick`` the NodeAutoscaler evaluates queue pressure / idleness

Region awareness rides on the preempt/resume path: a checkpoint written by a
preempted job remembers its region (the region hosting the plurality of its
slots), and a resume whose new home is in a DIFFERENT region bills the
checkpoint footprint as inter-region transfer (CostAccountant.bill_transfer).

Scale-down is drain-aware: :meth:`CloudSimulator.begin_drain` cordons a node,
migrates its residents onto free capacity elsewhere (each migrated job pays a
footprint-scaled rescale overhead), and decommissions once empty; the
autoscaler retries the drain every tick until it completes (migrate-or-wait).

Cost integration piggybacks on ``_record_util``: every allocation or capacity
boundary advances the :class:`CostAccountant` under the rates that held since
the previous boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional

from repro.cloud.cost import CostAccountant, CostReport
from repro.cloud.node_autoscaler import NodeAutoscaler
from repro.cloud.provider import SPOT, CloudProvider, NodeState
from repro.core.job import JobSpec, JobStatus
from repro.core.metrics import ScheduleMetrics
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload, _SimActions


class KillBlast(NamedTuple):
    """Per effective spot kill: what one node's reclaim displaced.  A plain
    tuple extension of the PR-2 (jobs, slots, preempts) record, so existing
    index-based consumers keep working; ``zone`` attributes the kill to its
    failure domain (correlated reclaims land many same-zone rows at one
    timestamp)."""
    jobs: int           # jobs displaced (the node's residents)
    slots: int          # slots displaced
    preempts: int       # of those jobs, how many were checkpoint-preempted
    zone: str           # failure zone of the killed node


class _CloudActions(_SimActions):
    """Region-aware actions: remember where a preempted job's checkpoint was
    written; bill inter-region transfer when it resumes elsewhere.  Every
    preempt/resume also bills its checkpoint write/restore slot-time to the
    accountant's preemption-overhead item, and resumes of KILL-caused
    preemptions feed the follow-up cost (restore, outage lost-work,
    transfer) back to the spot-risk ledger of the killing zone."""

    def preempt(self, job) -> bool:
        region = self.sim.job_region(job.job_id)    # before slots are freed
        replicas = job.replicas
        ok = super().preempt(job)
        if ok:
            # bill exactly the checkpoint the base preempt charged the clock
            dollars = self.sim.accountant.bill_preempt_overhead(
                job.job_id, self.sim.last_preempt_ckpt_s, replicas)
            if self.sim.tracer.enabled:
                self.sim.tracer.emit(
                    "cost_preempt_overhead", t=self.sim.now, job=job.job_id,
                    dollars=dollars,
                    slot_s=self.sim.last_preempt_ckpt_s * replicas,
                    phase="ckpt")
            if region is not None:
                self.sim._ckpt_region[job.job_id] = region
        return ok

    def create(self, job, replicas: int) -> bool:
        wl = self.sim.workloads[job.job_id]
        ok = super().create(job, replicas)
        if ok:
            xfer = 0.0
            src = self.sim._ckpt_region.pop(job.job_id, None)
            dst = self.sim.job_region(job.job_id) if src is not None else None
            if src is not None and dst is not None and dst != src:
                xfer = self.sim.accountant.bill_transfer(
                    job.job_id, wl.data_bytes,
                    self.sim.provider.transfer_price_per_gb)
                if self.sim.tracer.enabled:
                    self.sim.tracer.emit("cost_transfer", t=self.sim.now,
                                         job=job.job_id, dollars=xfer)
            # bill exactly the restore the base create charged the clock
            # (0 unless this create resumed a preempted job)
            restore_dollars = 0.0
            if self.sim.last_resume_s > 0.0:
                restore_dollars = self.sim.accountant.bill_preempt_overhead(
                    job.job_id, self.sim.last_resume_s, replicas)
                if self.sim.tracer.enabled:
                    self.sim.tracer.emit(
                        "cost_preempt_overhead", t=self.sim.now,
                        job=job.job_id, dollars=restore_dollars,
                        slot_s=self.sim.last_resume_s * replicas,
                        phase="restore")
            kill = self.sim._kill_zone.pop(job.job_id, None)
            if kill is not None and self.sim.risk_ledger is not None:
                zone, killed_at, killed_reps = kill
                # lost work: the outage window in victim slot-seconds (the
                # job produced nothing between kill and resume), priced at
                # the blended rate the accountant exposes
                outage = max(0.0, self.sim.now - killed_at)
                lost_s = outage * killed_reps
                self.sim.risk_ledger.record_cost(
                    zone, self.sim.now,
                    dollars=(restore_dollars + lost_s *
                             self.sim.accountant.blended_slot_rate()),
                    lost_seconds=lost_s, transfer_dollars=xfer)
        return ok


class CloudSimulator(Simulator):
    def __init__(self, provider: CloudProvider, policy_cfg: PolicyConfig,
                 *, autoscaler: Optional[NodeAutoscaler] = None,
                 policy=None, placement: str = "pack", tracer=None,
                 profiler=None):
        # all capacity comes from nodes; `placement` picks the slot->node
        # strategy (pack: low fragmentation; spread: small kill blast radius)
        super().__init__(0, policy_cfg, placement=placement, tracer=tracer,
                         profiler=profiler)
        if policy is not None:
            self.policy = policy
        self.provider = provider
        self.autoscaler = autoscaler
        self.actions = _CloudActions(self)  # region-aware preempt/resume
        self.accountant = CostAccountant()
        self.cost_report: Optional[CostReport] = None
        self.spot_victim_jobs = 0           # job preemptions caused by kills
        self.migrations = 0                 # jobs relocated off dying nodes
        self.zone_reclaims = 0              # correlated events that drew blood
        self.kill_blasts: List[KillBlast] = []
        # per correlated EVENT: union of the batch's displaced residents —
        # the per-node rows in kill_blasts understate correlation (a job
        # losing 2 slots on each of 3 dying nodes is one 6-slot casualty)
        self.zone_blasts: List[KillBlast] = []
        self._ckpt_region: Dict[str, str] = {}   # preempted job -> ckpt home
        # demand-aware bidding: the bidder rides on the autoscaler config;
        # its risk ledger consumes kill/resume costs this sim attributes
        self.bidder = autoscaler.cfg.bidder if autoscaler is not None else None
        self.risk_ledger = self.bidder.ledger if self.bidder is not None \
            else None
        # kill-preempted job -> (zone, kill time, replicas at kill): resume
        # attributes its follow-up cost back to the zone that caused it
        self._kill_zone: Dict[str, tuple] = {}
        self._expected_jobs = 0
        for node in provider.bootstrap(self.queue):
            self.cluster.add_node(node.node_id, node.slots,
                                  zone=node.pool.zone)
            self.accountant.node_up(node)
            self._trace_node_up(node)
        provider.schedule_zone_reclaims(self.queue)
        self.util.record_capacity(0.0, self.cluster.total_slots)
        if autoscaler is not None:
            self.queue.push(0.0, "autoscale_tick", None)

    # -- bookkeeping hooks ---------------------------------------------------
    def _trace_node_up(self, node) -> None:
        # boot window feeds the phase decomposition: initial queue wait that
        # overlaps a node's request->up interval is boot_wait, not queue_wait
        self.phases.note_boot_window(node.requested_at, self.now)
        if self.tracer.enabled:
            self.tracer.emit("node_up", t=self.now, node=node.node_id,
                             slots=node.slots, zone=node.pool.zone,
                             region=node.pool.region, market=node.pool.market,
                             price_per_slot_hour=node.pool.price_per_slot_hour,
                             boot_s=self.now - node.requested_at)

    def _wire_decisions(self) -> None:
        super()._wire_decisions()
        from repro.obs.decisions import DecisionLog
        log = DecisionLog(self.tracer)
        if self.autoscaler is not None and self.autoscaler.decisions is None:
            self.autoscaler.decisions = log
        if self.bidder is not None and self.bidder.decisions is None:
            self.bidder.decisions = log

    def _record_util(self):
        # integrate [last boundary, now] under the OLD allocations/rates,
        # then snapshot the new allocation state
        self.accountant.advance(self.now)
        super()._record_util()
        self.accountant.set_allocations(self.cluster.running_jobs())

    def _record_capacity(self):
        self.util.record_capacity(self.now, self.cluster.total_slots)
        self._record_util()

    def _sync_all(self):
        """Bring every running job's progress up to ``now``.  No event
        handler calls this anymore (the fleet-scale refactor made progress
        sync lazy: mutators sync their own victims, and policies that read
        ``work_remaining`` pull it through ``sync_job``); kept as a debugging
        aid for extensions that want a globally-consistent snapshot."""
        for j in self.cluster.running_jobs():
            self._sync_progress(j)

    def _all_done(self) -> bool:
        jobs = self.cluster.jobs
        return (len(jobs) >= self._expected_jobs and
                all(j.status is JobStatus.COMPLETED for j in jobs.values()))

    def _should_stop(self) -> bool:
        # the experiment window ends at the last completion; don't bill idle
        # nodes out to their far-future spot fates / teardown events
        if self._all_done():
            return True
        # stuck: every job submitted, nothing running, nothing booting, and
        # no autoscaler able to make progress — the queued remainder can
        # never start, so stop instead of billing to the next far-future
        # event.  With an autoscaler, "able to make progress" means some
        # queued job fits the pools' theoretical ceiling (the autoscaler can
        # provision toward it); past max_horizon nothing provisions either.
        jobs = self.cluster.jobs
        if (len(jobs) < self._expected_jobs
                or any(j.status is JobStatus.RUNNING for j in jobs.values())
                or self.provider.nodes_in(NodeState.PROVISIONING)):
            return False
        if self.autoscaler is None:
            return True
        if self.now >= self.autoscaler.cfg.max_horizon:
            return True
        max_slots = self.provider.theoretical_max_slots()
        return all(j.spec.min_replicas > max_slots
                   for j in self.cluster.queued_jobs())

    # -- API -----------------------------------------------------------------
    def submit(self, spec: JobSpec, workload: SimWorkload):
        self._expected_jobs += 1
        super().submit(spec, workload)

    def _final_metrics(self) -> ScheduleMetrics:
        metrics = super()._final_metrics()
        self.accountant.advance(self.now)
        self.cost_report = self.accountant.report()
        r = self.cost_report

        def _blast_stats(kills: List[KillBlast]):
            if not kills:
                return 0.0, 0.0, 0.0
            n = float(len(kills))
            # damage concentration: displaced slots per victim job, averaged
            # over kills (kills that hit empty nodes contribute 0)
            return (sum(k.jobs for k in kills) / n,
                    sum(k.slots / k.jobs for k in kills if k.jobs) / n,
                    sum(k.preempts for k in kills) / n)
        blast_jobs, blast_radius, preempts = _blast_stats(self.kill_blasts)
        zb_jobs, _, zb_preempts = _blast_stats(self.zone_blasts)
        # weighted, not mean-of-ratios: how many slots the average CASUALTY
        # lost to a correlated event (events that only hit empty nodes carry
        # no casualties and must not dilute the damage statistic)
        zb_victims = sum(k.jobs for k in self.zone_blasts)
        zb_radius = (sum(k.slots for k in self.zone_blasts) / zb_victims
                     if zb_victims else 0.0)
        return dataclasses.replace(
            metrics, total_cost=r.total_cost, idle_cost=r.idle_cost,
            node_hours=r.node_hours, spot_preemptions=r.spot_preemptions,
            transfer_cost=r.transfer_cost, zone_reclaims=self.zone_reclaims,
            kill_blast_jobs=blast_jobs, kill_blast_radius=blast_radius,
            kill_preemptions=preempts, zone_blast_jobs=zb_jobs,
            zone_blast_radius=zb_radius, zone_preemptions=zb_preempts,
            preempt_overhead_cost=r.preempt_overhead_cost,
            bid_adjustments=(self.bidder.adjustments
                             if self.bidder is not None else 0),
            spot_share_by_zone=self.spot_share_by_zone())

    def spot_share_by_zone(self) -> Dict[str, float]:
        """Observed (not bid) per-zone spot share: spot slot-hours billed in
        each zone over ALL billed slot-hours — what the fleet actually held,
        for comparison against the bidder's emitted quotas."""
        total = sum(n.slots * n.billed_hours(self.now)
                    for n in self.provider.nodes.values())
        if total <= 0.0:
            return {}
        per: Dict[str, float] = {}
        for n in self.provider.nodes.values():
            if n.pool.market == SPOT:
                h = n.slots * n.billed_hours(self.now)
                if h > 0.0:
                    per[n.pool.zone] = per.get(n.pool.zone, 0.0) + h
        return {z: h / total for z, h in sorted(per.items())}

    def job_region(self, job_id: str) -> Optional[str]:
        """Region hosting the plurality of the job's slots (checkpoint home
        for transfer billing); None while the job holds no slots."""
        per: Dict[str, int] = {}
        for nid, cnt in self.cluster.placement.job_nodes(job_id).items():
            r = self.provider.region_of(nid)
            per[r] = per.get(r, 0) + cnt
        if not per:
            return None
        return max(sorted(per), key=lambda r: per[r])

    def decommission(self, node_id: str) -> bool:
        """Voluntarily release an EMPTY node (autoscaler scale-down).  The
        capacity leaves the scheduler now; billing runs through teardown.
        Drain-aware guard: returns False while jobs are still resident
        (callers drain via :meth:`begin_drain`) instead of crashing."""
        if self.cluster.residents(node_id):
            return False
        self._record_util()                       # close the interval first
        self.cluster.remove_node(node_id)
        self.provider.release_node(node_id, self.now, self.queue)
        self._record_capacity()
        if self.tracer.enabled:
            self.tracer.emit("node_removed", t=self.now, node=node_id)
        return True

    # -- drain (graceful scale-down) -----------------------------------------
    def begin_drain(self, node_id: str) -> bool:
        """Cordon a node and try to empty it by migrating residents onto free
        slots elsewhere; decommission once empty.  Returns True when the node
        was released, False while residents remain (caller retries next tick
        — migrate-or-wait)."""
        if node_id not in self.cluster.nodes():
            return True                           # spot market beat us to it
        if not self.cluster.is_cordoned(node_id):
            self._record_util()
            if self.tracer.enabled:
                self.tracer.emit("node_cordon", t=self.now, node=node_id,
                                 slots=self.provider.nodes[node_id].slots,
                                 cause="drain")
            self.cluster.cordon(node_id)
            self._record_capacity()               # capacity leaves now
        residents = self.cluster.residents(node_id)
        for job_id in sorted(residents,
                             key=lambda i: self.cluster.jobs[i].sort_key()):
            self._migrate_job(self.cluster.jobs[job_id], node_id)
        return self.decommission(node_id)

    def cancel_drain(self, node_id: str) -> None:
        """Queue pressure returned mid-drain: put the capacity back."""
        if self.cluster.is_cordoned(node_id):
            self._record_util()
            self.cluster.uncordon(node_id)
            self._record_capacity()
            if self.tracer.enabled:
                self.tracer.emit("node_uncordon", t=self.now, node=node_id,
                                 slots=self.provider.nodes[node_id].slots)

    def _migrate_job(self, job, node_id: str) -> int:
        """Relocate a running job's workers off ``node_id`` onto free slots
        elsewhere.  The moved workers checkpoint/restart on their new homes:
        the job pays the rescale-model overhead scaled by the fraction of its
        replicas that moved."""
        if job.status is not JobStatus.RUNNING or job.replicas <= 0:
            return 0
        moved = self.cluster.migrate(job.job_id, node_id)
        if moved:
            self._sync_progress(job)
            wl = self.workloads[job.job_id]
            overhead = (wl.rescale.total(job.replicas, job.replicas,
                                         wl.data_bytes)
                        * moved / job.replicas)
            job.overhead_until = max(self.now, job.overhead_until) + overhead
            self.total_overhead += overhead
            self.migrations += 1
            self.counters.inc("migrations")
            self.phases.on_migrate(job.job_id, self.now, overhead)
            if self.tracer.enabled:
                self.tracer.emit("job_migrate", t=self.now, job=job.job_id,
                                 from_node=node_id, moved=moved,
                                 overhead_s=overhead)
            self._schedule_completion(job)
            self._record_util()
        return moved

    # -- cloud event kinds ---------------------------------------------------
    def _handle_event(self, ev) -> None:
        if ev.kind == "node_up":
            self._on_node_up(ev.payload)
        elif ev.kind == "node_down":
            node = self.provider.on_node_down(ev.payload, self.now)
            if node is not None:
                self._record_util()               # integrate, then drop rate
                self.accountant.node_down(node)
                if self.tracer.enabled:
                    self.tracer.emit("node_billing_end", t=self.now,
                                     node=node.node_id, cause="teardown")
        elif ev.kind == "spot_kill":
            self._on_spot_kill(ev.payload)
        elif ev.kind == "zone_reclaim":
            self._on_zone_reclaim(ev.payload)
        elif ev.kind == "autoscale_tick":
            self._on_autoscale_tick()
        else:
            super()._handle_event(ev)

    def _on_node_up(self, node_id: str) -> None:
        node = self.provider.on_node_up(node_id, self.now)
        if node is None:
            return                                # killed while booting
        self._record_util()                       # close interval at old rate
        self.accountant.node_up(node)
        self._trace_node_up(node)
        self.cluster.add_node(node.node_id, node.slots, zone=node.pool.zone)
        self._record_capacity()
        # fresh capacity is a completion-shaped opportunity: run the Fig. 3
        # redistribution so queued jobs start / running jobs expand
        self.policy.on_job_complete(self.cluster, node.slots, self.now,
                                    self.actions)

    def _on_spot_kill(self, node_id: str) -> None:
        node, was_up = self.provider.on_spot_kill(node_id, self.now)
        if node is None:
            return                                # stale: already gone
        self._record_util()
        self.accountant.node_down(node, killed=True)
        self.counters.inc("spot_kills")
        if not was_up:
            if self.tracer.enabled:   # was draining: billing only
                self.tracer.emit("node_billing_end", t=self.now,
                                 node=node_id, cause="spot_kill_draining")
            return
        # placement makes the blast set exact: ONLY the jobs resident on the
        # killed node are displaced (paper: the operator loses specific pods
        # on a specific node), never arbitrary victims elsewhere
        victims = dict(self.cluster.residents(node_id))
        # residents parked on OTHER cordoned nodes (an in-flight drain) are
        # that drain's deficit, not this kill's: the postcondition is that
        # the kill adds nothing to it
        pre_overcommit = self.cluster.overcommit
        was_cordoned = self.cluster.is_cordoned(node_id)
        if self.tracer.enabled:
            # opens the blast window the auditor allows transient overcommit
            # in; closed by the matching kill_blast_end below
            self.tracer.emit("spot_kill", t=self.now, node=node_id,
                             slots=node.slots,
                             zone=self.provider.zone_of(node_id),
                             residents=victims, was_cordoned=was_cordoned)
        self.cluster.cordon(node_id)              # capacity is gone NOW
        self._record_capacity()
        by_prio = sorted((self.cluster.jobs[v] for v in victims),
                         key=lambda j: j.sort_key())
        # 1) migrate: free slots elsewhere absorb displaced workers (highest
        #    priority first gets the scarce free capacity)
        for j in by_prio:
            self._migrate_job(j, node_id)
        # 2) shrink still-resident elastic victims toward min, lowest
        #    priority first (forced: the capacity is already gone, so no
        #    gap/priority ceremony); placement.evict vacates the cordoned
        #    node first, so the shrink comes off the dying node exactly
        self._evict_prefer = node_id
        try:
            for j in reversed(by_prio):
                still = self.cluster.residents(node_id).get(j.job_id, 0)
                if still and j.status is JobStatus.RUNNING:
                    target = j.spec.feasible(
                        max(j.spec.min_replicas, j.replicas - still))
                    # only a shrink that clears the job OFF the node helps;
                    # a partial one pays rescale overhead and the job gets
                    # checkpoint-preempted in step 3 regardless
                    if target < j.replicas and target <= j.replicas - still:
                        self.actions.shrink(j, target)
        finally:
            self._evict_prefer = None
        # 3) still resident: checkpoint-to-disk preemption (same path as
        #    PreemptingPolicy), lowest priority first
        zone = self.provider.zone_of(node_id)
        ovh0 = self.accountant.preempt_overhead_cost
        ovh_s0 = self.accountant.preempt_overhead_slot_s
        preempted = 0
        for j in reversed(by_prio):
            if self.cluster.residents(node_id).get(j.job_id, 0):
                reps = j.replicas
                self.actions.preempt(j)
                self.spot_victim_jobs += 1
                preempted += 1
                # resume will attribute restore/outage/transfer to this zone
                self._kill_zone[j.job_id] = (zone, self.now, reps)
        assert not self.cluster.residents(node_id), "spot eviction failed"
        self.cluster.remove_node(node_id)
        assert self.cluster.overcommit <= pre_overcommit, \
            "spot eviction failed"
        self.kill_blasts.append(KillBlast(
            len(victims), sum(victims.values()), preempted, zone))
        if self.tracer.enabled:
            self.tracer.emit("kill_blast_end", t=self.now, node=node_id,
                             jobs=len(victims), slots=sum(victims.values()),
                             preempts=preempted)
        if self.risk_ledger is not None:
            # the kill itself plus the checkpoint dollars its victims just
            # paid (accountant delta — never re-derived here)
            self.risk_ledger.record_kill(
                zone, self.now,
                dollars=self.accountant.preempt_overhead_cost - ovh0,
                lost_seconds=(self.accountant.preempt_overhead_slot_s
                              - ovh_s0))
        # surviving free capacity (shrinks may have overshot node granularity)
        # goes back through the redistribution pass; pass the real free count
        # so pseudocode-faithful configs (redistribute_idle=False) see it too
        free = self.cluster.free_slots
        if free > 0:
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)

    def _on_zone_reclaim(self, zone: str) -> None:
        """One correlated reclaim: the provider picks the victims (and re-arms
        the zone's Poisson stream); this sim replays them as a batch of
        node-exact kills.  Cordoning the WHOLE blast set up front keeps the
        per-node displacement honest: a worker migrated off one dying node is
        never parked on another node dying in the same burst."""
        victims = self.provider.on_zone_reclaim(zone, self.now, self.queue)
        if not victims:
            return
        self.zone_reclaims += 1
        self.counters.inc("zone_reclaims")
        if self.tracer.enabled:
            self.tracer.emit("zone_reclaim", t=self.now, zone=zone,
                             victims=list(victims))
        # event-level blast set, captured BEFORE displacement: a preemption
        # during the batch evicts the job everywhere, so later nodes' own
        # resident maps would under-count what this event took from it
        displaced: Dict[str, int] = {}
        for node_id in victims:
            if node_id in self.cluster.nodes():
                for job_id, cnt in self.cluster.residents(node_id).items():
                    displaced[job_id] = displaced.get(job_id, 0) + cnt
                if self.tracer.enabled:
                    self.tracer.emit(
                        "node_cordon", t=self.now, node=node_id,
                        slots=self.provider.nodes[node_id].slots,
                        cause="zone_reclaim")
                self.cluster.cordon(node_id)
        pre_preempts = self.spot_victim_jobs
        for node_id in victims:
            self._on_spot_kill(node_id)
        if self.tracer.enabled:
            self.tracer.emit("zone_reclaim_end", t=self.now, zone=zone)
        self.zone_blasts.append(KillBlast(
            len(displaced), sum(displaced.values()),
            self.spot_victim_jobs - pre_preempts, zone))

    def _on_autoscale_tick(self) -> None:
        if self.autoscaler is None:
            return
        self.counters.inc("autoscale_ticks")
        self.autoscaler.evaluate(self, self.now)
        # CLUES-style periodic queue re-examination: offer free capacity to
        # queued jobs that earlier passes skipped (e.g. a rescale-gap
        # cooldown that has since expired) — without this, a startable job
        # could wait forever if no completion/node event comes
        free = self.cluster.free_slots
        if free > 0 and self.cluster.queued_jobs():
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)
        if (not self._all_done()
                and self.now < self.autoscaler.cfg.max_horizon):
            self.queue.push(self.now + self.autoscaler.cfg.tick_interval,
                            "autoscale_tick", None)
