"""Discrete-event simulator over a :class:`CloudProvider`: dynamic capacity,
spot preemption, node autoscaling, and cost accounting.

Extends :class:`repro.core.simulator.Simulator` with four event kinds:

- ``node_up``        capacity attaches; queued jobs get a Fig.-3 offer pass
- ``node_down``      a drained node's billing stops
- ``spot_kill``      a spot node vanishes NOW; running jobs above the new
                     capacity are first shrunk toward min_replicas (lowest
                     priority first), then checkpoint-to-disk preempted via
                     the same ``Actions.preempt`` path PreemptingPolicy uses
                     (victims requeue and later resume with progress intact)
- ``autoscale_tick`` the NodeAutoscaler evaluates queue pressure / idleness

Cost integration piggybacks on ``_record_util``: every allocation or capacity
boundary advances the :class:`CostAccountant` under the rates that held since
the previous boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.cloud.cost import CostAccountant, CostReport
from repro.cloud.node_autoscaler import NodeAutoscaler
from repro.cloud.provider import CloudProvider, NodeState
from repro.core.job import JobSpec, JobStatus
from repro.core.metrics import ScheduleMetrics
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload


class CloudSimulator(Simulator):
    def __init__(self, provider: CloudProvider, policy_cfg: PolicyConfig,
                 *, autoscaler: Optional[NodeAutoscaler] = None,
                 policy=None):
        super().__init__(0, policy_cfg)     # all capacity comes from nodes
        if policy is not None:
            self.policy = policy
        self.provider = provider
        self.autoscaler = autoscaler
        self.accountant = CostAccountant()
        self.cost_report: Optional[CostReport] = None
        self.spot_victim_jobs = 0           # job preemptions caused by kills
        self._expected_jobs = 0
        for node in provider.bootstrap(self.queue):
            self.cluster.add_node(node.node_id, node.slots)
            self.accountant.node_up(node)
        self.util.record_capacity(0.0, self.cluster.total_slots)
        if autoscaler is not None:
            self.queue.push(0.0, "autoscale_tick", None)

    # -- bookkeeping hooks ---------------------------------------------------
    def _record_util(self):
        # integrate [last boundary, now] under the OLD allocations/rates,
        # then snapshot the new allocation state
        self.accountant.advance(self.now)
        super()._record_util()
        self.accountant.set_allocations(self.cluster.running_jobs())

    def _record_capacity(self):
        self.util.record_capacity(self.now, self.cluster.total_slots)
        self._record_util()

    def _sync_all(self):
        for j in self.cluster.running_jobs():
            self._sync_progress(j)

    def _all_done(self) -> bool:
        jobs = self.cluster.jobs
        return (len(jobs) >= self._expected_jobs and
                all(j.status is JobStatus.COMPLETED for j in jobs.values()))

    def _should_stop(self) -> bool:
        # the experiment window ends at the last completion; don't bill idle
        # nodes out to their far-future spot fates / teardown events
        if self._all_done():
            return True
        # stuck: every job submitted, nothing running, nothing booting, and
        # no autoscaler able to make progress — the queued remainder can
        # never start, so stop instead of billing to the next far-future
        # event.  With an autoscaler, "able to make progress" means some
        # queued job fits the pools' theoretical ceiling (the autoscaler can
        # provision toward it); past max_horizon nothing provisions either.
        jobs = self.cluster.jobs
        if (len(jobs) < self._expected_jobs
                or any(j.status is JobStatus.RUNNING for j in jobs.values())
                or self.provider.nodes_in(NodeState.PROVISIONING)):
            return False
        if self.autoscaler is None:
            return True
        if self.now >= self.autoscaler.cfg.max_horizon:
            return True
        max_slots = self.provider.theoretical_max_slots()
        return all(j.spec.min_replicas > max_slots
                   for j in self.cluster.queued_jobs())

    # -- API -----------------------------------------------------------------
    def submit(self, spec: JobSpec, workload: SimWorkload):
        self._expected_jobs += 1
        super().submit(spec, workload)

    def run(self) -> ScheduleMetrics:
        metrics = super().run()
        self.accountant.advance(self.now)
        self.cost_report = self.accountant.report()
        r = self.cost_report
        return dataclasses.replace(
            metrics, total_cost=r.total_cost, idle_cost=r.idle_cost,
            node_hours=r.node_hours, spot_preemptions=r.spot_preemptions)

    def decommission(self, node_id: str) -> None:
        """Voluntarily release an idle node (autoscaler scale-down).  The
        capacity leaves the scheduler now; billing runs through teardown."""
        node = self.provider.nodes[node_id]
        assert self.cluster.free_slots >= node.slots, \
            "decommission would displace running work"
        self._record_util()                       # close the interval first
        self.cluster.remove_node(node_id)
        self.provider.release_node(node_id, self.now, self.queue)
        self._record_capacity()

    # -- cloud event kinds ---------------------------------------------------
    def _handle_event(self, ev) -> None:
        if ev.kind == "node_up":
            self._on_node_up(ev.payload)
        elif ev.kind == "node_down":
            node = self.provider.on_node_down(ev.payload, self.now)
            if node is not None:
                self._record_util()               # integrate, then drop rate
                self.accountant.node_down(node)
        elif ev.kind == "spot_kill":
            self._on_spot_kill(ev.payload)
        elif ev.kind == "autoscale_tick":
            self._on_autoscale_tick()
        else:
            super()._handle_event(ev)

    def _on_node_up(self, node_id: str) -> None:
        node = self.provider.on_node_up(node_id, self.now)
        if node is None:
            return                                # killed while booting
        self._record_util()                       # close interval at old rate
        self.accountant.node_up(node)
        self.cluster.add_node(node.node_id, node.slots)
        self._record_capacity()
        # fresh capacity is a completion-shaped opportunity: run the Fig. 3
        # redistribution so queued jobs start / running jobs expand
        self._sync_all()
        self.policy.on_job_complete(self.cluster, node.slots, self.now,
                                    self.actions)

    def _on_spot_kill(self, node_id: str) -> None:
        node, was_up = self.provider.on_spot_kill(node_id, self.now)
        if node is None:
            return                                # stale: already gone
        self._record_util()
        self.accountant.node_down(node, killed=True)
        if not was_up:
            return                                # was draining: billing only
        self._sync_all()
        self.cluster.remove_node(node_id)
        self._record_capacity()
        deficit = self.cluster.overcommit
        # 1) shrink elastic victims toward min, lowest priority first (forced:
        #    the capacity is already gone, so no gap/priority ceremony)
        if deficit > 0:
            for j in reversed(self.cluster.running_jobs()):
                if deficit <= 0:
                    break
                target = j.spec.feasible(
                    max(j.spec.min_replicas, j.replicas - deficit))
                if target < j.replicas:
                    freed = j.replicas - target
                    if self.actions.shrink(j, target):
                        deficit -= freed
        # 2) still over: checkpoint-to-disk preemption (same path as
        #    PreemptingPolicy), lowest priority first
        if deficit > 0:
            for j in reversed(self.cluster.running_jobs()):
                if deficit <= 0:
                    break
                deficit -= j.replicas
                self.actions.preempt(j)
                self.spot_victim_jobs += 1
        assert self.cluster.overcommit == 0, "spot eviction failed"
        # surviving free capacity (shrinks may have overshot node granularity)
        # goes back through the redistribution pass; pass the real free count
        # so pseudocode-faithful configs (redistribute_idle=False) see it too
        free = self.cluster.free_slots
        if free > 0:
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)

    def _on_autoscale_tick(self) -> None:
        if self.autoscaler is None:
            return
        self._sync_all()
        self.autoscaler.evaluate(self, self.now)
        # CLUES-style periodic queue re-examination: offer free capacity to
        # queued jobs that earlier passes skipped (e.g. a rescale-gap
        # cooldown that has since expired) — without this, a startable job
        # could wait forever if no completion/node event comes
        free = self.cluster.free_slots
        if free > 0 and self.cluster.queued_jobs():
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)
        if (not self._all_done()
                and self.now < self.autoscaler.cfg.max_horizon):
            self.queue.push(self.now + self.autoscaler.cfg.tick_interval,
                            "autoscale_tick", None)
