"""Cloud provider layer: dynamic node pools, pricing, spot preemption, and a
CLUES-style node autoscaler — the pay-as-you-go substrate the paper's elastic
scheduler is judged against (see README §Cloud subsystem).
"""
from repro.cloud.cost import CostAccountant, CostReport
from repro.cloud.node_autoscaler import AutoscalerConfig, NodeAutoscaler
from repro.cloud.provider import (ON_DEMAND, SPOT, CloudProvider, Node,
                                  NodePool, NodeState)
from repro.cloud.sim import CloudSimulator, KillBlast

__all__ = [
    "CostAccountant", "CostReport", "AutoscalerConfig", "NodeAutoscaler",
    "ON_DEMAND", "SPOT", "CloudProvider", "Node", "NodePool", "NodeState",
    "CloudSimulator", "KillBlast",
]
