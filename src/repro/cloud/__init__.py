"""Cloud provider layer: dynamic node pools, pricing, spot preemption, a
CLUES-style node autoscaler, and demand-aware per-zone spot bidding — the
pay-as-you-go substrate the paper's elastic scheduler is judged against
(see README §Cloud subsystem, §Spot bidding).
"""
from repro.cloud.bidding import (BidderConfig, DemandAwareBidder,
                                 SpotRiskLedger, ZoneRisk)
from repro.cloud.cost import CostAccountant, CostReport
from repro.cloud.node_autoscaler import (AutoscalerConfig, NodeAutoscaler,
                                         NodeAutoscalerConfig)
from repro.cloud.provider import (ON_DEMAND, SPOT, CloudProvider, Node,
                                  NodePool, NodeState)
from repro.cloud.sim import CloudSimulator, KillBlast

__all__ = [
    "BidderConfig", "DemandAwareBidder", "SpotRiskLedger", "ZoneRisk",
    "CostAccountant", "CostReport", "AutoscalerConfig", "NodeAutoscaler",
    "NodeAutoscalerConfig", "ON_DEMAND", "SPOT", "CloudProvider", "Node",
    "NodePool", "NodeState", "CloudSimulator", "KillBlast",
]
