"""Per-job and per-cluster cost accounting (paper premise: pay-as-you-go).

:class:`CostAccountant` is a piecewise-constant integrator.  The cloud
simulator calls :meth:`advance` at every state-change boundary *before*
applying the change, so each elapsed interval is integrated under the rates
that actually held during it:

- total cost:  sum over billed nodes of slots x $/slot-hour, plus any
               inter-region transfer dollars
- used cost:   running-job slots x the capacity-weighted mean price of the
               currently billed capacity (blended rate)
- idle cost:   capacity total - used  (wasted-idle dollars: provisioned, not
               running; transfer dollars are neither idle nor used capacity)
- job cost:    each job's replicas x blended rate, accumulated over its life
- transfer:    $/GB for checkpoint data restored in a different REGION than
               it was written in (a preempted job resuming across a region
               boundary drags its checkpoint over the wire; intra-region
               restores are free) — itemized separately and per job
- preemption overhead: the slot-seconds a victim spends writing/restoring
               its disk checkpoint, priced at the blended rate — an
               ATTRIBUTION of capacity dollars already billed (a subset of
               used/idle), itemized per job so consumers (the spot-bidding
               risk ledger) never re-derive it; never added to total_cost

Attribution note: the counting simulator does not pin jobs to nodes, so jobs
pay the *blended* $/slot-hour of whatever capacity mix is live — a job running
during a spot-heavy period is cheap, the same job on pure on-demand is not.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List

from repro.core.job import JobState


@dataclass(frozen=True)
class CostReport:
    total_cost: float               # $ billed: node capacity + transfer
    used_cost: float                # $ attributed to running job slots
    idle_cost: float                # $ of provisioned-but-unused slot time
    node_hours: float               # billed node-hours
    slot_hours: float               # billed slot-hours
    job_costs: Dict[str, float]     # job_id -> capacity $ attributed
    spot_preemptions: int           # nodes reclaimed by the spot market
    transfer_cost: float = 0.0      # $ of inter-region checkpoint transfer
    transfer_costs: Dict[str, float] = field(default_factory=dict)  # per job
    # preemption overhead: checkpoint write/restore slot-time priced at the
    # blended rate — attribution of already-billed capacity $, not additive
    preempt_overhead_cost: float = 0.0
    preempt_overhead_slot_s: float = 0.0  # victim slot-seconds of overhead
    preempt_overhead_costs: Dict[str, float] = field(default_factory=dict)

    @property
    def idle_fraction(self) -> float:
        """Share of CAPACITY dollars wasted idle — transfer spend is not
        capacity and must not dilute the denominator."""
        capacity = self.used_cost + self.idle_cost
        return self.idle_cost / capacity if capacity else 0.0

    def row(self) -> str:
        return (f"cost=${self.total_cost:8.4f} idle=${self.idle_cost:8.4f} "
                f"({self.idle_fraction:6.2%}) node_h={self.node_hours:6.2f} "
                f"spot_kills={self.spot_preemptions} "
                f"xfer=${self.transfer_cost:7.4f}")


class CostAccountant:
    def __init__(self):
        self._now = 0.0
        self._dollars_per_s = 0.0       # current billed capacity burn rate
        self._billed_slots = 0
        self._billed_nodes = 0
        self._job_alloc: Dict[str, int] = {}
        self.total_cost = 0.0
        self.used_cost = 0.0
        self.node_seconds = 0.0
        self.slot_seconds = 0.0
        self.job_costs: Dict[str, float] = defaultdict(float)
        self.spot_preemptions = 0
        self.transfer_cost = 0.0
        self.transfer_costs: Dict[str, float] = defaultdict(float)
        self.preempt_overhead_cost = 0.0
        self.preempt_overhead_slot_s = 0.0
        self.preempt_overhead_costs: Dict[str, float] = defaultdict(float)

    # -- integration ---------------------------------------------------------
    def advance(self, now: float) -> None:
        """Integrate the interval since the last boundary under the current
        rates.  MUST be called before any node or allocation change."""
        dt = now - self._now
        if dt <= 0.0:
            return
        self._now = now
        self.total_cost += self._dollars_per_s * dt
        self.node_seconds += self._billed_nodes * dt
        self.slot_seconds += self._billed_slots * dt
        if self._billed_slots:
            blended = self._dollars_per_s / self._billed_slots   # $/slot-s
            alloc_total = sum(r for r in self._job_alloc.values() if r > 0)
            # a spot kill can leave allocations transiently above billed
            # capacity (victims checkpoint before eviction completes); scale
            # attribution down so used_cost never exceeds total_cost and
            # idle = total - used stays a true identity
            scale = (min(1.0, self._billed_slots / alloc_total)
                     if alloc_total else 1.0)
            for job_id, replicas in self._job_alloc.items():
                if replicas > 0:
                    dollars = replicas * scale * dt * blended
                    self.job_costs[job_id] += dollars
                    self.used_cost += dollars

    def spend_through(self, now: float) -> float:
        """Projected total spend at ``now`` without mutating state."""
        return (self.total_cost + self.transfer_cost
                + self._dollars_per_s * max(0.0, now - self._now))

    # -- state changes (apply AFTER advance) ---------------------------------
    def node_up(self, node) -> None:
        self._dollars_per_s += node.slots * node.pool.price_per_slot_hour / 3600.0
        self._billed_slots += node.slots
        self._billed_nodes += 1

    def node_down(self, node, *, killed: bool = False) -> None:
        self._dollars_per_s -= node.slots * node.pool.price_per_slot_hour / 3600.0
        self._billed_slots -= node.slots
        self._billed_nodes -= 1
        if self._billed_nodes == 0:
            self._dollars_per_s = 0.0    # kill float residue
        if killed:
            self.spot_preemptions += 1

    def set_allocations(self, running_jobs: Iterable[JobState]) -> None:
        self._job_alloc = {j.job_id: j.replicas for j in running_jobs}

    def blended_slot_rate(self) -> float:
        """Current blended $/slot-second of the billed capacity (0 with
        nothing billed) — the rate preemption overhead and lost work are
        priced at."""
        return (self._dollars_per_s / self._billed_slots
                if self._billed_slots else 0.0)

    def bill_preempt_overhead(self, job_id: str, seconds: float,
                              replicas: int) -> float:
        """Attribute one checkpoint write (at preempt) or restore (at
        resume) to the victim: ``seconds`` of ``replicas`` slots at the
        blended rate.  Returns the dollars so callers (the spot-bidding
        ledger) can consume them without re-deriving."""
        dollars = seconds * max(0, replicas) * self.blended_slot_rate()
        self.preempt_overhead_cost += dollars
        self.preempt_overhead_slot_s += seconds * max(0, replicas)
        self.preempt_overhead_costs[job_id] += dollars
        return dollars

    def bill_transfer(self, job_id: str, data_bytes: float,
                      price_per_gb: float) -> float:
        """Bill one inter-region checkpoint restore: the job's checkpoint
        footprint crosses a region boundary at ``price_per_gb``."""
        dollars = data_bytes / 1e9 * price_per_gb
        self.transfer_cost += dollars
        self.transfer_costs[job_id] += dollars
        return dollars

    # -- reporting -----------------------------------------------------------
    def report(self) -> CostReport:
        return CostReport(
            total_cost=self.total_cost + self.transfer_cost,
            used_cost=self.used_cost,
            idle_cost=max(0.0, self.total_cost - self.used_cost),
            node_hours=self.node_seconds / 3600.0,
            slot_hours=self.slot_seconds / 3600.0,
            job_costs=dict(self.job_costs),
            spot_preemptions=self.spot_preemptions,
            transfer_cost=self.transfer_cost,
            transfer_costs=dict(self.transfer_costs),
            preempt_overhead_cost=self.preempt_overhead_cost,
            preempt_overhead_slot_s=self.preempt_overhead_slot_s,
            preempt_overhead_costs=dict(self.preempt_overhead_costs),
        )
