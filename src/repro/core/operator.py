"""ElasticClusterController — the Kubernetes-operator analog (paper C2).

Owns a pool of JAX devices partitioned into replica slots, runs the *same*
:class:`ElasticPolicy` as the simulator, but against live
:class:`ElasticTrainer` jobs: create/shrink/expand actually build meshes,
compile, and reshard training state.  The control loop is cooperative
(single-process): each tick advances every running job by ``steps_per_tick``
train steps — the scheduling observable is identical to running jobs in
parallel processes, which one CPU core cannot do honestly anyway.

Clocking: the controller's clock advances by each job-step's *modeled* wall
time when ``step_time_fn`` is given (so T_rescale_gap is meaningful in
simulated seconds) or by real wall time otherwise.

Fault tolerance (paper §3.2.2): ``inject_failure`` kills a running job; if a
disk checkpoint exists the job is resubmitted with the restart flag and
resumes from its last snapshot, otherwise it restarts from scratch.

Node awareness: with ``slots_per_node`` the device pool is partitioned into
named nodes (``base00..``) through the same :class:`PlacementMap` the cloud
simulator uses, so the controller kills/drains *specific jobs on specific
nodes* (paper: pods on nodes).  ``inject_node_failure`` abruptly fails every
job resident on a node; ``drain_node`` gracefully migrates residents' workers
onto free slots elsewhere (a live rescale onto the new device set), shrinking
jobs that cannot move and restart-requeueing jobs stuck with nowhere to go.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.checkpoint import DiskCheckpointStore
from repro.core.cluster import Cluster
from repro.core.elastic import ElasticTrainer, RescaleTimings, TrainJobConfig
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.metrics import ScheduleMetrics, UtilizationLog, compute_metrics
from repro.core.policies import Actions, ElasticPolicy, PolicyConfig
from repro.obs.decisions import DecisionLog
from repro.obs.stats import Counters, LatencyRecorder
from repro.obs.trace import current_tracer


@dataclass
class LiveJob:
    state: JobState
    factory: Callable[[list], ElasticTrainer]   # devices -> trainer
    trainer: Optional[ElasticTrainer] = None
    checkpoint_every: int = 0                    # steps; 0 = off
    failures: int = 0


class _LiveActions(Actions):
    def __init__(self, op: "ElasticClusterController"):
        self.op = op

    def create(self, job: JobState, replicas: int) -> bool:
        op = self.op
        live = op.live[job.job_id]
        if not op.cluster.can_place(replicas):
            return False        # raced a cordon/drain: stay queued
        slots = op.cluster.place(job.job_id, replicas)
        devices = op.cluster.devices_for_slots(slots)
        resumed = bool(op.restart_flags.get(job.job_id))
        try:
            if live.trainer is None:
                live.trainer = live.factory(devices)
                if op.disk_store is not None and op.restart_flags.get(job.job_id):
                    try:
                        live.trainer.restore_disk(op.disk_store, job.job_id)
                    except FileNotFoundError:
                        pass
            else:   # queued job that had run before (preempted/restarted)
                live.trainer.rescale(devices)
        except Exception:
            op.cluster.release_slots(job.job_id)
            raise
        job.status = JobStatus.RUNNING
        job.replicas = replicas
        job.device_ids = tuple(slots)
        job.last_action = op.now
        if job.start_time is None:
            job.start_time = op.now
        op._record_util()
        op.latency.mark_started(job.job_id, op.now)
        if op.tracer.enabled:
            op.tracer.emit("job_start", t=op.now, job=job.job_id,
                           slots=replicas, priority=job.spec.priority,
                           resume=resumed, overhead_s=0.0)
        return True

    def expand(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def shrink(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def _rescale(self, job: JobState, replicas: int) -> bool:
        op = self.op
        live = op.live[job.job_id]
        if replicas == job.replicas or live.trainer is None:
            return True
        if replicas > job.replicas:
            extra = replicas - job.replicas
            if extra > op.cluster.free_slots:
                return False
            op.cluster.place(job.job_id, extra)
        else:
            # a drain names its node via _evict_prefer; cordoned nodes are
            # vacated first regardless
            op.cluster.evict(job.job_id, job.replicas - replicas,
                             prefer=op._evict_prefer)
        slots = op.cluster.slots_of(job.job_id)
        devices = op.cluster.devices_for_slots(slots)
        from_replicas = job.replicas
        timings = live.trainer.rescale(devices)
        op.rescale_events.append((op.now, job.job_id, job.replicas, replicas,
                                  timings))
        op.advance_clock(timings.total)
        job.replicas = replicas
        job.device_ids = tuple(slots)
        job.last_action = op.now
        job.rescale_count += 1
        op._record_util()
        op.counters.inc("rescales")
        if op.tracer.enabled:
            op.tracer.emit("job_rescale", t=op.now, job=job.job_id,
                           **{"from": from_replicas, "to": replicas},
                           overhead_s=timings.total)
        return True

    def enqueue(self, job: JobState) -> None:
        job.status = JobStatus.QUEUED
        op = self.op
        op.latency.mark_queued(job.job_id, op.now)
        if op.tracer.enabled:
            op.tracer.emit("job_queue", t=op.now, job=job.job_id)


class ElasticClusterController:
    def __init__(self, devices: list, *, slots: int, devices_per_slot: int = 1,
                 policy: PolicyConfig = PolicyConfig(rescale_gap=0.0),
                 disk_store: Optional[DiskCheckpointStore] = None,
                 step_time_fn: Optional[Callable[[JobState], float]] = None,
                 steps_per_tick: int = 1,
                 slots_per_node: Optional[int] = None,
                 placement: str = "pack", tracer=None):
        self.cluster = Cluster(slots, devices, devices_per_slot,
                               slots_per_node=slots_per_node,
                               placement=placement)
        self.policy = ElasticPolicy(policy)
        self.actions = _LiveActions(self)
        self.live: Dict[str, LiveJob] = {}
        self.pending: List[JobState] = []
        self.disk_store = disk_store
        self.restart_flags: Dict[str, bool] = {}
        self.step_time_fn = step_time_fn
        self.steps_per_tick = steps_per_tick
        self.now = 0.0
        self._wall0 = time.perf_counter()
        self._evict_prefer: Optional[str] = None  # forced-shrink target node
        self.util = UtilizationLog(slots)
        self.rescale_events: List[tuple] = []
        self.replica_trace: List[tuple] = []     # (t, job_id, replicas)
        # observability: same flight recorder as the simulators, so one
        # auditor/timeline consumes traces from both lanes
        self.tracer = tracer if tracer is not None else current_tracer()
        self.counters = Counters()
        self.latency = LatencyRecorder()
        self.run_id = self.tracer.next_run_id()
        self._submitted: set = set()     # job_submit emitted (resubmits skip)
        if self.tracer.enabled:
            self.tracer.emit("run_start", t=0.0, run=self.run_id, slots=slots,
                             sim=type(self).__name__)

    # -- clock ----------------------------------------------------------------
    def advance_clock(self, dt: float):
        if self.step_time_fn is not None:
            self.now += dt
        else:
            self.now = time.perf_counter() - self._wall0

    def _record_util(self):
        self.util.record(self.now, self.cluster.used_slots)
        if self.cluster.node_count > 1:     # single-node: frag is undefined
            self.util.record_fragmentation(self.now,
                                           self.cluster.fragmentation())
        for j in self.cluster.jobs.values():
            self.replica_trace.append((self.now, j.job_id, j.replicas))

    # -- API --------------------------------------------------------------------
    def submit(self, spec: JobSpec, factory: Callable[[list], ElasticTrainer],
               checkpoint_every: int = 0, restart: bool = False):
        state = JobState(spec=spec)
        self.live[spec.job_id] = LiveJob(state=state, factory=factory,
                                         checkpoint_every=checkpoint_every)
        self.restart_flags[spec.job_id] = restart
        self.pending.append(state)
        self.pending.sort(key=lambda j: j.spec.submit_time)

    def inject_failure(self, job_id: str):
        """Kill a running job (process failure).  Resubmission goes through
        the normal newJob path with the restart flag set (paper §3.2.2)."""
        self._fail_and_resubmit(job_id)

    def _fail_and_resubmit(self, job_id: str, redistribute: bool = True):
        """``redistribute=False`` defers the Fig.-3 pass so multi-victim
        callers (node failure) don't expand a job they are about to kill."""
        job = self.cluster.jobs[job_id]
        live = self.live[job_id]
        assert job.status == JobStatus.RUNNING
        self.cluster.evict(job_id)
        freed = job.replicas
        job.replicas = 0
        job.status = JobStatus.PENDING
        live.trainer = None          # process state lost
        live.failures += 1
        self.restart_flags[job_id] = True
        del self.cluster.jobs[job_id]
        self._record_util()
        self.counters.inc("failures")
        self.latency.mark_queued(job_id, self.now)
        if self.tracer.enabled:
            self.tracer.emit("job_fail", t=self.now, job=job_id, slots=freed)
        if redistribute:
            # freed capacity is redistributed like a completion
            self.policy.on_job_complete(self.cluster, freed, self.now,
                                        self.actions)
        # resubmit immediately
        self.pending.append(job)
        self.pending.sort(key=lambda j: j.spec.submit_time)

    # -- node-level operations (paper: pods on nodes) -------------------------
    def inject_node_failure(self, node_id: str) -> List[str]:
        """Abrupt node death: every job resident on the node loses workers
        with no warning — per-worker state is unrecoverable, so each victim
        restarts from its last disk checkpoint (or scratch), exactly like
        :meth:`inject_failure` but with a placement-exact blast set.  The
        node's capacity stays offline until :meth:`recover_node`."""
        victims = sorted(self.cluster.residents(node_id))
        if self.tracer.enabled:
            self.tracer.emit("node_cordon", t=self.now, node=node_id,
                             cause="failure")
        self.cluster.cordon(node_id)
        self.util.record_capacity(self.now, self.cluster.total_slots)
        for job_id in victims:
            # defer redistribution: a mid-loop Fig.-3 pass could expand (a
            # real trainer rescale) a job this loop kills next
            self._fail_and_resubmit(job_id, redistribute=False)
        free = self.cluster.free_slots
        if victims and free > 0:
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)
        return victims

    def recover_node(self, node_id: str) -> None:
        """A failed/drained node rejoins; its capacity is offered to queued
        and running jobs like a completion (Fig. 3 pass)."""
        self.cluster.uncordon(node_id)
        self.util.record_capacity(self.now, self.cluster.total_slots)
        if self.tracer.enabled:
            self.tracer.emit("node_uncordon", t=self.now, node=node_id)
        free = self.cluster.free_slots
        if free > 0:
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)

    def drain_node(self, node_id: str) -> None:
        """Graceful drain (`kubectl drain` analog): cordon the node, then for
        each resident job — highest priority first — migrate its workers onto
        free slots elsewhere (live rescale onto the new device set), shrink
        what cannot move, and restart-requeue jobs stuck with nowhere to go.
        The node ends cordoned and empty."""
        if self.tracer.enabled:
            self.tracer.emit("node_cordon", t=self.now, node=node_id,
                             cause="drain")
        self.cluster.cordon(node_id)
        self.util.record_capacity(self.now, self.cluster.total_slots)
        residents = self.cluster.residents(node_id)
        requeued = 0
        for job_id in sorted(residents,
                             key=lambda i: self.cluster.jobs[i].sort_key()):
            job = self.cluster.jobs[job_id]
            live = self.live[job_id]
            moved = self.cluster.migrate(job_id, node_id)
            if moved and live.trainer is not None:
                slots = self.cluster.slots_of(job_id)
                devices = self.cluster.devices_for_slots(slots)
                timings = live.trainer.rescale(devices)
                self.rescale_events.append(
                    (self.now, job_id, job.replicas, job.replicas, timings))
                self.advance_clock(timings.total)
                job.device_ids = tuple(slots)
                self.counters.inc("migrations")
                if self.tracer.enabled:
                    self.tracer.emit("job_migrate", t=self.now, job=job_id,
                                     from_node=node_id, moved=moved,
                                     overhead_s=timings.total)
            still = self.cluster.residents(node_id).get(job_id, 0)
            if still:
                target = job.spec.feasible(
                    max(job.spec.min_replicas, job.replicas - still))
                # only shrink when it clears the node: a partial shrink is a
                # live rescale thrown away by the requeue below
                if target < job.replicas and target <= job.replicas - still:
                    self._evict_prefer = node_id
                    try:
                        self.actions.shrink(job, target)
                    finally:
                        self._evict_prefer = None
            if self.cluster.residents(node_id).get(job_id, 0):
                # nowhere to go: requeue — deferring redistribution so the
                # freed slots aren't handed out before later residents get
                # their chance to migrate onto them
                self._fail_and_resubmit(job_id, redistribute=False)
                requeued += 1
        assert not self.cluster.residents(node_id)
        free = self.cluster.free_slots
        if requeued and free > 0:
            self.policy.on_job_complete(self.cluster, free, self.now,
                                        self.actions)
        self._record_util()

    # -- control loop -------------------------------------------------------------
    def _process_submissions(self):
        while self.pending and self.pending[0].spec.submit_time <= self.now:
            job = self.pending.pop(0)
            if job.job_id not in self.cluster.jobs:
                self.cluster.add_job(job)
            if job.job_id not in self._submitted:
                # failed jobs resubmit through this same path: one submit
                # record per job, so trace lifecycle counts reconcile
                self._submitted.add(job.job_id)
                if self.tracer.enabled:
                    self.tracer.emit("job_submit", t=self.now,
                                     job=job.job_id,
                                     priority=job.spec.priority,
                                     min=job.spec.min_replicas,
                                     max=job.spec.max_replicas)
            self.policy.on_new_job(self.cluster, job, self.now, self.actions)

    def _complete(self, job: JobState):
        freed = job.replicas
        self.cluster.release_slots(job.job_id)
        job.status = JobStatus.COMPLETED
        job.end_time = self.now
        job.replicas = 0
        self._record_util()
        self.counters.inc("completions")
        self.latency.observe_completed(job)
        if self.tracer.enabled:
            self.tracer.emit("job_complete", t=self.now, job=job.job_id,
                             slots=freed)
        self.policy.on_job_complete(self.cluster, freed, self.now, self.actions)

    def run(self, max_ticks: int = 1_000_000) -> ScheduleMetrics:
        if self.tracer.enabled and \
                getattr(self.policy, "decisions", None) is None:
            self.policy.decisions = DecisionLog(self.tracer)
        ticks = 0
        while ticks < max_ticks:
            ticks += 1
            self.counters.inc("ticks")
            self._process_submissions()
            running = [j for j in self.cluster.jobs.values()
                       if j.status == JobStatus.RUNNING]
            if not running:
                if self.pending:
                    # idle-advance to the next submission
                    self.advance_clock(
                        max(0.0, self.pending[0].spec.submit_time - self.now)
                        if self.step_time_fn else 0.0)
                    if self.step_time_fn is None:
                        self.now = max(self.now,
                                       self.pending[0].spec.submit_time)
                    continue
                break
            for job in running:
                live = self.live[job.job_id]
                for _ in range(self.steps_per_tick):
                    if live.trainer.done:
                        break
                    live.trainer.step()
                    dt = (self.step_time_fn(job) if self.step_time_fn
                          else 0.0)
                    self.advance_clock(dt)
                    ce = live.checkpoint_every
                    if (self.disk_store is not None and ce
                            and live.trainer.step_idx % ce == 0):
                        live.trainer.save_disk(self.disk_store, job.job_id)
                if live.trainer.done and job.status == JobStatus.RUNNING:
                    self._complete(job)
        metrics = compute_metrics(list(self.cluster.jobs.values()), self.util,
                                  latency=self.latency,
                                  counters=self.counters.as_dict())
        if self.tracer.enabled:
            # failed-and-never-restarted jobs live in self.pending, outside
            # cluster.jobs — reconcile drops against emitted submit records
            completes = self.counters.get("completions")
            self.tracer.emit("run_end", t=self.now, run=self.run_id,
                             total_cost=metrics.total_cost,
                             transfer_cost=metrics.transfer_cost,
                             preempt_overhead_cost=metrics.preempt_overhead_cost,
                             dropped=max(0, len(self._submitted) - completes),
                             rescales=metrics.rescale_count)
            self.tracer.flush()
        return metrics
