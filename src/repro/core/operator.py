"""ElasticClusterController — the Kubernetes-operator analog (paper C2).

Owns a pool of JAX devices partitioned into replica slots, runs the *same*
:class:`ElasticPolicy` as the simulator, but against live
:class:`ElasticTrainer` jobs: create/shrink/expand actually build meshes,
compile, and reshard training state.  The control loop is cooperative
(single-process): each tick advances every running job by ``steps_per_tick``
train steps — the scheduling observable is identical to running jobs in
parallel processes, which one CPU core cannot do honestly anyway.

Clocking: the controller's clock advances by each job-step's *modeled* wall
time when ``step_time_fn`` is given (so T_rescale_gap is meaningful in
simulated seconds) or by real wall time otherwise.

Fault tolerance (paper §3.2.2): ``inject_failure`` kills a running job; if a
disk checkpoint exists the job is resubmitted with the restart flag and
resumes from its last snapshot, otherwise it restarts from scratch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.checkpoint import DiskCheckpointStore
from repro.core.cluster import Cluster
from repro.core.elastic import ElasticTrainer, RescaleTimings, TrainJobConfig
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.metrics import ScheduleMetrics, UtilizationLog, compute_metrics
from repro.core.policies import Actions, ElasticPolicy, PolicyConfig


@dataclass
class LiveJob:
    state: JobState
    factory: Callable[[list], ElasticTrainer]   # devices -> trainer
    trainer: Optional[ElasticTrainer] = None
    checkpoint_every: int = 0                    # steps; 0 = off
    failures: int = 0


class _LiveActions(Actions):
    def __init__(self, op: "ElasticClusterController"):
        self.op = op

    def create(self, job: JobState, replicas: int) -> bool:
        op = self.op
        live = op.live[job.job_id]
        slots = op.cluster.allocate_slots(job.job_id, replicas)
        devices = op.cluster.devices_for_slots(slots)
        try:
            if live.trainer is None:
                live.trainer = live.factory(devices)
                if op.disk_store is not None and op.restart_flags.get(job.job_id):
                    try:
                        live.trainer.restore_disk(op.disk_store, job.job_id)
                    except FileNotFoundError:
                        pass
            else:   # queued job that had run before (preempted/restarted)
                live.trainer.rescale(devices)
        except Exception:
            op.cluster.release_slots(job.job_id)
            raise
        job.status = JobStatus.RUNNING
        job.replicas = replicas
        job.device_ids = tuple(slots)
        job.last_action = op.now
        if job.start_time is None:
            job.start_time = op.now
        op._record_util()
        return True

    def expand(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def shrink(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def _rescale(self, job: JobState, replicas: int) -> bool:
        op = self.op
        live = op.live[job.job_id]
        if replicas == job.replicas or live.trainer is None:
            return True
        if replicas > job.replicas:
            extra = replicas - job.replicas
            if extra > op.cluster.free_slots:
                return False
            op.cluster.allocate_slots(job.job_id, extra)
        else:
            op.cluster.release_slots(job.job_id, keep=replicas)
        slots = op.cluster.slots_of(job.job_id)
        devices = op.cluster.devices_for_slots(slots)
        timings = live.trainer.rescale(devices)
        op.rescale_events.append((op.now, job.job_id, job.replicas, replicas,
                                  timings))
        op.advance_clock(timings.total)
        job.replicas = replicas
        job.device_ids = tuple(slots)
        job.last_action = op.now
        job.rescale_count += 1
        op._record_util()
        return True

    def enqueue(self, job: JobState) -> None:
        job.status = JobStatus.QUEUED


class ElasticClusterController:
    def __init__(self, devices: list, *, slots: int, devices_per_slot: int = 1,
                 policy: PolicyConfig = PolicyConfig(rescale_gap=0.0),
                 disk_store: Optional[DiskCheckpointStore] = None,
                 step_time_fn: Optional[Callable[[JobState], float]] = None,
                 steps_per_tick: int = 1):
        self.cluster = Cluster(slots, devices, devices_per_slot)
        self.policy = ElasticPolicy(policy)
        self.actions = _LiveActions(self)
        self.live: Dict[str, LiveJob] = {}
        self.pending: List[JobState] = []
        self.disk_store = disk_store
        self.restart_flags: Dict[str, bool] = {}
        self.step_time_fn = step_time_fn
        self.steps_per_tick = steps_per_tick
        self.now = 0.0
        self._wall0 = time.perf_counter()
        self.util = UtilizationLog(slots)
        self.rescale_events: List[tuple] = []
        self.replica_trace: List[tuple] = []     # (t, job_id, replicas)

    # -- clock ----------------------------------------------------------------
    def advance_clock(self, dt: float):
        if self.step_time_fn is not None:
            self.now += dt
        else:
            self.now = time.perf_counter() - self._wall0

    def _record_util(self):
        self.util.record(self.now, self.cluster.used_slots)
        for j in self.cluster.jobs.values():
            self.replica_trace.append((self.now, j.job_id, j.replicas))

    # -- API --------------------------------------------------------------------
    def submit(self, spec: JobSpec, factory: Callable[[list], ElasticTrainer],
               checkpoint_every: int = 0, restart: bool = False):
        state = JobState(spec=spec)
        self.live[spec.job_id] = LiveJob(state=state, factory=factory,
                                         checkpoint_every=checkpoint_every)
        self.restart_flags[spec.job_id] = restart
        self.pending.append(state)
        self.pending.sort(key=lambda j: j.spec.submit_time)

    def inject_failure(self, job_id: str):
        """Kill a running job (node failure).  Resubmission goes through the
        normal newJob path with the restart flag set (paper §3.2.2)."""
        job = self.cluster.jobs[job_id]
        live = self.live[job_id]
        assert job.status == JobStatus.RUNNING
        self.cluster.release_slots(job_id)
        freed = job.replicas
        job.replicas = 0
        job.status = JobStatus.PENDING
        live.trainer = None          # process state lost
        live.failures += 1
        self.restart_flags[job_id] = True
        del self.cluster.jobs[job_id]
        self._record_util()
        # freed capacity is redistributed like a completion
        self.policy.on_job_complete(self.cluster, freed, self.now, self.actions)
        # resubmit immediately
        self.pending.append(job)
        self.pending.sort(key=lambda j: j.spec.submit_time)

    # -- control loop -------------------------------------------------------------
    def _process_submissions(self):
        while self.pending and self.pending[0].spec.submit_time <= self.now:
            job = self.pending.pop(0)
            if job.job_id not in self.cluster.jobs:
                self.cluster.add_job(job)
            self.policy.on_new_job(self.cluster, job, self.now, self.actions)

    def _complete(self, job: JobState):
        freed = job.replicas
        self.cluster.release_slots(job.job_id)
        job.status = JobStatus.COMPLETED
        job.end_time = self.now
        job.replicas = 0
        self._record_util()
        self.policy.on_job_complete(self.cluster, freed, self.now, self.actions)

    def run(self, max_ticks: int = 1_000_000) -> ScheduleMetrics:
        ticks = 0
        while ticks < max_ticks:
            ticks += 1
            self._process_submissions()
            running = [j for j in self.cluster.jobs.values()
                       if j.status == JobStatus.RUNNING]
            if not running:
                if self.pending:
                    # idle-advance to the next submission
                    self.advance_clock(
                        max(0.0, self.pending[0].spec.submit_time - self.now)
                        if self.step_time_fn else 0.0)
                    if self.step_time_fn is None:
                        self.now = max(self.now,
                                       self.pending[0].spec.submit_time)
                    continue
                break
            for job in running:
                live = self.live[job.job_id]
                for _ in range(self.steps_per_tick):
                    if live.trainer.done:
                        break
                    live.trainer.step()
                    dt = (self.step_time_fn(job) if self.step_time_fn
                          else 0.0)
                    self.advance_clock(dt)
                    ce = live.checkpoint_every
                    if (self.disk_store is not None and ce
                            and live.trainer.step_idx % ce == 0):
                        live.trainer.save_disk(self.disk_store, job.job_id)
                if live.trainer.done and job.status == JobStatus.RUNNING:
                    self._complete(job)
        return compute_metrics(list(self.cluster.jobs.values()), self.util)
