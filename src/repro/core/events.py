"""Tiny deterministic event queue (virtual or wall clock)."""
from __future__ import annotations

import heapq
import math
import itertools
from dataclasses import dataclass, field
from time import perf_counter
from typing import Any, Optional


#: default tiebreak: sorts AFTER any finite submit key, so the same-time
#: semantics are "arrivals first": every submit at time t is processed
#: before any other event at t (a tick or kill landing exactly on an
#: arrival timestamp sees that arrival).  Default-keyed events keep plain
#: insertion order among themselves.  Note this is a (deliberate) semantic
#: change from the pre-tiebreak seq-only ordering in the rare case where a
#: non-submit event was pushed before submit() was called with the same
#: timestamp (e.g. the autoscaler's t=0 bootstrap tick now runs after t=0
#: arrivals instead of seeing an empty queue).
_LAST = (math.inf,)


@dataclass(order=True)
class Event:
    time: float
    # orders same-time events BEFORE insertion order.  Simulator.submit
    # passes (-priority, job_id) so bursty arrivals that collapse onto one
    # timestamp process in a canonical order no matter the order submit()
    # was called in (trace replay is insertion-agnostic); every other event
    # kind keeps plain insertion order via the _LAST sentinel.
    tiebreak: tuple = field(default=_LAST)
    seq: int = 0
    kind: str = field(compare=False, default="")
    payload: Any = field(compare=False, default=None)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._count = itertools.count()
        # optional repro.obs.profile.SimProfiler: the owning simulator wires
        # its profiler in so heap pushes show up as a "heap_push" section
        self.profiler = None

    def push(self, time: float, kind: str, payload: Any = None,
             tiebreak: tuple = _LAST) -> Event:
        ev = Event(time, tiebreak, next(self._count), kind, payload)
        prof = self.profiler
        if prof is None:
            heapq.heappush(self._heap, ev)
        else:
            t0 = perf_counter()
            heapq.heappush(self._heap, ev)
            prof.section("heap_push", perf_counter() - t0)
        return ev

    def pop(self) -> Optional[Event]:
        return heapq.heappop(self._heap) if self._heap else None

    def peek_time(self) -> Optional[float]:
        return self._heap[0].time if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)
