"""Tiny deterministic event queue (virtual or wall clock)."""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._count = itertools.count()

    def push(self, time: float, kind: str, payload: Any = None) -> Event:
        ev = Event(time, next(self._count), kind, payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Optional[Event]:
        return heapq.heappop(self._heap) if self._heap else None

    def peek_time(self) -> Optional[float]:
        return self._heap[0].time if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)
