"""Tiny deterministic event queue (virtual or wall clock).

Hot-path notes (the fleet-scale refactor): :class:`Event` is a plain
``__slots__`` class with a hand-rolled ``__lt__`` (a dataclass with
``order=True`` builds a comparison tuple per heap sift), the queue can
drain every event sharing the earliest timestamp in one pass
(:meth:`EventQueue.pop_batch`), and events invalidated by a rescale can be
:meth:`cancelled <EventQueue.cancel>` in place — the heap drops the
tombstone at pop time for the cost of one attribute check instead of a
full dispatch.  ``stale_dropped`` counts those drops (surfaced as the
``stale_events`` counter): how much dead weight the heap carried.
"""
from __future__ import annotations

import heapq
import math
import itertools
from time import perf_counter
from typing import Any, List, Optional

#: default tiebreak: sorts AFTER any finite submit key, so the same-time
#: semantics are "arrivals first": every submit at time t is processed
#: before any other event at t (a tick or kill landing exactly on an
#: arrival timestamp sees that arrival).  Default-keyed events keep plain
#: insertion order among themselves.  Note this is a (deliberate) semantic
#: change from the pre-tiebreak seq-only ordering in the rare case where a
#: non-submit event was pushed before submit() was called with the same
#: timestamp (e.g. the autoscaler's t=0 bootstrap tick now runs after t=0
#: arrivals instead of seeing an empty queue).
_LAST = (math.inf,)

#: kind a cancelled (tombstoned) event carries while it waits in the heap
_CANCELLED = "__cancelled__"


class Event:
    __slots__ = ("time", "tiebreak", "seq", "kind", "payload")

    def __init__(self, time: float, tiebreak: tuple = _LAST, seq: int = 0,
                 kind: str = "", payload: Any = None):
        self.time = time
        # orders same-time events BEFORE insertion order.  Simulator.submit
        # passes (-priority, job_id) so bursty arrivals that collapse onto one
        # timestamp process in a canonical order no matter the order submit()
        # was called in (trace replay is insertion-agnostic); every other
        # event kind keeps plain insertion order via the _LAST sentinel.
        self.tiebreak = tiebreak
        self.seq = seq
        self.kind = kind
        self.payload = payload

    def __lt__(self, other: "Event") -> bool:
        if self.time != other.time:
            return self.time < other.time
        if self.tiebreak != other.tiebreak:
            return self.tiebreak < other.tiebreak
        return self.seq < other.seq

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Event(t={self.time}, kind={self.kind!r}, seq={self.seq}, "
                f"payload={self.payload!r})")


class EventQueue:
    def __init__(self):
        self._heap: List[Event] = []
        self._count = itertools.count()
        self._cancelled = 0           # tombstones still sitting in the heap
        #: cancelled events silently dropped at pop time so far
        self.stale_dropped = 0
        # optional repro.obs.profile.SimProfiler: the owning simulator wires
        # its profiler in so heap pushes show up as a "heap_push" section
        self.profiler = None

    def push(self, time: float, kind: str, payload: Any = None,
             tiebreak: tuple = _LAST) -> Event:
        ev = Event(time, tiebreak, next(self._count), kind, payload)
        prof = self.profiler
        if prof is None:
            heapq.heappush(self._heap, ev)
        else:
            t0 = perf_counter()
            heapq.heappush(self._heap, ev)
            prof.section("heap_push", perf_counter() - t0)
        return ev

    def cancel(self, ev: Event) -> None:
        """Invalidate an event in place (O(1)); the heap drops it at pop
        time for one attribute check instead of a full dispatch.  Safe on an
        already-popped event (the tombstone is simply never seen again)."""
        if ev.kind is not _CANCELLED:
            ev.kind = _CANCELLED
            self._cancelled += 1

    def _popped(self, ev: Event) -> None:
        """A cancelled event left the heap without being delivered."""
        self._cancelled -= 1
        self.stale_dropped += 1

    def pop(self) -> Optional[Event]:
        heap = self._heap
        while heap:
            ev = heapq.heappop(heap)
            if ev.kind is not _CANCELLED:
                return ev
            self._popped(ev)
        return None

    def pop_batch(self, out: List[Event]) -> int:
        """Drain every live event sharing the earliest timestamp into
        ``out`` (cleared first), preserving heap order; returns the count.
        One heap pass per *timestamp* instead of per event lets the
        simulator run its per-timestamp bookkeeping once per batch."""
        out.clear()
        heap = self._heap
        while heap:
            ev = heapq.heappop(heap)
            if ev.kind is _CANCELLED:
                self._popped(ev)
                continue
            out.append(ev)
            t = ev.time
            while heap and heap[0].time == t:
                ev = heapq.heappop(heap)
                if ev.kind is _CANCELLED:
                    self._popped(ev)
                else:
                    out.append(ev)
            break
        return len(out)

    def peek_time(self) -> Optional[float]:
        heap = self._heap
        while heap and heap[0].kind is _CANCELLED:
            self._popped(heapq.heappop(heap))
        return heap[0].time if heap else None

    @property
    def stale_total(self) -> int:
        """Stale (cancelled) events this queue ever carried: tombstones
        already dropped plus those still waiting in the heap — the
        ``stale_events`` counter at run end."""
        return self.stale_dropped + self._cancelled

    def __len__(self) -> int:
        """Live (non-cancelled) events still queued."""
        return len(self._heap) - self._cancelled
