"""Job model: spec (user-provided) + state (scheduler-owned).

Priority semantics (paper §3.2.1): larger integer = more important; ties are
FCFS by submission time.  ``sort_key`` orders decreasing priority.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional


class JobStatus(enum.Enum):
    PENDING = "pending"        # submitted, not yet scheduled
    QUEUED = "queued"          # could not start; in the internal priority queue
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"


@dataclass(frozen=True)
class JobSpec:
    job_id: str
    priority: int
    min_replicas: int
    max_replicas: int
    submit_time: float = 0.0
    # workload description — consumed by the perf model (simulator) or by the
    # live runtime (arch/config/steps for a real training job).
    workload: Any = None
    # SPMD feasibility (DESIGN.md §2): live training jobs keep a fixed global
    # batch, so the replica count must divide it.  None = unconstrained
    # (the paper's Charm++ jobs accept any count via overdecomposition).
    divides: Optional[int] = None

    def __post_init__(self):
        assert 1 <= self.min_replicas <= self.max_replicas, self
        if self.divides is not None:
            assert self.feasible(self.min_replicas) == self.min_replicas, \
                f"min_replicas must divide {self.divides}"
            assert self.feasible(self.max_replicas) == self.max_replicas, \
                f"max_replicas must divide {self.divides}"

    def feasible(self, replicas: int) -> int:
        """Largest feasible replica count <= requested (0 if none)."""
        r = min(replicas, self.max_replicas)
        if self.divides is None:
            return r
        while r >= 1 and self.divides % r:
            r -= 1
        return r

    def rigid(self, replicas: int) -> "JobSpec":
        """Paper §4.3.2: rigid schedulers are emulated by min==max."""
        return replace(self, min_replicas=replicas, max_replicas=replicas)


@dataclass
class JobState:
    spec: JobSpec
    status: JobStatus = JobStatus.PENDING
    replicas: int = 0
    # time of the last scheduling action on this job (T_rescale_gap anchor);
    # queued/pending jobs always pass the gap check (paper Fig. 3 hands slots
    # to queued jobs regardless of how recently they were enqueued).
    last_action: float = -math.inf
    start_time: Optional[float] = None      # first time it got resources
    end_time: Optional[float] = None
    # simulator bookkeeping
    work_remaining: float = 0.0
    last_progress_time: float = 0.0
    overhead_until: float = 0.0
    rescale_count: int = 0
    preempt_count: int = 0
    version: int = 0                        # invalidates stale events
    device_ids: tuple = ()                  # live runtime: allocated devices

    #: observer wired by Cluster.add_job so status/replicas transitions keep
    #: the cluster's incremental accounting (used-slot sum, priority-ordered
    #: schedulable list) in sync without per-query scans.  None (the class
    #: default) on free-standing JobStates: transitions are then plain field
    #: writes, so tests poking at un-added jobs see unchanged behavior.
    _watch = None

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    @property
    def priority(self) -> int:
        return self.spec.priority

    #: cached sort_key tuple — the spec is frozen, so the key never changes
    _key = None

    def sort_key(self):
        """Sorts DECREASING priority; FCFS within a priority level."""
        k = self._key
        if k is None:
            spec = self.spec
            k = self._key = (-spec.priority, spec.submit_time, spec.job_id)
        return k

    def higher_priority_than(self, other: "JobState") -> bool:
        """Strict user-priority comparison (paper's shrink-loop guard uses the
        raw priority field only; FCFS ties do not protect from shrinking)."""
        return self.spec.priority > other.spec.priority


def _watched(name: str):
    """Build a watched property for a JobState field: plain attribute
    semantics, plus a change notification to ``job._watch`` (the owning
    cluster) when one is attached.  Installed AFTER the @dataclass decorator
    runs so the generated ``__init__``/``repr``/``eq`` assign and read
    through it transparently."""
    priv = "_" + name

    def _get(self):
        return self.__dict__[priv]

    def _set(self, value):
        d = self.__dict__
        old = d.get(priv)
        d[priv] = value
        w = self._watch
        if w is not None and old != value:
            w._job_changed(self, name, old, value)

    return property(_get, _set, doc=f"watched dataclass field {name!r}")


JobState.status = _watched("status")
JobState.replicas = _watched("replicas")


def response_time(job: JobState) -> Optional[float]:
    if job.start_time is None:
        return None
    return job.start_time - job.spec.submit_time


def completion_time(job: JobState) -> Optional[float]:
    if job.end_time is None:
        return None
    return job.end_time - job.spec.submit_time
