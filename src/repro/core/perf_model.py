"""Performance models consumed by the simulator (paper C3).

The paper models (a) job runtime vs. replicas via piecewise-linear
interpolation of measured strong-scaling points and (b) rescale overhead via
piecewise-linear interpolation of measured stage times.  We provide:

- :class:`PiecewiseScalingModel` — exactly that interpolation, given points;
- :class:`JacobiModel` — analytic Jacobi2D strong-scaling generator (compute
  n^2/p, halo n/sqrt(p), latency) used to synthesize the measurement points we
  cannot take on EKS (DESIGN.md §6.4), calibrated to the paper's Table 1
  magnitudes;
- :class:`RescaleModel` — the four-stage overhead (checkpoint/restart/restore/
  load-balance) with the paper's observed asymptotics (Fig. 5): restart grows
  with replica count, checkpoint/restore scale with per-replica bytes,
  load-balance is flat in replicas and grows with problem size;
- :class:`ArchScalingModel` — step time of one of *this framework's* training
  jobs vs. number of 16-chip replica groups, derived from dry-run roofline
  terms (ties C3 to the TPU substrate).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple


def interp_piecewise(points: Sequence[Tuple[float, float]], x: float) -> float:
    """Piecewise-linear interpolation with flat extrapolation."""
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    if x <= xs[0]:
        return ys[0]
    if x >= xs[-1]:
        return ys[-1]
    i = bisect.bisect_right(xs, x)
    x0, x1, y0, y1 = xs[i - 1], xs[i], ys[i - 1], ys[i]
    return y0 + (y1 - y0) * (x - x0) / (x1 - x0)


@dataclass(frozen=True)
class PiecewiseScalingModel:
    """time-per-work-unit as piecewise-linear in replica count."""
    points: Tuple[Tuple[float, float], ...]   # (replicas, seconds/unit)

    def time_per_unit(self, replicas: int) -> float:
        # replica counts are small ints and the model is frozen, so every
        # lookup after the first is a dict hit (this sits under every
        # completion-time estimate the simulator makes)
        try:
            memo = self._memo
        except AttributeError:
            memo = {}
            object.__setattr__(self, "_memo", memo)
        y = memo.get(replicas)
        if y is None:
            xs = [p[0] for p in self.points]
            ys = [p[1] for p in self.points]
            x = float(replicas)
            if x <= xs[0]:
                y = ys[0]
            elif x >= xs[-1]:
                y = ys[-1]
            else:
                i = bisect.bisect_right(xs, x)
                x0, x1, y0, y1 = xs[i - 1], xs[i], ys[i - 1], ys[i]
                y = y0 + (y1 - y0) * (x - x0) / (x1 - x0)
            memo[replicas] = y
        return y

    # simulator-facing alias: one work unit == one step
    def time_per_step(self, replicas: int) -> float:
        return self.time_per_unit(replicas)

    def rate(self, replicas: int) -> float:
        return 1.0 / self.time_per_unit(replicas)


# ---------------------------------------------------------------------------
# Jacobi2D (the paper's workload)
# ---------------------------------------------------------------------------

# calibration constants (DESIGN.md §6.4): chosen so the Table 1 experiment
# (64 slots, 16 jobs, 90 s submission gap) lands in the paper's magnitude
# range (makespans ~1800-2500 s).
FLOP_PER_POINT = 5.0
EFF_FLOPS_PER_REPLICA = 1.0e9      # effective stencil rate per vCPU-replica
HALO_BYTES_PER_POINT = 16.0
NET_BW = 1.0e8                     # bytes/s per replica pair (EKS TCP-ish)
NET_LAT = 5.0e-4


@dataclass(frozen=True)
class JacobiModel:
    grid_n: int
    timesteps: int

    def time_per_step(self, replicas: int) -> float:
        p = max(1, replicas)
        n = self.grid_n
        compute = FLOP_PER_POINT * n * n / p / EFF_FLOPS_PER_REPLICA
        halo = HALO_BYTES_PER_POINT * n / math.sqrt(p) / NET_BW
        return compute + halo + NET_LAT

    def scaling_model(self, replica_grid: Sequence[int]
                      ) -> PiecewiseScalingModel:
        """Synthesize the 'measured' strong-scaling points the paper would
        have interpolated (Fig. 4a)."""
        return PiecewiseScalingModel(tuple(
            (float(r), self.time_per_step(r)) for r in replica_grid))

    @property
    def data_bytes(self) -> float:
        return 2 * 4.0 * self.grid_n * self.grid_n   # two fp32 grids


# the paper's four simulated job sizes (§4.3.1)
JACOBI_SIZES: Dict[str, dict] = {
    "small": dict(grid_n=512, timesteps=40_000, min_replicas=2, max_replicas=8),
    "medium": dict(grid_n=2048, timesteps=40_000, min_replicas=4, max_replicas=16),
    "large": dict(grid_n=8192, timesteps=40_000, min_replicas=8, max_replicas=32),
    "xlarge": dict(grid_n=16_384, timesteps=10_000, min_replicas=16, max_replicas=64),
}


# ---------------------------------------------------------------------------
# Rescale overhead (paper Fig. 5 asymptotics)
# ---------------------------------------------------------------------------

RESTART_BASE = 1.0                 # process-group restart floor
RESTART_PER_REPLICA = 0.08         # MPI startup grows with ranks
CKPT_BW_PER_REPLICA = 2.0e9        # /dev/shm write bandwidth per replica
RESTORE_BW_PER_REPLICA = 3.0e9
LB_BASE = 0.3
LB_PER_BYTE = 5.0e-11              # object migration grows with problem size
DISK_BW_PER_REPLICA = 2.0e8        # preemption checkpoints go to DISK (§3.2.2)

# -- fast lane (README §Checkpoint fast lane) -------------------------------
# Constants grounded by the slow-lane `fig5.live.*` / `fig5.kernel.*` rows
# (benchmarks/fig5_rescale_overhead.py): P2P reshard is one device_put with
# no host round-trip, warm restart is a mesh-cache hit instead of a re-jit,
# load-balance is the measured microseconds-scale shard_bounds re-split,
# preempt overlaps the write (async submit + barrier) and only rewrites the
# hot fraction of the tree (delta manifest), resume pipelines the restart
# with the disk read.
P2P_RESHARD_BW_PER_REPLICA = 2.5e10   # device-to-device, no host bounce
RESTART_WARM_BASE = 0.15              # cached-mesh restart floor
RESTART_WARM_PER_REPLICA = 0.01
LB_FAST_BASE = 0.02                   # stream re-split, no object migration
LB_FAST_PER_BYTE = 5.0e-12
ASYNC_BARRIER_S = 0.05                # join of the in-flight background write
DELTA_CKPT_FRACTION = 0.35            # hot-leaf share of the tree (measured)


@dataclass(frozen=True)
class RescaleModel:
    """Four-stage rescale overhead; ``stages`` returns the Fig. 5 breakdown.

    ``fast_lane=True`` (the default) prices the checkpoint/reshard fast
    path: P2P device-to-device reshard (no host snapshot), warm restarts
    from the mesh cache, async+delta disk checkpoints at preempt time.
    ``RescaleModel(fast_lane=False)`` reproduces the legacy (paper-faithful
    synchronous) cost model exactly.
    """
    fast_lane: bool = True

    def stages(self, old_replicas: int, new_replicas: int,
               data_bytes: float) -> Dict[str, float]:
        if self.fast_lane:
            return {
                "load_balance": LB_FAST_BASE + LB_FAST_PER_BYTE * data_bytes,
                # P2P reshard: no host snapshot; the move is billed as
                # restore (one device_put off the old shards)
                "checkpoint": 0.0,
                "restart": (RESTART_WARM_BASE
                            + RESTART_WARM_PER_REPLICA * new_replicas),
                "restore": data_bytes / (P2P_RESHARD_BW_PER_REPLICA
                                         * max(1, old_replicas)),
            }
        return {
            # shrink load-balances before ckpt/restart, expand after (§2.2) —
            # cost model identical either way
            "load_balance": LB_BASE + LB_PER_BYTE * data_bytes,
            "checkpoint": data_bytes / (CKPT_BW_PER_REPLICA * old_replicas),
            "restart": RESTART_BASE + RESTART_PER_REPLICA * new_replicas,
            "restore": data_bytes / (RESTORE_BW_PER_REPLICA * new_replicas),
        }

    def total(self, old_replicas: int, new_replicas: int,
              data_bytes: float) -> float:
        return sum(self.stages(old_replicas, new_replicas, data_bytes).values())

    def preempt_cost(self, replicas: int, data_bytes: float) -> float:
        """Checkpoint-to-disk on preemption (paper §3.2.2).

        Fast lane: the write already started in the background (async
        submit); preempt pays the barrier plus the unwritten hot fraction
        (delta manifest skips cold leaves)."""
        full = data_bytes / (DISK_BW_PER_REPLICA * max(1, replicas))
        if self.fast_lane:
            return ASYNC_BARRIER_S + DELTA_CKPT_FRACTION * full
        return full

    def resume_cost(self, replicas: int, data_bytes: float) -> float:
        """Restart + restore-from-disk when a preempted job resumes.

        Fast lane: warm restart pipelined with the disk read (the read
        dominates for real payloads), so the two overlap instead of adding.
        """
        read = data_bytes / (DISK_BW_PER_REPLICA * max(1, replicas))
        if self.fast_lane:
            return max(RESTART_WARM_BASE + RESTART_WARM_PER_REPLICA * replicas,
                       read)
        return RESTART_BASE + RESTART_PER_REPLICA * replicas + read


# ---------------------------------------------------------------------------
# TPU training jobs (ties the scheduler to this framework's archs)
# ---------------------------------------------------------------------------

V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9
CHIPS_PER_REPLICA = 16             # one model-parallel group (DESIGN.md §2)


@dataclass(frozen=True)
class ArchScalingModel:
    """Step time vs. replica-group count for a data-parallel training job.

    flops_per_step_per_replica: model FLOPs for one replica's batch shard at
    1 group (strong scaling: global batch fixed).  Derived either analytically
    (6*N*D) or from dry-run cost analysis. mfu: sustained fraction of peak.
    """
    name: str
    flops_per_step: float          # global-batch fwd+bwd FLOPs
    param_bytes: float             # gradient all-reduce payload
    mfu: float = 0.4

    def time_per_step(self, groups: int) -> float:
        compute = self.flops_per_step / (
            groups * CHIPS_PER_REPLICA * V5E_PEAK_FLOPS * self.mfu)
        # data-parallel gradient ring all-reduce across groups
        if groups > 1:
            comm = 2 * self.param_bytes * (groups - 1) / groups / (
                CHIPS_PER_REPLICA * V5E_ICI_BW)
        else:
            comm = 0.0
        return compute + max(comm, 0.0)

    @property
    def data_bytes(self) -> float:
        # checkpoint payload: params + fp32 adam moments
        return self.param_bytes * (1 + 4)


def arch_model_from_config(cfg, seq_len: int = 4096,
                           global_batch: int = 256) -> ArchScalingModel:
    from repro.configs.base import count_active_params, count_params
    n_active = count_active_params(cfg)
    n_total = count_params(cfg)
    tokens = seq_len * global_batch
    return ArchScalingModel(
        name=cfg.name,
        flops_per_step=6.0 * n_active * tokens,
        param_bytes=2.0 * n_total,
    )
