"""Beyond-paper scheduling extensions.

The paper explicitly defers these (§3.2.2 Discussion, §6 Future work); we
implement them as policy subclasses so every variant runs in both the
simulator and the live operator:

- :class:`AgingPolicy` — "a dynamic priority system could be implemented to
  gradually increase the priority of waiting jobs" (§3.2.2).  Effective
  priority = priority + age_rate * queue_wait.  Bounds starvation of
  low-priority jobs under heavy traffic (property-tested).
- :class:`CostBenefitPolicy` — "we do not consider the cost versus the
  potential benefit of rescaling" (§6).  Expansion is granted only if the
  modeled runtime saving over the job's remaining work exceeds
  ``benefit_margin`` x the modeled rescale overhead; shrinking a job with less
  than ``protect_tail`` of its work remaining is declined (the application-
  declines-rescale protocol of §6, folded into the scheduler using the same
  perf models the simulator trusts).
- :class:`PreemptingPolicy` — "lower-priority jobs could be sent a signal to
  checkpoint to disk and then be preempted" (§3.2.2).  When shrinking
  everything to min still cannot start a higher-priority job, the lowest-
  priority running jobs are checkpointed and requeued (they resume later with
  their progress intact); requires an :class:`Actions` implementation with
  ``preempt``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.cluster import Cluster
from repro.core.job import JobState, JobStatus
from repro.core.policies import Actions, ElasticPolicy, PolicyConfig


class AgingPolicy(ElasticPolicy):
    def __init__(self, cfg: PolicyConfig, *, age_rate: float = 1.0 / 600.0,
                 max_boost: float = 4.0):
        super().__init__(cfg)
        self.age_rate = age_rate
        self.max_boost = max_boost

    def _priority(self, job: JobState, now: float) -> float:
        base = float(job.spec.priority)
        if job.status in (JobStatus.QUEUED, JobStatus.PENDING):
            wait = max(0.0, now - job.spec.submit_time)
            return base + min(self.max_boost, self.age_rate * wait)
        return base


class CostBenefitPolicy(ElasticPolicy):
    """workload_fn(job) must return an object with .scaling.time_per_step,
    .data_bytes, .rescale (the simulator's SimWorkload fits directly)."""

    def __init__(self, cfg: PolicyConfig, workload_fn: Callable,
                 *, benefit_margin: float = 1.0, protect_tail: float = 0.05):
        super().__init__(cfg)
        self.workload_fn = workload_fn
        self.benefit_margin = benefit_margin
        self.protect_tail = protect_tail

    def _should_expand(self, job: JobState, new_replicas: int, now: float
                       ) -> bool:
        if self.sync_job is not None:   # lazy sync: bring work_remaining to
            self.sync_job(job)          # `now` only where it is actually read
        wl = self.workload_fn(job)
        t_old = wl.scaling.time_per_step(job.replicas)
        t_new = wl.scaling.time_per_step(new_replicas)
        benefit = job.work_remaining * max(0.0, t_old - t_new)
        cost = wl.rescale.total(job.replicas, new_replicas, wl.data_bytes)
        return benefit > self.benefit_margin * cost

    def _should_shrink(self, job: JobState, new_replicas: int, now: float
                       ) -> bool:
        if self.sync_job is not None:
            self.sync_job(job)
        wl = self.workload_fn(job)
        if wl.total_work > 0 and \
                job.work_remaining / wl.total_work < self.protect_tail:
            return False    # nearly done: let it finish (§6)
        return True


class PreemptingPolicy(ElasticPolicy):
    """Adds disk-checkpoint preemption as the last resort of Fig. 2."""

    def on_new_job(self, cluster: Cluster, job: JobState, now: float,
                   act: Actions) -> None:
        super().on_new_job(cluster, job, now, act)
        if job.status != JobStatus.QUEUED:
            return
        if not hasattr(act, "preempt"):
            return
        # preempt strictly-lower-priority running jobs, lowest first, until
        # the new job can start at min_replicas
        needed = job.spec.min_replicas - self._avail(cluster)
        if needed <= 0:
            return
        considered = [] if self.decisions is not None else None
        victims = []
        for j in reversed(self._sorted_desc(cluster.running_jobs(), now)):
            if self._priority(j, now) >= self._priority(job, now):
                if considered is not None:
                    considered.append({"job": j.job_id, "eligible": False,
                                       "why": "priority_ceiling"})
                break
            victims.append(j)
            if considered is not None:
                considered.append({"job": j.job_id, "eligible": True,
                                   "slots": j.replicas,
                                   "priority": j.spec.priority})
            needed -= j.replicas
            if needed <= 0:
                break
        if needed > 0:
            if self.decisions is not None:
                self.decisions.record(
                    "preempt_select", now, "insufficient",
                    inputs={"job": job.spec.job_id, "short": needed},
                    alternatives=considered)
            return      # even preempting everything lower wouldn't fit
        for v in victims:
            act.preempt(v)
        free = self._avail(cluster)
        replicas = job.spec.feasible(min(free, job.spec.max_replicas))
        started = False
        if replicas >= job.spec.min_replicas:
            started = act.create(job, replicas)
            # on failure the job simply stays QUEUED for redistribution
        if self.decisions is not None:
            self.decisions.record(
                "preempt_select", now,
                "preempted_started" if started else "preempted_queued",
                inputs={"job": job.spec.job_id,
                        "victims": [v.job_id for v in victims],
                        "granted": replicas if started else 0},
                alternatives=considered)
