"""ElasticTrainer — live shrink/expand of a JAX training job (paper C1).

A job runs on a dynamic set of devices arranged as a ``(data=R, model=M)``
mesh; the elastic axis is ``data`` (R = replicas, the scheduler's slot count).
Rescaling follows the paper's four stages and reports the same breakdown as
paper Fig. 5:

    load_balance  re-split the fixed global batch / data stream over the new
                  replica set (exact for SPMD — DESIGN.md §2b)
    checkpoint    device -> host-RAM snapshot (the /dev/shm analog)
    restart       build the new mesh + re-jit (lower+compile) the train step
                  (the MPI process-group restart analog; grows with scale)
    restore       host snapshot -> device arrays under the new shardings

The beyond-paper fast path (``via_host=False``) reshards device-to-device with
a single ``jax.device_put`` and skips the host round-trip; §Perf quantifies
the difference.  Training state is ``(params, opt_state, step)``; the data
pipeline is deterministic in ``(seed, step)`` so a rescaled run reproduces the
static run's loss trajectory (pinned by tests).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import (AsyncCheckpointer, MemoryCheckpointStore,
                              device_reshard, restore_from_host,
                              snapshot_to_host, surviving_devices,
                              unflatten_tree)
from repro.configs.base import ModelConfig
from repro.data import make_stream
from repro.models import model as M
from repro.optim import (AdamWConfig, adamw_init, adamw_update, opt_logical_axes,
                         warmup_cosine)
from repro.sharding import AxisRules, RULE_SETS, axis_rules, make_param_shardings


@dataclass
class RescaleTimings:
    load_balance: float = 0.0
    checkpoint: float = 0.0
    restart: float = 0.0
    restore: float = 0.0
    path: str = "host"          # "p2p" (device-to-device) or "host"

    @property
    def total(self) -> float:
        return self.load_balance + self.checkpoint + self.restart + self.restore

    def as_dict(self) -> Dict[str, float]:
        # numeric-only: consumers format every value as seconds
        return {"load_balance": self.load_balance, "checkpoint": self.checkpoint,
                "restart": self.restart, "restore": self.restore,
                "total": self.total}


@dataclass
class TrainJobConfig:
    global_batch: int = 8
    seq_len: int = 32
    total_steps: int = 50
    model_axis: int = 1
    rules: str = "tp"
    peak_lr: float = 3e-3
    warmup_steps: int = 10
    seed: int = 0
    dtype: str = "float32"


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, job: TrainJobConfig,
                 devices: Sequence):
        self.cfg = cfg.with_(dtype=job.dtype)
        self.job = job
        self.step_idx = 0
        self.stream = make_stream(self.cfg, seed=job.seed,
                                  global_batch=job.global_batch,
                                  seq_len=job.seq_len)
        self.adamw = AdamWConfig()
        self.metrics_log: List[dict] = []
        self.rescale_log: List[RescaleTimings] = []
        self._lr_fn = lambda s: warmup_cosine(
            s, peak_lr=job.peak_lr, warmup_steps=job.warmup_steps,
            total_steps=job.total_steps)

        # initial "restart" (mesh + compile) and state init
        t0 = time.perf_counter()
        self._mesh_cache: Dict[tuple, dict] = {}
        self._async_ckpt: Optional[AsyncCheckpointer] = None
        self.validate_devices(devices)
        self._ensure_mesh(devices)
        key = jax.random.PRNGKey(job.seed)
        with axis_rules(self.rules):
            self.params = jax.jit(
                lambda k: M.init_params(self.cfg, k),
                out_shardings=self._param_sh)(key)
            self.opt_state = jax.jit(
                adamw_init, out_shardings=self._opt_sh)(self.params)
        self._compile()
        self._mesh_cache[self._mesh_key(devices)]["compiled"] = self._compiled
        self.startup_time = time.perf_counter() - t0

    # -- mesh / sharding ------------------------------------------------------
    @property
    def replicas(self) -> int:
        return self.mesh.shape["data"]

    def validate_devices(self, devices: Sequence) -> int:
        """Check a target device set BEFORE any rescale stage runs.

        An indivisible global_batch/replica combination used to surface as a
        bare AssertionError from ``_build_mesh`` — after the checkpoint stage
        had already burned a full snapshot.  Returns the replica count."""
        devices = list(devices)
        m = self.job.model_axis
        if not devices:
            raise ValueError("rescale target has no devices")
        if len(devices) % m != 0:
            raise ValueError(
                f"{len(devices)} devices not divisible by model_axis {m}")
        r = len(devices) // m
        if self.job.global_batch % r != 0:
            raise ValueError(
                f"global_batch {self.job.global_batch} not divisible by "
                f"{r} replicas")
        return r

    @staticmethod
    def _mesh_key(devices: Sequence) -> tuple:
        return tuple(d.id for d in devices)

    def _ensure_mesh(self, devices: Sequence) -> bool:
        """Build (or restore from cache) mesh/shardings for ``devices``.

        Returns True on a cache hit — a previously-visited device set skips
        the re-jit entirely, which is what makes repeated shrink⇄expand
        oscillation cheap (the 'warm restart' the fast-lane perf model
        prices)."""
        key = self._mesh_key(devices)
        cached = self._mesh_cache.get(key)
        if cached is not None and cached.get("compiled") is not None:
            for attr, v in cached.items():
                if attr != "compiled":
                    setattr(self, attr, v)
            self._compiled = cached["compiled"]
            return True
        self._build_mesh(devices)
        self._mesh_cache[key] = {
            "devices": self.devices, "mesh": self.mesh, "rules": self.rules,
            "_param_sh": self._param_sh, "_opt_sh": self._opt_sh,
            "_batch_sh": self._batch_sh, "_scalar_sh": self._scalar_sh,
            "compiled": None}
        return False

    def _build_mesh(self, devices: Sequence):
        devices = list(devices)
        m = self.job.model_axis
        assert len(devices) % m == 0, (len(devices), m)
        r = len(devices) // m
        assert self.job.global_batch % r == 0, \
            f"global_batch {self.job.global_batch} not divisible by {r} replicas"
        self.devices = devices
        self.mesh = Mesh(np.array(devices).reshape(r, m), ("data", "model"))
        self.rules = AxisRules(self.mesh, RULE_SETS[self.job.rules]())
        axes = M.logical_axes(self.cfg)
        abstract_p = M.abstract_params(self.cfg)
        from repro.optim import abstract_opt_state
        self._param_sh = make_param_shardings(self.rules, axes, abstract_p)
        self._opt_sh = make_param_shardings(self.rules, opt_logical_axes(axes),
                                            abstract_opt_state(abstract_p))
        self._batch_sh = {
            k: NamedSharding(self.mesh, P("data", *([None] * (v.ndim - 1))))
            for k, v in self._abstract_batch().items()}
        self._scalar_sh = NamedSharding(self.mesh, P())

    def _abstract_batch(self) -> dict:
        B, S = self.job.global_batch, self.job.seq_len
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if self.cfg.enc_layers:
            d["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S, self.cfg.d_model), jnp.float32)
        return d

    # -- train step -----------------------------------------------------------
    def _step_fn(self, params, opt_state, batch, step):
        def lf(p):
            return M.loss_fn(self.cfg, p, batch)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        lr = self._lr_fn(step)
        params, opt_state, om = adamw_update(self.adamw, grads, opt_state,
                                             params, lr)
        metrics = dict(metrics, **om)
        return params, opt_state, metrics

    def _compile(self):
        """The 'restart' stage: jit + AOT compile for the current mesh."""
        with axis_rules(self.rules):
            jitted = jax.jit(
                self._step_fn,
                in_shardings=(self._param_sh, self._opt_sh, self._batch_sh,
                              self._scalar_sh),
                donate_argnums=(0, 1))
            abstract_p = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                jax.eval_shape(lambda: self.params))
            abstract_o = jax.eval_shape(lambda: self.opt_state)
            self._compiled = jitted.lower(
                abstract_p, abstract_o, self._abstract_batch(),
                jax.ShapeDtypeStruct((), jnp.int32)).compile()

    # -- public API -------------------------------------------------------------
    def step(self) -> dict:
        batch_np = self.stream.global_batch_at(self.step_idx)
        batch = {k: jax.device_put(v, self._batch_sh[k])
                 for k, v in batch_np.items()}
        step_arr = jax.device_put(jnp.asarray(self.step_idx, jnp.int32),
                                  self._scalar_sh)
        self.params, self.opt_state, metrics = self._compiled(
            self.params, self.opt_state, batch, step_arr)
        self.step_idx += 1
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step"] = self.step_idx
        metrics["replicas"] = self.replicas
        self.metrics_log.append(metrics)
        return metrics

    @property
    def done(self) -> bool:
        return self.step_idx >= self.job.total_steps

    def rescale(self, devices: Sequence, *, via_host: Optional[bool] = None
                ) -> RescaleTimings:
        """Shrink or expand onto ``devices`` (paper §3.1 shrink/expand).

        ``via_host=None`` (the default) picks the path automatically: when
        any source device survives into the target set, state moves
        peer-to-peer with a single ``jax.device_put`` (no host round-trip);
        when the sets are disjoint — a full migration — it falls back to the
        host-snapshot path.  Pass ``via_host=True``/``False`` to force."""
        devices = list(devices)
        self.validate_devices(devices)
        if via_host is None:
            via_host = surviving_devices(self.devices, devices) == 0
        t = RescaleTimings(path="host" if via_host else "p2p")

        t0 = time.perf_counter()
        # load balance: re-split the data stream over the new replica count
        new_r = len(devices) // self.job.model_axis
        bounds = [self.stream.shard_bounds(i, new_r) for i in range(new_r)]
        t.load_balance = time.perf_counter() - t0

        host = None
        if via_host:
            t0 = time.perf_counter()
            host = {"params": snapshot_to_host(self.params),
                    "opt": snapshot_to_host(self.opt_state)}
            t.checkpoint = time.perf_counter() - t0

        old_params, old_opt = self.params, self.opt_state
        t0 = time.perf_counter()
        if not self._ensure_mesh(devices):
            self._compile()
            self._mesh_cache[self._mesh_key(devices)]["compiled"] = \
                self._compiled
        t.restart = time.perf_counter() - t0

        t0 = time.perf_counter()
        if via_host:
            self.params = restore_from_host(host["params"], old_params,
                                            self._param_sh)
            self.opt_state = restore_from_host(host["opt"], old_opt,
                                               self._opt_sh)
        else:
            self.params = device_reshard(old_params, self._param_sh)
            self.opt_state = device_reshard(old_opt, self._opt_sh)
        jax.block_until_ready((self.params, self.opt_state))
        t.restore = time.perf_counter() - t0

        self.rescale_log.append(t)
        del bounds
        return t

    # -- fault tolerance (paper §3.2.2) ----------------------------------------
    def state_tree(self) -> dict:
        return {"params": self.params, "opt": self.opt_state,
                "step": jnp.asarray(self.step_idx, jnp.int32)}

    def save_disk(self, store, job_id: str, *, delta: bool = False,
                  fused: bool = False) -> float:
        return store.save(job_id, self.step_idx, self.state_tree(),
                          meta={"replicas": self.replicas}, delta=delta,
                          fused=fused)

    def save_disk_async(self, store, job_id: str, *, delta: bool = True,
                        fused: bool = False) -> None:
        """Snapshot now, write to disk in the background (fast lane).

        Training may continue immediately; call ``ckpt_barrier()`` before
        the job's slots are released (preempt) so ``latest_step`` is a fully
        published checkpoint."""
        if self._async_ckpt is None or self._async_ckpt.store is not store:
            if self._async_ckpt is not None:
                self._async_ckpt.close()
            self._async_ckpt = AsyncCheckpointer(store, delta=delta)
        self._async_ckpt.delta = delta
        self._async_ckpt.submit(job_id, self.step_idx, self.state_tree(),
                                meta={"replicas": self.replicas}, fused=fused)

    def ckpt_barrier(self) -> None:
        """Join all pending async checkpoint writes (preempt-time barrier)."""
        if self._async_ckpt is not None:
            self._async_ckpt.barrier()

    def restore_disk(self, store, job_id: str) -> int:
        """Restart-from-checkpoint (the paper's extra restart flag)."""
        flat, manifest = store.load(job_id)
        template = jax.eval_shape(self.state_tree)
        tree = unflatten_tree(template, flat)
        self.params = jax.device_put(tree["params"], self._param_sh)
        self.opt_state = jax.device_put(tree["opt"], self._opt_sh)
        self.step_idx = int(manifest["step"])
        return self.step_idx
