"""Cluster slot accounting + node-aware slot allocation.

A *slot* is the malleability quantum: one worker replica (paper: one pod/PE;
here: one model-parallel device group — DESIGN.md §2).  Every slot belongs to
a concrete node via :class:`~repro.core.placement.PlacementMap`, so kills and
drains displace the jobs actually resident on a node (paper: the operator
kills/drains specific pods on specific nodes), not "some" victims.

Base capacity given at construction becomes one node (``base``) or, with
``slots_per_node``, a row of ``base00..``; the cloud layer (repro.cloud)
attaches and detaches whole nodes via :meth:`add_node` / :meth:`remove_node`.
A spot preemption cordons a node out from under running jobs, so
``free_slots`` can transiently go negative; ``overcommit`` exposes the
deficit the caller must resolve (migrate/shrink/preempt).

Counting (``total/used/free_slots``) stays derived from job replica counts;
the placement map is the concrete slot->node assignment backing it.  The two
agree whenever every replica change goes through :meth:`place`/:meth:`evict`
(property-tested: residency sums equal ``used_slots``).
"""
from __future__ import annotations

from bisect import bisect_left, insort
from typing import Dict, List, Optional, Sequence

from repro.core.job import JobState, JobStatus
from repro.core.placement import PlacementError, PlacementMap

#: statuses that appear in the paper's allJobs list (and in ``_order``)
_SCHEDULABLE = (JobStatus.RUNNING, JobStatus.QUEUED)


class Cluster:
    def __init__(self, total_slots: int, devices: Optional[Sequence] = None,
                 devices_per_slot: int = 1, *,
                 slots_per_node: Optional[int] = None,
                 placement: str = "pack"):
        self.jobs: Dict[str, JobState] = {}
        # fleet-scale accounting, maintained by the JobState watch hook:
        # schedulable jobs in sort_key order (static, unique per job) and the
        # running-replica sum — so running_jobs()/used_slots never scan or
        # re-sort the whole job table.
        self._order: List[JobState] = []
        self._running: List[JobState] = []   # RUNNING subset, same order
        # offerable subset, same order: jobs Fig.-3 redistribution could
        # actually hand slots to — queued, or running below max_replicas.
        # Running-at-max jobs (the bulk of a loaded fleet) never enter, so
        # the per-completion scan is O(candidates), not O(running jobs).
        self._offerable: List[JobState] = []
        self._used = 0
        self.devices = list(devices) if devices is not None else None
        self.devices_per_slot = devices_per_slot
        if self.devices is not None:
            assert len(self.devices) >= total_slots * devices_per_slot
        self.placement = PlacementMap(strategy=placement)
        if total_slots > 0:
            if slots_per_node is None:
                self.placement.add_node("base", total_slots)
            else:
                assert slots_per_node >= 1
                i, left = 0, total_slots
                while left > 0:
                    self.placement.add_node(f"base{i:02d}",
                                            min(slots_per_node, left))
                    left -= slots_per_node
                    i += 1

    # --- accounting -------------------------------------------------------
    @property
    def total_slots(self) -> int:
        """Schedulable capacity (cordoned/draining nodes excluded)."""
        return self.placement.total_capacity

    @property
    def used_slots(self) -> int:
        """Running-replica sum, maintained incrementally (stays derived from
        job replica counts, so a job running beyond yanked capacity still
        counts — see ``overcommit``)."""
        return self._used

    @property
    def free_slots(self) -> int:
        return self.total_slots - self.used_slots

    @property
    def overcommit(self) -> int:
        """Slots running beyond capacity (after a node was yanked)."""
        return max(0, self.used_slots - self.total_slots)

    # --- dynamic capacity (cloud node lifecycle) ---------------------------
    def add_node(self, node_id: str, slots: int,
                 zone: Optional[str] = None) -> None:
        assert self.devices is None, \
            "dynamic nodes are unsupported on a device-backed cluster"
        self.placement.add_node(node_id, slots, zone=zone)

    def remove_node(self, node_id: str) -> int:
        """Detach an EMPTY node's slots.  Callers must displace residents
        first (migrate/shrink/preempt — see repro.cloud.sim spot kills);
        raises :class:`PlacementError` while any job is still resident."""
        if node_id not in self.placement.nodes():
            raise KeyError(node_id)
        return self.placement.remove_node(node_id)

    def cordon(self, node_id: str) -> None:
        """Exclude a node from capacity and new placement (drain begins);
        residents stay until migrated/evicted."""
        self.placement.cordon(node_id)

    def uncordon(self, node_id: str) -> None:
        self.placement.uncordon(node_id)

    def is_cordoned(self, node_id: str) -> bool:
        return self.placement.is_cordoned(node_id)

    @property
    def node_count(self) -> int:
        return self.placement.node_count

    def nodes(self) -> List[str]:
        return self.placement.nodes()

    def residents(self, node_id: str) -> Dict[str, int]:
        """job_id -> slots resident on this node (kill/drain blast set)."""
        return self.placement.residents(node_id)

    def resident_count(self, node_id: str) -> int:
        return self.placement.resident_count(node_id)

    def fragmentation(self) -> float:
        """Free-capacity stranding (see PlacementMap.fragmentation)."""
        return self.placement.fragmentation()

    def zone_of(self, node_id: str) -> str:
        return self.placement.zone_of(node_id)

    def job_zones(self, job_id: str) -> Dict[str, int]:
        """zone -> slots the job holds there (correlated blast footprint)."""
        return self.placement.job_zones(job_id)

    def add_job(self, job: JobState):
        assert job.job_id not in self.jobs, job.job_id
        self.jobs[job.job_id] = job
        # account whatever state the job arrives in (tests hand-build RUNNING
        # jobs with preset replicas to model overcommit), then watch it
        if job.status in _SCHEDULABLE:
            self._order_insert(self._order, job)
            if self._offer(job, job.status, job.replicas):
                self._order_insert(self._offerable, job)
        if job.status == JobStatus.RUNNING:
            self._order_insert(self._running, job)
            self._used += job.replicas
        job._watch = self

    # -- JobState watch hook -------------------------------------------------
    @staticmethod
    def _order_insert(order: List[JobState], job: JobState) -> None:
        insort(order, job, key=JobState.sort_key)

    @staticmethod
    def _order_remove(order: List[JobState], job: JobState) -> None:
        i = bisect_left(order, job.sort_key(), key=JobState.sort_key)
        # sort_key is unique per job, so this is the only candidate index
        if i < len(order) and order[i] is job:
            del order[i]

    @staticmethod
    def _offer(job: JobState, status, replicas: int) -> bool:
        """Could redistribution hand this job slots?  Queued jobs always;
        running jobs only below their max size (the policy's side-effect-free
        saturation test, evaluated incrementally instead of per scan)."""
        return status == JobStatus.QUEUED or (
            status == JobStatus.RUNNING
            and replicas < job.spec.max_replicas)

    def _job_changed(self, job: JobState, field: str, old, new) -> None:
        """Called by the watched ``status``/``replicas`` properties on every
        transition of a job this cluster owns: O(log jobs) bookkeeping in
        place of O(jobs) scans at every query."""
        if field == "status":
            if (old in _SCHEDULABLE) != (new in _SCHEDULABLE):
                if new in _SCHEDULABLE:
                    self._order_insert(self._order, job)
                else:
                    self._order_remove(self._order, job)
            r = job.replicas
            if self._offer(job, old, r) != self._offer(job, new, r):
                if self._offer(job, new, r):
                    self._order_insert(self._offerable, job)
                else:
                    self._order_remove(self._offerable, job)
            if old == JobStatus.RUNNING:
                self._order_remove(self._running, job)
                self._used -= job.replicas
            elif new == JobStatus.RUNNING:
                self._order_insert(self._running, job)
                self._used += job.replicas
        elif field == "replicas" and job.status == JobStatus.RUNNING:
            self._used += new - old
            mx = job.spec.max_replicas
            if (old < mx) != (new < mx):
                if new < mx:
                    self._order_insert(self._offerable, job)
                else:
                    self._order_remove(self._offerable, job)

    def running_jobs(self) -> List[JobState]:
        """Sorted by DECREASING priority (paper's runningJobs list)."""
        return list(self._running)

    def queued_jobs(self) -> List[JobState]:
        return [j for j in self._order if j.status == JobStatus.QUEUED]

    def all_schedulable_jobs(self) -> List[JobState]:
        """Running + queued, decreasing priority (paper's allJobs list)."""
        return list(self._order)

    def offerable_jobs(self) -> List[JobState]:
        """The schedulable jobs that could accept slots (queued, or running
        below max), same priority order — what Fig.-3 redistribution scans.
        Jobs the policy would skip via its saturation test are pre-filtered
        here incrementally, so the scan no longer touches every running job
        on every completion."""
        return list(self._offerable)

    # --- node-backed slot assignment ---------------------------------------
    def can_place(self, n: int) -> bool:
        return self.placement.free() >= n

    def place(self, job_id: str, n: int,
              strategy: Optional[str] = None) -> List[int]:
        """Assign n concrete node-backed slots (strategy: pack/spread);
        returns slot indices (stable per node, contiguous within a node —
        the ICI-locality analog of the paper's pod affinity)."""
        return self.placement.place(job_id, n, strategy)

    def evict(self, job_id: str, n: Optional[int] = None,
              prefer: Optional[str] = None) -> List[int]:
        """Free n of a job's slots (all when None), draining/preferred nodes
        first; returns the freed indices."""
        return self.placement.evict(job_id, n, prefer)

    def migrate(self, job_id: str, from_node: str) -> int:
        """Relocate the job's slots off ``from_node`` onto free capacity
        elsewhere; returns how many moved."""
        return self.placement.migrate(job_id, from_node)

    # --- compat aliases (live operator's device-range view) -----------------
    def allocate_slots(self, job_id: str, n: int) -> List[int]:
        return self.place(job_id, n)

    def release_slots(self, job_id: str, keep: int = 0) -> List[int]:
        """Free all but ``keep`` of a job's slots."""
        owned = self.placement.owned(job_id)
        if owned <= keep:
            return []
        return self.evict(job_id, owned - keep)

    def slots_of(self, job_id: str) -> List[int]:
        return self.placement.slots_of(job_id)

    def devices_for_slots(self, slots: Sequence[int]) -> list:
        assert self.devices is not None
        out = []
        for s in slots:
            out.extend(self.devices[s * self.devices_per_slot:
                                    (s + 1) * self.devices_per_slot])
        return out
