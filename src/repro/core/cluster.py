"""Cluster slot accounting + device-range allocation.

A *slot* is the malleability quantum: one worker replica (paper: one pod/PE;
here: one model-parallel device group — DESIGN.md §2).  The live operator
additionally tracks which concrete JAX devices back each slot; the simulator
only counts.

Capacity is *dynamic*: beyond the fixed base slots given at construction, the
cloud layer (repro.cloud) attaches and detaches whole nodes via
:meth:`add_node` / :meth:`remove_node`.  A spot preemption may remove a node
out from under running jobs, so ``free_slots`` can transiently go negative;
``overcommit`` exposes the deficit the caller must resolve (shrink/preempt).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.job import JobState, JobStatus


class Cluster:
    def __init__(self, total_slots: int, devices: Optional[Sequence] = None,
                 devices_per_slot: int = 1):
        self._base_slots = total_slots
        self._node_slots: Dict[str, int] = {}    # dynamic capacity by node
        self.jobs: Dict[str, JobState] = {}
        self.devices = list(devices) if devices is not None else None
        self.devices_per_slot = devices_per_slot
        if self.devices is not None:
            assert len(self.devices) >= total_slots * devices_per_slot
        # slot index -> job_id (None = free); contiguous ranges preferred
        self._slot_owner: List[Optional[str]] = [None] * total_slots

    # --- accounting -------------------------------------------------------
    @property
    def total_slots(self) -> int:
        return self._base_slots + sum(self._node_slots.values())

    @property
    def used_slots(self) -> int:
        return sum(j.replicas for j in self.jobs.values()
                   if j.status == JobStatus.RUNNING)

    @property
    def free_slots(self) -> int:
        return self.total_slots - self.used_slots

    @property
    def overcommit(self) -> int:
        """Slots running beyond capacity (after a node was yanked)."""
        return max(0, self.used_slots - self.total_slots)

    # --- dynamic capacity (cloud node lifecycle) ---------------------------
    def add_node(self, node_id: str, slots: int) -> None:
        assert node_id not in self._node_slots, node_id
        assert self.devices is None, \
            "dynamic nodes are unsupported on a device-backed cluster"
        self._node_slots[node_id] = slots
        self._slot_owner.extend([None] * slots)

    def remove_node(self, node_id: str) -> int:
        """Detach a node's slots.  Only unallocated slot indices are retired,
        so the caller must evict or shrink victims first when the live slot
        map is in use (the counting simulator never allocates indices)."""
        slots = self._node_slots.pop(node_id)
        retired = 0
        for i in range(len(self._slot_owner) - 1, -1, -1):
            if retired == slots:
                break
            if self._slot_owner[i] is None:
                del self._slot_owner[i]
                retired += 1
        assert retired == slots, \
            f"remove_node({node_id}): only {retired}/{slots} slots free"
        return slots

    def add_job(self, job: JobState):
        assert job.job_id not in self.jobs, job.job_id
        self.jobs[job.job_id] = job

    def running_jobs(self) -> List[JobState]:
        """Sorted by DECREASING priority (paper's runningJobs list)."""
        out = [j for j in self.jobs.values() if j.status == JobStatus.RUNNING]
        out.sort(key=JobState.sort_key)
        return out

    def queued_jobs(self) -> List[JobState]:
        out = [j for j in self.jobs.values() if j.status == JobStatus.QUEUED]
        out.sort(key=JobState.sort_key)
        return out

    def all_schedulable_jobs(self) -> List[JobState]:
        """Running + queued, decreasing priority (paper's allJobs list)."""
        out = [j for j in self.jobs.values()
               if j.status in (JobStatus.RUNNING, JobStatus.QUEUED)]
        out.sort(key=JobState.sort_key)
        return out

    # --- device-range allocation (live operator) ---------------------------
    def allocate_slots(self, job_id: str, n: int) -> List[int]:
        """Grab n slots, preferring a contiguous range (ICI-locality analog of
        the paper's pod affinity)."""
        free = [i for i, o in enumerate(self._slot_owner) if o is None]
        assert len(free) >= n, (job_id, n, len(free))
        # longest contiguous run first
        runs, cur = [], [free[0]]
        for a, b in zip(free, free[1:]):
            if b == a + 1:
                cur.append(b)
            else:
                runs.append(cur)
                cur = [b]
        runs.append(cur)
        runs.sort(key=len, reverse=True)
        chosen: List[int] = []
        for run in runs:
            take = min(n - len(chosen), len(run))
            chosen.extend(run[:take])
            if len(chosen) == n:
                break
        for i in chosen:
            self._slot_owner[i] = job_id
        return sorted(chosen)

    def release_slots(self, job_id: str, keep: int = 0) -> List[int]:
        """Free all but ``keep`` of a job's slots (highest indices first)."""
        owned = [i for i, o in enumerate(self._slot_owner) if o == job_id]
        to_free = owned[keep:] if keep else owned
        for i in to_free:
            self._slot_owner[i] = None
        return to_free

    def slots_of(self, job_id: str) -> List[int]:
        return [i for i, o in enumerate(self._slot_owner) if o == job_id]

    def devices_for_slots(self, slots: Sequence[int]) -> list:
        assert self.devices is not None
        out = []
        for s in slots:
            out.extend(self.devices[s * self.devices_per_slot:
                                    (s + 1) * self.devices_per_slot])
        return out
