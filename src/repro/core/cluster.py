"""Cluster slot accounting + node-aware slot allocation.

A *slot* is the malleability quantum: one worker replica (paper: one pod/PE;
here: one model-parallel device group — DESIGN.md §2).  Every slot belongs to
a concrete node via :class:`~repro.core.placement.PlacementMap`, so kills and
drains displace the jobs actually resident on a node (paper: the operator
kills/drains specific pods on specific nodes), not "some" victims.

Base capacity given at construction becomes one node (``base``) or, with
``slots_per_node``, a row of ``base00..``; the cloud layer (repro.cloud)
attaches and detaches whole nodes via :meth:`add_node` / :meth:`remove_node`.
A spot preemption cordons a node out from under running jobs, so
``free_slots`` can transiently go negative; ``overcommit`` exposes the
deficit the caller must resolve (migrate/shrink/preempt).

Counting (``total/used/free_slots``) stays derived from job replica counts;
the placement map is the concrete slot->node assignment backing it.  The two
agree whenever every replica change goes through :meth:`place`/:meth:`evict`
(property-tested: residency sums equal ``used_slots``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.job import JobState, JobStatus
from repro.core.placement import PlacementError, PlacementMap


class Cluster:
    def __init__(self, total_slots: int, devices: Optional[Sequence] = None,
                 devices_per_slot: int = 1, *,
                 slots_per_node: Optional[int] = None,
                 placement: str = "pack"):
        self.jobs: Dict[str, JobState] = {}
        self.devices = list(devices) if devices is not None else None
        self.devices_per_slot = devices_per_slot
        if self.devices is not None:
            assert len(self.devices) >= total_slots * devices_per_slot
        self.placement = PlacementMap(strategy=placement)
        if total_slots > 0:
            if slots_per_node is None:
                self.placement.add_node("base", total_slots)
            else:
                assert slots_per_node >= 1
                i, left = 0, total_slots
                while left > 0:
                    self.placement.add_node(f"base{i:02d}",
                                            min(slots_per_node, left))
                    left -= slots_per_node
                    i += 1

    # --- accounting -------------------------------------------------------
    @property
    def total_slots(self) -> int:
        """Schedulable capacity (cordoned/draining nodes excluded)."""
        return self.placement.total_capacity

    @property
    def used_slots(self) -> int:
        return sum(j.replicas for j in self.jobs.values()
                   if j.status == JobStatus.RUNNING)

    @property
    def free_slots(self) -> int:
        return self.total_slots - self.used_slots

    @property
    def overcommit(self) -> int:
        """Slots running beyond capacity (after a node was yanked)."""
        return max(0, self.used_slots - self.total_slots)

    # --- dynamic capacity (cloud node lifecycle) ---------------------------
    def add_node(self, node_id: str, slots: int,
                 zone: Optional[str] = None) -> None:
        assert self.devices is None, \
            "dynamic nodes are unsupported on a device-backed cluster"
        self.placement.add_node(node_id, slots, zone=zone)

    def remove_node(self, node_id: str) -> int:
        """Detach an EMPTY node's slots.  Callers must displace residents
        first (migrate/shrink/preempt — see repro.cloud.sim spot kills);
        raises :class:`PlacementError` while any job is still resident."""
        if node_id not in self.placement.nodes():
            raise KeyError(node_id)
        return self.placement.remove_node(node_id)

    def cordon(self, node_id: str) -> None:
        """Exclude a node from capacity and new placement (drain begins);
        residents stay until migrated/evicted."""
        self.placement.cordon(node_id)

    def uncordon(self, node_id: str) -> None:
        self.placement.uncordon(node_id)

    def is_cordoned(self, node_id: str) -> bool:
        return self.placement.is_cordoned(node_id)

    @property
    def node_count(self) -> int:
        return self.placement.node_count

    def nodes(self) -> List[str]:
        return self.placement.nodes()

    def residents(self, node_id: str) -> Dict[str, int]:
        """job_id -> slots resident on this node (kill/drain blast set)."""
        return self.placement.residents(node_id)

    def resident_count(self, node_id: str) -> int:
        return self.placement.resident_count(node_id)

    def fragmentation(self) -> float:
        """Free-capacity stranding (see PlacementMap.fragmentation)."""
        return self.placement.fragmentation()

    def zone_of(self, node_id: str) -> str:
        return self.placement.zone_of(node_id)

    def job_zones(self, job_id: str) -> Dict[str, int]:
        """zone -> slots the job holds there (correlated blast footprint)."""
        return self.placement.job_zones(job_id)

    def add_job(self, job: JobState):
        assert job.job_id not in self.jobs, job.job_id
        self.jobs[job.job_id] = job

    def running_jobs(self) -> List[JobState]:
        """Sorted by DECREASING priority (paper's runningJobs list)."""
        out = [j for j in self.jobs.values() if j.status == JobStatus.RUNNING]
        out.sort(key=JobState.sort_key)
        return out

    def queued_jobs(self) -> List[JobState]:
        out = [j for j in self.jobs.values() if j.status == JobStatus.QUEUED]
        out.sort(key=JobState.sort_key)
        return out

    def all_schedulable_jobs(self) -> List[JobState]:
        """Running + queued, decreasing priority (paper's allJobs list)."""
        out = [j for j in self.jobs.values()
               if j.status in (JobStatus.RUNNING, JobStatus.QUEUED)]
        out.sort(key=JobState.sort_key)
        return out

    # --- node-backed slot assignment ---------------------------------------
    def can_place(self, n: int) -> bool:
        return self.placement.free() >= n

    def place(self, job_id: str, n: int,
              strategy: Optional[str] = None) -> List[int]:
        """Assign n concrete node-backed slots (strategy: pack/spread);
        returns slot indices (stable per node, contiguous within a node —
        the ICI-locality analog of the paper's pod affinity)."""
        return self.placement.place(job_id, n, strategy)

    def evict(self, job_id: str, n: Optional[int] = None,
              prefer: Optional[str] = None) -> List[int]:
        """Free n of a job's slots (all when None), draining/preferred nodes
        first; returns the freed indices."""
        return self.placement.evict(job_id, n, prefer)

    def migrate(self, job_id: str, from_node: str) -> int:
        """Relocate the job's slots off ``from_node`` onto free capacity
        elsewhere; returns how many moved."""
        return self.placement.migrate(job_id, from_node)

    # --- compat aliases (live operator's device-range view) -----------------
    def allocate_slots(self, job_id: str, n: int) -> List[int]:
        return self.place(job_id, n)

    def release_slots(self, job_id: str, keep: int = 0) -> List[int]:
        """Free all but ``keep`` of a job's slots."""
        owned = self.placement.owned(job_id)
        if owned <= keep:
            return []
        return self.evict(job_id, owned - keep)

    def slots_of(self, job_id: str) -> List[int]:
        return self.placement.slots_of(job_id)

    def devices_for_slots(self, slots: Sequence[int]) -> list:
        assert self.devices is not None
        out = []
        for s in slots:
            out.extend(self.devices[s * self.devices_per_slot:
                                    (s + 1) * self.devices_per_slot])
        return out
