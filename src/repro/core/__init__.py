"""The paper's primary contribution: an elastic, priority-based job scheduler
for malleable (shrink/expand-able) parallel jobs, plus the runtime that makes
JAX training jobs malleable and the simulator used for policy evaluation.

- C1 (shrink/expand):   core.elastic.ElasticTrainer
- C2 (operator+policy): core.operator.ElasticClusterController, core.policies
- C3 (simulator):       core.simulator
Beyond-paper:           core.autoscale (aging, cost-benefit, preemption)
"""
from repro.core.autoscale import AgingPolicy, CostBenefitPolicy, PreemptingPolicy
from repro.core.cluster import Cluster
from repro.core.elastic import ElasticTrainer, RescaleTimings, TrainJobConfig
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.metrics import ScheduleMetrics, UtilizationLog, compute_metrics
from repro.core.operator import ElasticClusterController
from repro.core.placement import PlacementError, PlacementMap
from repro.core.policies import Actions, ElasticPolicy, PolicyConfig
from repro.core.simulator import (Simulator, SimWorkload, VARIANTS,
                                  jacobi_workload, make_jacobi_jobs,
                                  run_variant)

__all__ = [
    "AgingPolicy", "CostBenefitPolicy", "PreemptingPolicy", "Cluster",
    "ElasticTrainer", "RescaleTimings", "TrainJobConfig", "JobSpec",
    "JobState", "JobStatus", "ScheduleMetrics", "UtilizationLog",
    "compute_metrics", "ElasticClusterController", "PlacementError",
    "PlacementMap", "Actions", "ElasticPolicy",
    "PolicyConfig", "Simulator", "SimWorkload", "VARIANTS", "jacobi_workload",
    "make_jacobi_jobs", "run_variant",
]
