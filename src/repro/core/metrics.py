"""Scheduler evaluation metrics (paper §4.3).

- total time: first submission -> last completion
- cluster utilization: time-averaged used/total slots over that window; with
  a dynamic (cloud) cluster the denominator is the time-varying *provisioned*
  capacity, recorded via :meth:`UtilizationLog.record_capacity`
- weighted mean response time: sum(priority * (start - submit)) / sum(priority)
- weighted mean completion time: same with (end - submit)
- cost fields (cloud runs only): node-hours x pool price, wasted-idle dollars
- placement fields (multi-node runs): time-averaged fragmentation (free
  capacity stranded on partially-used nodes) and spot-kill blast radius —
  ``kill_blast_radius`` is the mean displaced slots PER RESIDENT JOB per
  kill, i.e. how concentrated the damage is: ``pack`` placement focuses a
  kill on few jobs (large radius), ``spread`` dilutes it (small radius)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.job import JobState, completion_time, response_time


def _integrate(events: Sequence[Tuple[float, float]], t0: float, t1: float,
               initial: float) -> float:
    """Area under a piecewise-constant step series over [t0, t1].  The value
    before the first event (and at t <= t0) is the last event at or before
    t0, else ``initial``."""
    area = 0.0
    cur = initial
    prev = t0
    for t, u in events:
        if t <= t0:
            cur = u
            continue
        tc = min(t, t1)
        area += cur * max(0.0, tc - prev)
        prev = max(prev, tc)
        cur = u
        if t >= t1:
            break
    area += cur * max(0.0, t1 - prev)
    return area


def _coalesce(series: List[Tuple[float, float]], t: float, value) -> None:
    """Append ``(t, value)``, coalescing same-timestamp updates: several
    state changes at one instant leave only the last value (a zero-width
    step contributes no area and would bloat the series)."""
    if series and series[-1][0] == t:
        series[-1] = (t, value)
    else:
        series.append((t, value))


class _Accum:
    """Running integral of one piecewise-constant stream: each record adds
    ``last_value * (t - last_t)`` — the exact float additions ``_integrate``
    would perform over the same in-window series, so the two agree bit-for-
    bit whenever every record falls inside the queried window (property-
    tested in tests/test_metrics_incremental.py).  Same-timestamp updates add
    a zero-width (0.0-area) segment and overwrite the value: identical to
    ``_coalesce`` + re-integrate."""

    __slots__ = ("first_t", "last_t", "value", "area")

    def __init__(self):
        self.first_t: Optional[float] = None
        self.last_t = 0.0
        self.value = 0.0
        self.area = 0.0

    def record(self, t: float, value: float) -> None:
        if self.first_t is None:
            self.first_t = t
        else:
            self.area += self.value * (t - self.last_t)
        self.last_t = t
        self.value = value

    def integral(self, t0: float, t1: float, initial: float) -> float:
        """Integral over [t0, t1], assuming the stream was ``initial`` before
        the first record.  Exact when t0 <= first_t and t1 >= last_t (the
        simulator's metrics window always satisfies both: records start at
        the first dispatch >= min submit and end at the last completion)."""
        if self.first_t is None:
            return initial * (t1 - t0)
        return (initial * max(0.0, self.first_t - t0) + self.area
                + self.value * max(0.0, t1 - self.last_t))


class UtilizationLog:
    """Step-series log of used slots / capacity / fragmentation.

    Two speeds (the fleet-scale refactor):

    - ``keep_series=True`` (default): full step series retained;
      ``average()`` integrates it offline with :func:`_integrate` —
      bit-identical to the original implementation, and what tracers /
      timelines / ``profile()`` consume.
    - ``keep_series=False``: bounded memory for million-event replays.  The
      used/fragmentation series are NOT retained; ``average()`` reads the
      O(1) running accumulators instead.  The capacity series is always
      retained (node lifecycle events are rare — and a fixed-capacity run
      has none), so dynamic-capacity averaging stays exact.

    The accumulators are maintained in BOTH modes, which is what lets the
    property suite assert incremental == offline on arbitrary interleavings.
    """

    def __init__(self, total_slots: int, *, keep_series: bool = True):
        self.total_slots = total_slots
        self.keep_series = keep_series
        self.events: List[Tuple[float, int]] = []            # (t, used)
        # (t, provisioned slots); empty = capacity fixed at total_slots
        self.capacity_events: List[Tuple[float, int]] = []
        # (t, fragmentation in [0,1]); empty = single-node cluster (undefined)
        self.frag_events: List[Tuple[float, float]] = []
        self._used_acc = _Accum()
        self._cap_acc = _Accum()
        self._frag_acc = _Accum()

    def record(self, t: float, used: int):
        # _coalesce + _Accum.record, inlined: this lands on every scheduling
        # action the simulator takes
        if self.keep_series:
            ev = self.events
            if ev and ev[-1][0] == t:
                ev[-1] = (t, used)
            else:
                ev.append((t, used))
        acc = self._used_acc
        if acc.first_t is None:
            acc.first_t = t
        else:
            acc.area += acc.value * (t - acc.last_t)
        acc.last_t = t
        acc.value = used

    def record_fragmentation(self, t: float, frag: float):
        if self.keep_series:
            _coalesce(self.frag_events, t, frag)
        self._frag_acc.record(t, frag)

    def record_capacity(self, t: float, total: int):
        _coalesce(self.capacity_events, t, total)
        self._cap_acc.record(t, total)

    def average(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        if self.keep_series:
            if not self.events:
                return 0.0
            used = _integrate(self.events, t0, t1, 0)
        else:
            if self._used_acc.first_t is None:
                return 0.0
            used = self._used_acc.integral(t0, t1, 0.0)
        if self.capacity_events:
            cap = _integrate(self.capacity_events, t0, t1,
                             float(self.total_slots))
        else:
            cap = self.total_slots * (t1 - t0)
        return used / cap if cap > 0 else 0.0

    def average_fragmentation(self, t0: float, t1: float) -> float:
        if t1 <= t0:
            return 0.0
        if self.keep_series:
            if not self.frag_events:
                return 0.0
            return _integrate(self.frag_events, t0, t1, 0.0) / (t1 - t0)
        if self._frag_acc.first_t is None:
            return 0.0
        return self._frag_acc.integral(t0, t1, 0.0) / (t1 - t0)

    def profile(self) -> List[Tuple[float, int]]:
        return list(self.events)


@dataclass(frozen=True)
class ScheduleMetrics:
    total_time: float
    utilization: float
    weighted_mean_response: float
    weighted_mean_completion: float
    rescale_count: int
    dropped_jobs: int = 0
    # cloud runs (repro.cloud) — zero on fixed-capacity simulations
    total_cost: float = 0.0        # $ billed: node capacity + transfer
    idle_cost: float = 0.0         # $ of provisioned-but-unused slot time
    node_hours: float = 0.0        # billed node-hours
    spot_preemptions: int = 0      # nodes reclaimed by the spot market
    transfer_cost: float = 0.0     # $ of inter-region checkpoint transfer
    zone_reclaims: int = 0         # correlated zone events that killed nodes
    # placement (multi-node runs) — zero on single-node simulations
    avg_fragmentation: float = 0.0   # time-averaged stranded-free fraction
    kill_blast_jobs: float = 0.0     # mean jobs displaced per spot kill
    kill_blast_radius: float = 0.0   # mean displaced slots per victim job
    kill_preemptions: float = 0.0    # mean checkpoint-preempted jobs per kill
    # correlated (zone_reclaim) EVENT-level blasts: a job losing slots on
    # several nodes dying in one burst is ONE casualty of that burst
    zone_blast_jobs: float = 0.0     # mean jobs displaced per zone reclaim
    zone_blast_radius: float = 0.0   # mean displaced slots per victim job
    zone_preemptions: float = 0.0    # mean checkpoint-preempted per reclaim
    # spot bidding (cloud runs) — preemption-overhead dollars are an
    # attribution of capacity dollars already in total_cost, never additive
    preempt_overhead_cost: float = 0.0  # $ of ckpt write/restore slot-time
    bid_adjustments: int = 0         # bidder open<->closed zone flips
    # observed spot share by zone: spot slot-hours billed in the zone over
    # all billed slot-hours (empty on fixed-capacity or spotless runs)
    spot_share_by_zone: Dict[str, float] = field(default_factory=dict)
    # streaming latency percentiles (repro.obs.stats.LatencyRecorder): flat
    # keys like ``resp_p99`` (all jobs) / ``resp_p99_prio5`` (one priority
    # class) for resp/compl/wait x p50/p95/p99; empty when no job completed
    percentiles: Dict[str, float] = field(default_factory=dict)
    # monotonic run counters (events processed, rescales, migrations, ...)
    counters: Dict[str, int] = field(default_factory=dict)
    # makespan decomposition (repro.obs.critical_path): priority-weighted
    # mean seconds per phase over completed jobs — the phases PARTITION each
    # makespan, so the values sum to weighted_mean_completion
    phase_seconds: Dict[str, float] = field(default_factory=dict)
    # plain mean seconds per phase within one priority class, flattened as
    # ``prio<k>.<phase>``
    phase_by_priority: Dict[str, float] = field(default_factory=dict)
    # jobs whose single largest phase is <phase> (fleet histogram)
    dominant_phase: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        """Machine-readable form (plain scalars + dicts, JSON-safe) — the
        benchmark tables emit rows from this instead of ad-hoc formatting."""
        return dataclasses.asdict(self)

    def row(self) -> str:
        s = (f"total={self.total_time:9.1f}s util={self.utilization:6.2%} "
             f"resp={self.weighted_mean_response:8.2f}s "
             f"compl={self.weighted_mean_completion:8.2f}s "
             f"rescales={self.rescale_count}")
        if self.total_cost > 0.0:
            s += (f" cost=${self.total_cost:7.3f} idle=${self.idle_cost:6.3f}"
                  f" node_h={self.node_hours:5.2f}"
                  f" spot_kills={self.spot_preemptions}")
            if self.transfer_cost > 0.0 or self.zone_reclaims > 0:
                s += (f" xfer=${self.transfer_cost:6.4f}"
                      f" zone_reclaims={self.zone_reclaims}")
            if self.preempt_overhead_cost > 0.0 or self.bid_adjustments:
                s += (f" ovh=${self.preempt_overhead_cost:6.4f}"
                      f" bids={self.bid_adjustments}")
        if self.avg_fragmentation > 0.0 or self.kill_blast_jobs > 0.0:
            s += (f" frag={self.avg_fragmentation:5.2f}"
                  f" blast={self.kill_blast_radius:4.1f}")
        return s


def compute_metrics(jobs: Sequence[JobState], util: UtilizationLog, *,
                    latency=None, counters: Optional[Dict[str, int]] = None,
                    phases=None) -> ScheduleMetrics:
    """Cost fields stay at their zero defaults here; CloudSimulator's
    ``_final_metrics`` fills them from its CostReport via
    dataclasses.replace.  ``latency`` is a
    :class:`repro.obs.stats.LatencyRecorder` (or anything with
    ``percentile_fields()``); ``counters`` a plain dict; ``phases`` a
    :class:`repro.obs.critical_path.PhaseLedger` whose per-job makespan
    decompositions are rolled up into the ``phase_*`` fields."""
    done = [j for j in jobs if j.end_time is not None]
    submits = [j.spec.submit_time for j in jobs]
    t0 = min(submits) if submits else 0.0
    t1 = max((j.end_time for j in done), default=t0)
    wsum = sum(j.spec.priority for j in done) or 1.0
    resp = sum(j.spec.priority * (response_time(j) or 0.0) for j in done) / wsum
    comp = sum(j.spec.priority * (completion_time(j) or 0.0) for j in done) / wsum
    phase_kw = {}
    if phases is not None:
        from repro.obs.critical_path import rollup
        fleet = rollup(phases.per_job(),
                       {j.spec.job_id: j.spec.priority for j in jobs})
        if fleet.jobs:
            phase_kw = dict(phase_seconds=fleet.phase_seconds,
                            phase_by_priority=fleet.phase_by_priority,
                            dominant_phase=fleet.dominant_phase)
    return ScheduleMetrics(
        total_time=t1 - t0,
        utilization=util.average(t0, t1),
        weighted_mean_response=resp,
        weighted_mean_completion=comp,
        rescale_count=sum(j.rescale_count for j in jobs),
        dropped_jobs=len(jobs) - len(done),
        avg_fragmentation=util.average_fragmentation(t0, t1),
        percentiles=(latency.percentile_fields()
                     if latency is not None else {}),
        counters=dict(counters) if counters else {},
        **phase_kw,
    )
