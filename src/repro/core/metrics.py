"""Scheduler evaluation metrics (paper §4.3).

- total time: first submission -> last completion
- cluster utilization: time-averaged used/total slots over that window
- weighted mean response time: sum(priority * (start - submit)) / sum(priority)
- weighted mean completion time: same with (end - submit)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from repro.core.job import JobState, completion_time, response_time


@dataclass
class UtilizationLog:
    total_slots: int
    events: List[Tuple[float, int]] = field(default_factory=list)  # (t, used)

    def record(self, t: float, used: int):
        if self.events and self.events[-1][0] == t:
            self.events[-1] = (t, used)
        else:
            self.events.append((t, used))

    def average(self, t0: float, t1: float) -> float:
        if t1 <= t0 or not self.events:
            return 0.0
        area = 0.0
        used = 0
        prev = t0
        for t, u in self.events:
            if t <= t0:
                used = u
                continue
            tc = min(t, t1)
            area += used * max(0.0, tc - prev)
            prev = max(prev, tc)
            used = u
            if t >= t1:
                break
        area += used * max(0.0, t1 - prev)
        return area / (self.total_slots * (t1 - t0))

    def profile(self) -> List[Tuple[float, int]]:
        return list(self.events)


@dataclass(frozen=True)
class ScheduleMetrics:
    total_time: float
    utilization: float
    weighted_mean_response: float
    weighted_mean_completion: float
    rescale_count: int
    dropped_jobs: int = 0

    def row(self) -> str:
        return (f"total={self.total_time:9.1f}s util={self.utilization:6.2%} "
                f"resp={self.weighted_mean_response:8.2f}s "
                f"compl={self.weighted_mean_completion:8.2f}s "
                f"rescales={self.rescale_count}")


def compute_metrics(jobs: Sequence[JobState], util: UtilizationLog
                    ) -> ScheduleMetrics:
    done = [j for j in jobs if j.end_time is not None]
    submits = [j.spec.submit_time for j in jobs]
    t0 = min(submits) if submits else 0.0
    t1 = max((j.end_time for j in done), default=t0)
    wsum = sum(j.spec.priority for j in done) or 1.0
    resp = sum(j.spec.priority * (response_time(j) or 0.0) for j in done) / wsum
    comp = sum(j.spec.priority * (completion_time(j) or 0.0) for j in done) / wsum
    return ScheduleMetrics(
        total_time=t1 - t0,
        utilization=util.average(t0, t1),
        weighted_mean_response=resp,
        weighted_mean_completion=comp,
        rescale_count=sum(j.rescale_count for j in jobs),
        dropped_jobs=len(jobs) - len(done),
    )
