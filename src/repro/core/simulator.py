"""Discrete-event scheduler simulator — paper contribution C3.

Reproduces §4.3.1: Fig. 7 (submission-gap sweep), Fig. 8 (T_rescale_gap
sweep), and the simulation columns of Table 1.  Job runtime vs. replicas and
rescale overheads come from the piecewise models in ``perf_model`` (the paper
interpolates measured Jacobi2D points; we synthesize them — DESIGN.md §6.4).

Progress accounting: a running job accrues work at ``1/time_per_step(r)``
steps/s except inside its rescale-overhead window.  Completion events carry a
version stamp so a rescale invalidates the stale completion.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache
from time import perf_counter
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import Cluster
from repro.core.events import _CANCELLED, EventQueue
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.metrics import ScheduleMetrics, UtilizationLog, compute_metrics
from repro.core.perf_model import (JACOBI_SIZES, JacobiModel,
                                   PiecewiseScalingModel, RescaleModel)
from repro.core.policies import ElasticPolicy, PolicyConfig
from repro.obs.critical_path import NullPhaseLedger, PhaseLedger
from repro.obs.decisions import DecisionLog
from repro.obs.profile import current_profiler
from repro.obs.stats import Counters, LatencyRecorder
from repro.obs.trace import current_tracer


@dataclass
class SimWorkload:
    """Perf description of one simulated job."""
    scaling: object                 # .time_per_step(replicas) -> s
    total_work: float               # steps
    data_bytes: float
    rescale: RescaleModel = field(default_factory=RescaleModel)


class _SimActions:
    """Actions implementation mutating simulator state (virtual clock)."""

    def __init__(self, sim: "Simulator"):
        self.sim = sim

    # paper: rigid emulation also passes through here; policy never calls
    # shrink/expand on rigid jobs because min == max.
    def create(self, job: JobState, replicas: int) -> bool:
        sim = self.sim
        # capacity can shrink under a running policy (spot kill between the
        # policy's free_slots read and this call) — refuse, don't crash.
        # free_slots <= placement free always (jobs resident on cordoned
        # nodes count as used), so this one check also guarantees place()
        if replicas <= 0 or replicas > sim.cluster.free_slots:
            return False
        job_id = job.spec.job_id
        sim.cluster.place(job_id, replicas)
        job.status = JobStatus.RUNNING
        job.replicas = replicas
        job.last_action = sim.now
        if job.start_time is None:
            job.start_time = sim.now
        sim.last_resume_s = 0.0
        resumed = False
        if job.preempt_count and job.work_remaining < sim.workloads[
                job_id].total_work:
            # resuming a preempted job: restart + restore-from-disk; the
            # cost is published (like last_preempt_ckpt_s) so extensions
            # bill exactly what the simulation charged the clock
            wl = sim.workloads[job_id]
            sim.last_resume_s = wl.rescale.resume_cost(replicas,
                                                       wl.data_bytes)
            job.overhead_until = sim.now + sim.last_resume_s
            resumed = True
        job.last_progress_time = sim.now
        sim._schedule_completion(job)
        sim._record_util()
        sim.latency.mark_started(job_id, sim.now)
        sim.phases.on_start(job_id, sim.now, restore_s=sim.last_resume_s)
        if sim.tracer.enabled:
            sim.tracer.emit("job_start", t=sim.now, job=job_id,
                            slots=replicas, priority=job.spec.priority,
                            resume=resumed, overhead_s=sim.last_resume_s)
        return True

    def expand(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def shrink(self, job: JobState, replicas: int) -> bool:
        return self._rescale(job, replicas)

    def _rescale(self, job: JobState, replicas: int) -> bool:
        sim = self.sim
        if replicas == job.replicas:
            return True
        from_replicas = job.replicas
        delta = replicas - from_replicas
        # shrinks always succeed — even when free_slots is negative because a
        # node was yanked (the cloud layer shrinks victims to resolve exactly
        # that deficit)
        if delta > 0 and delta > sim.cluster.free_slots:
            return False
        job_id = job.spec.job_id
        if delta > 0:
            sim.cluster.place(job_id, delta)
        else:
            # a forced shrink (spot kill) names the dying node via
            # _evict_prefer so the freed slots come off it exactly — even
            # when another node is cordoned for an in-flight drain; absent
            # that, cordoned nodes are vacated first anyway
            sim.cluster.evict(job_id, -delta, prefer=sim._evict_prefer)
        sim._sync_progress(job)
        wl = sim.workloads[job_id]
        overhead = wl.rescale.total(job.replicas, replicas, wl.data_bytes)
        job.overhead_until = max(sim.now, job.overhead_until) + overhead
        job.replicas = replicas
        job.last_action = sim.now
        job.rescale_count += 1
        sim.total_overhead += overhead
        sim._schedule_completion(job)
        sim._record_util()
        sim.counters.inc("rescales")
        sim.phases.on_rescale(job_id, sim.now, overhead)
        if sim.tracer.enabled:
            sim.tracer.emit("job_rescale", t=sim.now, job=job_id,
                            **{"from": from_replicas, "to": replicas},
                            overhead_s=overhead)
        return True

    def enqueue(self, job: JobState) -> None:
        job.status = JobStatus.QUEUED
        sim = self.sim
        sim.latency.mark_queued(job.job_id, sim.now)
        if sim.tracer.enabled:
            sim.tracer.emit("job_queue", t=sim.now, job=job.job_id)

    def preempt(self, job: JobState) -> bool:
        """Checkpoint-to-disk preemption (core/autoscale.PreemptingPolicy)."""
        sim = self.sim
        sim._sync_progress(job)
        wl = sim.workloads[job.job_id]
        # the victim pays the disk checkpoint before its slots free up; the
        # cost is published so extensions (cloud overhead billing) price
        # exactly the checkpoint the simulation charged, never a re-derival
        sim.last_preempt_ckpt_s = wl.rescale.preempt_cost(job.replicas,
                                                          wl.data_bytes)
        sim.now += sim.last_preempt_ckpt_s
        sim.counters.inc("preemptions")
        sim.latency.mark_queued(job.job_id, sim.now)
        sim.phases.on_preempt(job.job_id, sim.now, sim.last_preempt_ckpt_s)
        if sim.tracer.enabled:
            sim.tracer.emit("job_preempt", t=sim.now, job=job.job_id,
                            slots=job.replicas,
                            ckpt_s=sim.last_preempt_ckpt_s)
        sim.cluster.evict(job.job_id)
        job.status = JobStatus.QUEUED
        job.replicas = 0
        job.version += 1            # invalidate its completion event
        sim._cancel_completion(job)
        job.preempt_count += 1
        # queued jobs must always pass the rescale-gap check (job.py: Fig. 3
        # hands slots to queued jobs regardless of recency) — anchoring
        # last_action here would strand the victim for a whole gap window
        job.last_action = -math.inf
        sim._record_util()
        return True


class Simulator:
    def __init__(self, total_slots: int, policy_cfg: PolicyConfig, *,
                 placement: str = "pack",
                 slots_per_node: Optional[int] = None, tracer=None,
                 profiler=None, util_series: bool = True,
                 track_phases: bool = True):
        """``util_series=False`` / ``track_phases=False`` put the simulator
        in bounded-memory fleet mode (benchmarks/bench_simcore.py's ~1M-job
        replay): utilization integrals run on O(1) accumulators instead of a
        retained step series, and per-job phase decomposition is skipped."""
        self.cluster = Cluster(total_slots, slots_per_node=slots_per_node,
                               placement=placement)
        self.policy = ElasticPolicy(policy_cfg)
        self.queue = EventQueue()
        self.actions = _SimActions(self)
        self.workloads: Dict[str, SimWorkload] = {}
        self.util = UtilizationLog(total_slots, keep_series=util_series)
        # job_id -> queued completion Event, so a rescale CANCELS the stale
        # completion in place (tombstone, dropped inside the heap) instead of
        # paying a full dispatch when it eventually surfaces
        self._pending_complete: Dict[str, object] = {}
        self.now = 0.0
        self.total_overhead = 0.0
        self.last_preempt_ckpt_s = 0.0  # ckpt seconds of the latest preempt
        self.last_resume_s = 0.0        # restore seconds of the latest create
        self._evict_prefer: Optional[str] = None   # forced-shrink target node
        # observability (repro.obs): explicit tracer wins, else whatever
        # `obs.trace.install` put up, else the no-op null tracer
        self.tracer = tracer if tracer is not None else current_tracer()
        # self-profiler (repro.obs.profile): same precedence; None = off
        self.profiler = profiler if profiler is not None \
            else current_profiler()
        self.queue.profiler = self.profiler
        self.counters = Counters()
        self.latency = LatencyRecorder()
        # makespan decomposition (repro.obs.critical_path); a no-op ledger in
        # bounded-memory fleet mode
        self.phases = PhaseLedger() if track_phases else NullPhaseLedger()
        self.run_id = self.tracer.next_run_id()
        if self.tracer.enabled:
            # emitted from __init__ so subclass capacity bootstrap (cloud
            # node_up records) lands inside the run span
            self.tracer.emit("run_start", t=0.0, run=self.run_id,
                             slots=total_slots, sim=type(self).__name__)

    # -- bookkeeping ---------------------------------------------------------
    def _record_util(self):
        prof = self.profiler
        t0 = perf_counter() if prof is not None else 0.0
        self.util.record(self.now, self.cluster.used_slots)
        if self.cluster.node_count > 1:     # single-node: frag is undefined
            self.util.record_fragmentation(self.now,
                                           self.cluster.fragmentation())
        if prof is not None:
            prof.section("metrics_tick", perf_counter() - t0)

    def _rate(self, job: JobState) -> float:
        wl = self.workloads[job.job_id]
        return 1.0 / wl.scaling.time_per_step(job.replicas)

    def _sync_progress(self, job: JobState):
        if job.status != JobStatus.RUNNING:
            return
        start = max(job.last_progress_time, min(job.overhead_until, self.now))
        if self.now > start:
            job.work_remaining -= (self.now - start) * self._rate(job)
        job.last_progress_time = self.now

    def _cancel_completion(self, job: JobState) -> None:
        prev = self._pending_complete.pop(job.job_id, None)
        if prev is not None:
            self.queue.cancel(prev)

    def _schedule_completion(self, job: JobState):
        job.version += 1
        job_id = job.spec.job_id
        prev = self._pending_complete.pop(job_id, None)
        if prev is not None:            # the old event is now a tombstone
            self.queue.cancel(prev)
        begin = max(self.now, job.overhead_until)
        t_done = begin + job.work_remaining * \
            self.workloads[job_id].scaling.time_per_step(job.replicas)
        self._pending_complete[job_id] = self.queue.push(
            t_done, "complete", (job_id, job.version))

    # -- API -----------------------------------------------------------------
    def submit(self, spec: JobSpec, workload: SimWorkload):
        """Register an arrival at ``spec.submit_time``.  Arrival processing
        order depends only on (submit_time, -priority, job_id) — never on the
        order submit() was called in — so replaying a bursty trace (many
        arrivals collapsed onto one timestamp) is insertion-agnostic."""
        state = JobState(spec=spec, work_remaining=workload.total_work)
        self.workloads[spec.job_id] = workload
        self.queue.push(spec.submit_time, "submit", state,
                        tiebreak=(-spec.priority, spec.job_id))

    def run(self) -> ScheduleMetrics:
        if self.tracer.enabled:
            self._wire_decisions()
        # lazy progress sync: extension hooks that read work_remaining
        # (CostBenefitPolicy) pull the job up to date themselves instead of
        # the loop syncing every running job on every submit/complete
        self.policy.sync_job = self._sync_progress
        counters = self.counters
        prof = self.profiler
        batch: List = []
        stop = False
        n_events = 0    # folded into counters once, after the loop
        # one heap pass drains ALL events sharing the earliest timestamp
        # (tombstoned stale completions are dropped inside the pass); events
        # within the batch dispatch in exactly the old pop-by-pop order
        while not stop:
            if prof is None:
                if not self.queue.pop_batch(batch):
                    break
            else:
                t0 = perf_counter()
                n = self.queue.pop_batch(batch)
                prof.section("heap_pop", perf_counter() - t0)
                if not n:
                    break
            for ev in batch:
                if self._should_stop():
                    stop = True
                    break
                # an earlier event in THIS batch may have cancelled this one
                # (a same-timestamp admission shrinking a running job kills
                # its completion event); the per-event pop() used to drop it
                # at pop time, so the batch loop must re-check
                if ev.kind is _CANCELLED:
                    self.queue._popped(ev)
                    continue
                if prof is None:
                    self.now = max(self.now, ev.time)
                    n_events += 1
                    self._dispatch(ev)
                else:
                    t1 = perf_counter()
                    self.now = max(self.now, ev.time)
                    n_events += 1
                    self._dispatch(ev)
                    prof.event(ev.kind, perf_counter() - t1)
        counters.inc("events", n_events)
        counters.inc("stale_events", self.queue.stale_total)
        metrics = self._final_metrics()
        if self.tracer.enabled:
            self.tracer.emit(
                "run_end", t=self.now, run=self.run_id,
                total_cost=metrics.total_cost,
                transfer_cost=metrics.transfer_cost,
                preempt_overhead_cost=metrics.preempt_overhead_cost,
                dropped=metrics.dropped_jobs,
                rescales=metrics.rescale_count)
            self.tracer.flush()
        return metrics

    def _dispatch(self, ev) -> None:
        """Process one popped event (clock already advanced, counter
        ticked).  Split out of :meth:`run` so the profiler can time every
        event by kind with two ``perf_counter`` calls around one method."""
        if ev.kind == "submit":
            job: JobState = ev.payload
            self.cluster.add_job(job)
            self.phases.on_submit(job.job_id, self.now,
                                  priority=job.spec.priority)
            if self.tracer.enabled:
                self.tracer.emit("job_submit", t=self.now,
                                 job=job.job_id,
                                 priority=job.spec.priority,
                                 min=job.spec.min_replicas,
                                 max=job.spec.max_replicas)
            # policies that consult work_remaining (cost-benefit) sync the
            # job themselves via the sync_job hook — no sync-all pass here
            self.policy.on_new_job(self.cluster, job, self.now,
                                   self.actions)
        elif ev.kind == "complete":
            job_id, version = ev.payload
            if self._pending_complete.get(job_id) is ev:
                del self._pending_complete[job_id]
            job = self.cluster.jobs[job_id]
            if job.version != version or job.status != JobStatus.RUNNING:
                return         # stale event (job was rescaled since)
            self._sync_progress(job)
            if job.work_remaining > 1e-6:   # overhead pushed completion
                self._schedule_completion(job)
                return
            freed = job.replicas
            self.cluster.evict(job_id)
            job.status = JobStatus.COMPLETED
            job.end_time = self.now
            job.replicas = 0
            self._record_util()
            self.counters.inc("completions")
            self.latency.observe_completed(job)
            self.phases.on_complete(job_id, self.now)
            if self.tracer.enabled:
                self.tracer.emit("job_complete", t=self.now,
                                 job=job.job_id, slots=freed)
            self.policy.on_job_complete(self.cluster, freed, self.now,
                                        self.actions)
        else:
            # extension point: repro.cloud adds node_up / node_down /
            # spot_kill / autoscale_tick event kinds
            self._handle_event(ev)

    def _final_metrics(self) -> ScheduleMetrics:
        """Extension hook: CloudSimulator closes its cost ledger here so the
        base run loop can emit one ``run_end`` record with final dollars."""
        return compute_metrics(list(self.cluster.jobs.values()), self.util,
                               latency=self.latency,
                               counters=self.counters.as_dict(),
                               phases=self.phases)

    def _wire_decisions(self) -> None:
        """Bind a DecisionLog to every decision-carrying component (policies
        are often swapped after __init__, so this runs at the top of run())."""
        log = DecisionLog(self.tracer)
        if getattr(self.policy, "decisions", None) is None:
            self.policy.decisions = log

    def _handle_event(self, ev) -> None:
        raise ValueError(f"unknown event kind {ev.kind!r}")

    def _should_stop(self) -> bool:
        """Extension hook: lets subclasses end the run before the queue
        drains (cloud sims carry perpetual node-lifecycle events that would
        otherwise bill idle nodes out to their far-future spot fates)."""
        return False


# ---------------------------------------------------------------------------
# Workload generation (paper §4.3.1)
# ---------------------------------------------------------------------------

REPLICA_GRID = (1, 2, 4, 8, 16, 32, 64, 128)


def jacobi_workload(size: str) -> SimWorkload:
    d = JACOBI_SIZES[size]
    model = JacobiModel(d["grid_n"], d["timesteps"])
    return SimWorkload(
        scaling=model.scaling_model(REPLICA_GRID),
        total_work=float(d["timesteps"]),
        data_bytes=model.data_bytes,
    )


@lru_cache(maxsize=None)
def _jacobi_workload_cached(size: str) -> SimWorkload:
    """One shared SimWorkload per size for the default run_variant path:
    the simulator only ever reads workloads (scaling/total_work/data_bytes/
    rescale are immutable), and synthesizing the scaling points is ~10x the
    cost of a simulated event."""
    return jacobi_workload(size)


def make_jacobi_jobs(seed: int, n_jobs: int = 16, submission_gap: float = 90.0,
                     sizes: Optional[Sequence[str]] = None) -> List[JobSpec]:
    """16 jobs drawn from the 4 sizes with priorities U{1..5} (paper).
    ``sizes`` restricts the mix (e.g. ("small", "medium") for the cloud-cost
    benchmark, where jobs must not absorb arbitrary capacity)."""
    rng = np.random.default_rng(seed)
    sizes = list(sizes) if sizes is not None else list(JACOBI_SIZES)
    specs = []
    for i in range(n_jobs):
        size = sizes[int(rng.integers(len(sizes)))]
        d = JACOBI_SIZES[size]
        specs.append(JobSpec(
            job_id=f"job{i:03d}-{size}",
            priority=int(rng.integers(1, 6)),
            min_replicas=d["min_replicas"],
            max_replicas=d["max_replicas"],
            submit_time=i * submission_gap,
            workload=size,
        ))
    return specs


def variant_setup(variant: str, specs: Sequence[JobSpec], *,
                  rescale_gap: float = 180.0, launcher_reserve: int = 0):
    """Specs transform + policy for one scheduler variant (paper §4.3's four
    schedulers plus the preempting extension).  Returns ``(specs, pcfg,
    policy)`` where ``policy`` is None for the plain config-driven
    ElasticPolicy.  Shared by :func:`run_variant` and the trace-replay layer
    (``repro.workloads.replay``) so the variant semantics cannot drift."""
    policy = None
    if variant == "rigid_min":
        specs = [s.rigid(s.min_replicas) for s in specs]
        pcfg = PolicyConfig(rescale_gap=rescale_gap,
                            launcher_reserve=launcher_reserve)
    elif variant == "rigid_max":
        specs = [s.rigid(s.max_replicas) for s in specs]
        pcfg = PolicyConfig(rescale_gap=rescale_gap,
                            launcher_reserve=launcher_reserve)
    elif variant == "moldable":
        pcfg = PolicyConfig.moldable(launcher_reserve=launcher_reserve)
    elif variant == "elastic":
        pcfg = PolicyConfig(rescale_gap=rescale_gap,
                            launcher_reserve=launcher_reserve)
    elif variant == "elastic_preempt":
        from repro.core.autoscale import PreemptingPolicy
        pcfg = PolicyConfig(rescale_gap=rescale_gap,
                            launcher_reserve=launcher_reserve)
        policy = PreemptingPolicy(pcfg)
    else:
        raise ValueError(variant)
    return list(specs), pcfg, policy


def run_variant(variant: str, specs: Sequence[JobSpec], *, total_slots: int,
                rescale_gap: float = 180.0, launcher_reserve: int = 0,
                workload_fn: Callable[[JobSpec], SimWorkload] = None
                ) -> ScheduleMetrics:
    """Run one scheduling policy variant (paper §4.3's four schedulers)."""
    workload_fn = workload_fn or (lambda s: _jacobi_workload_cached(s.workload))
    specs, pcfg, policy = variant_setup(variant, specs,
                                        rescale_gap=rescale_gap,
                                        launcher_reserve=launcher_reserve)
    sim = Simulator(total_slots, pcfg)
    if policy is not None:
        sim.policy = policy
    for s in specs:
        sim.submit(s, workload_fn(s))
    return sim.run()


VARIANTS = ("rigid_min", "rigid_max", "moldable", "elastic")
