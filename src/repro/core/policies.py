"""Priority-based elastic scheduling policy — paper Fig. 2 / Fig. 3, faithful.

The policy is pure decision logic over a :class:`Cluster` view; effects go
through the :class:`Actions` interface, implemented by both the discrete-event
simulator (virtual clock) and the live operator (real JAX jobs).  This is what
lets one implementation serve contributions C2 and C3.

Pseudocode reconstruction notes (the published listing is garbled by PDF
extraction) are in DESIGN.md §6.3; tests/test_scheduler_policies.py pins each
behavior to a sentence of the paper's prose.

The four evaluated schedulers (paper §4.3) are all this one policy:
    rigid-min   jobs submitted with min==max==min_replicas
    rigid-max   jobs submitted with min==max==max_replicas
    moldable    rescale_gap = +inf (size picked at launch, never rescaled)
    elastic     the full policy
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Protocol

from repro.core.cluster import Cluster
from repro.core.job import JobState, JobStatus


class Actions(Protocol):
    """Effect interface; implementations must update cluster accounting
    synchronously (create/shrink/expand return success).

    Placement contract: every replica an implementation grants must be backed
    by a concrete node-owned slot (``Cluster.place``) and every replica it
    revokes must free one (``Cluster.evict``) — both the simulator's
    ``_SimActions`` and the live operator's ``_LiveActions`` thread placement
    through this way, so node kills and drains displace exactly the jobs
    resident on the affected node.  ``create``/``expand`` may return False
    when capacity raced away (a cordon or spot kill between the policy's
    ``free_slots`` read and the call); the policy then re-enqueues."""

    def create(self, job: JobState, replicas: int) -> bool: ...
    def expand(self, job: JobState, replicas: int) -> bool: ...
    def shrink(self, job: JobState, replicas: int) -> bool: ...
    def enqueue(self, job: JobState) -> None: ...


@dataclass(frozen=True)
class PolicyConfig:
    rescale_gap: float = 180.0        # T_rescale_gap (paper §3.2.1)
    launcher_reserve: int = 0         # paper's `freeSlots - 1` (MPI launcher
    #                                   pod); 1 reproduces the paper exactly,
    #                                   0 is the TPU default (DESIGN.md §2d)
    # Fig. 3's pseudocode redistributes ONLY the slots freed by the completing
    # job; slots that were already idle are never re-offered, which can strand
    # capacity forever (a queued job whose min exceeds every later completion
    # starves on an idle cluster).  True (default) offers freed + idle slots;
    # False is pseudocode-faithful.  See DESIGN.md §6.3 and the policy tests.
    redistribute_idle: bool = True

    @classmethod
    def moldable(cls, **kw) -> "PolicyConfig":
        kw.setdefault("rescale_gap", math.inf)
        return cls(**kw)


class ElasticPolicy:
    #: lazy progress-sync hook (fleet-scale refactor): the simulator wires
    #: this to its ``_sync_progress`` at run start, and extension hooks that
    #: read simulator-owned job state (CostBenefitPolicy's ``work_remaining``
    #: checks) call it first.  The base policy never reads such state, so the
    #: event loop no longer syncs every running job on every submit/complete
    #: just in case a subclass might look.
    sync_job = None

    def __init__(self, cfg: PolicyConfig):
        self.cfg = cfg
        # decision-audit sink (repro.obs.decisions.DecisionLog); None (the
        # default) records nothing — traced runs wire one in at run start
        self.decisions = None

    # -- extension hooks (see core/autoscale.py) ------------------------------
    def _priority(self, job: JobState, now: float) -> float:
        """Effective priority; AgingPolicy overrides (paper §3.2.2 'aging')."""
        return float(job.spec.priority)

    def _should_expand(self, job: JobState, new_replicas: int, now: float
                       ) -> bool:
        """CostBenefitPolicy overrides (paper §6: expansion must pay for its
        rescale overhead)."""
        return True

    def _should_shrink(self, job: JobState, new_replicas: int, now: float
                       ) -> bool:
        """CostBenefitPolicy overrides (paper §6: a nearly-finished job should
        run to completion instead of being shrunk)."""
        return True

    # -- helpers ------------------------------------------------------------
    def _sorted_desc(self, jobs, now: float):
        # fast path (fleet-scale refactor): with the base static priority the
        # key equals JobState.sort_key, and every caller passes a Cluster
        # query result (running/queued/all_schedulable) that is already in
        # that exact order — skip the O(n log n) re-sort per event.  Dynamic
        # priorities (AgingPolicy) override _priority and take the sort.
        if type(self)._priority is ElasticPolicy._priority:
            return jobs
        return sorted(jobs, key=lambda j: (-self._priority(j, now),
                                           j.spec.submit_time, j.spec.job_id))

    def _avail(self, cluster: Cluster) -> int:
        return cluster.free_slots - self.cfg.launcher_reserve

    def _gap_ok(self, job: JobState, now: float) -> bool:
        return now - job.last_action >= self.cfg.rescale_gap

    # -- Figure 2: a new job is submitted ------------------------------------
    def _admit_decision(self, job: JobState, now: float, verdict: str,
                        free: int, granted: int = 0, alternatives=None):
        if self.decisions is not None:
            spec = job.spec
            self.decisions.record(
                "admit", now, verdict,
                inputs={"job": spec.job_id, "priority": spec.priority,
                        "free": free, "granted": granted,
                        "min": spec.min_replicas, "max": spec.max_replicas},
                alternatives=alternatives)

    def on_new_job(self, cluster: Cluster, job: JobState, now: float,
                   act: Actions) -> None:
        spec = job.spec
        free = self._avail(cluster)
        replicas = spec.feasible(min(free, spec.max_replicas))
        if replicas >= spec.min_replicas:
            # start immediately; never shrink anyone if min fits (paper §3.2.1:
            # "run the higher priority job at its minimum replicas
            #  configuration to avoid a shrink call")
            if act.create(job, replicas):
                self._admit_decision(job, now, "start", free, replicas)
            else:
                act.enqueue(job)    # capacity shrank under us (spot kill)
                self._admit_decision(job, now, "enqueue_raced", free)
            return

        # dry pass: could shrinking strictly-lower/equal-priority running jobs
        # (outside their cool-down) free enough for min_replicas?
        considered = [] if self.decisions is not None else None
        running_desc = self._sorted_desc(cluster.running_jobs(), now)
        num_to_free = spec.min_replicas - free
        p_new = self._priority(job, now)    # `now` is fixed across the loop
        for j in reversed(running_desc):              # lowest priority first
            if num_to_free <= 0:
                break
            if self._priority(j, now) > p_new:
                if considered is not None:
                    considered.append({"job": j.job_id, "eligible": False,
                                       "why": "higher_priority"})
                break                                 # priority guard
            if not self._gap_ok(j, now):
                if considered is not None:
                    considered.append({"job": j.job_id, "eligible": False,
                                       "why": "rescale_gap"})
                continue
            shrinkable = max(0, j.replicas - j.spec.min_replicas)
            if considered is not None:
                considered.append({"job": j.job_id, "eligible": True,
                                   "shrinkable": shrinkable})
            num_to_free -= shrinkable
        if num_to_free > 0:
            act.enqueue(job)
            self._admit_decision(job, now, "enqueue", free,
                                 alternatives=considered)
            return

        # real pass: shrink toward the NEW job's max configuration
        min_to_free = spec.min_replicas - free
        max_to_free = spec.max_replicas - free
        for j in reversed(running_desc):
            if max_to_free <= 0:
                break
            if self._priority(j, now) > p_new:
                break
            if not self._gap_ok(j, now):
                continue
            if j.replicas > j.spec.min_replicas:
                target = j.spec.feasible(
                    max(j.spec.min_replicas, j.replicas - max_to_free))
                if target >= j.replicas or not self._should_shrink(j, target, now):
                    continue
                freed = j.replicas - target
                if act.shrink(j, target):
                    min_to_free -= freed
                    max_to_free -= freed
        if min_to_free > 0:
            act.enqueue(job)    # raced a cool-down; shouldn't normally happen
            self._admit_decision(job, now, "enqueue_raced", free,
                                 alternatives=considered)
            return
        free = self._avail(cluster)
        replicas = spec.feasible(min(free, spec.max_replicas))
        if replicas >= spec.min_replicas and act.create(job, replicas):
            self._admit_decision(job, now, "start_after_shrink", free,
                                 replicas, alternatives=considered)
        else:
            act.enqueue(job)
            self._admit_decision(job, now, "enqueue", free,
                                 alternatives=considered)

    # -- Figure 3: a job completed -------------------------------------------
    def on_job_complete(self, cluster: Cluster, freed_slots: int, now: float,
                        act: Actions) -> None:
        """Redistribute the freed slots (paper: numWorkers = freeWorkers(job))
        over running+queued jobs, highest priority first."""
        num = cluster.free_slots if self.cfg.redistribute_idle else freed_slots
        if num <= 0:
            return    # a yanked node can leave free_slots <= 0: nothing to
            #           offer, so skip building the schedulable list at all
        offered = num
        grants = [] if self.decisions is not None else None
        # offerable_jobs pre-filters the saturation test (running at max)
        # incrementally — the scan order and every decision are identical to
        # walking all_schedulable_jobs, but a loaded fleet's saturated bulk
        # is never touched
        for j in self._sorted_desc(cluster.offerable_jobs(), now):
            if num <= 0:
                break
            # the saturation test is retained verbatim: it still guards
            # free-standing JobStates handed in by tests, and keeps the
            # decision logic readable as Fig. 3's
            r = j.replicas
            spec = j.spec
            if r < spec.max_replicas and self._gap_ok(j, now):
                add = min(num, spec.max_replicas - r)
                new_r = spec.feasible(r + add)
                add = new_r - r
                if add > 0 and new_r >= spec.min_replicas:
                    if (j.status == JobStatus.RUNNING
                            and not self._should_expand(j, new_r, now)):
                        continue
                    started = j.status != JobStatus.RUNNING
                    ok = (act.create(j, new_r) if started
                          else act.expand(j, new_r))
                    if ok:
                        num -= add
                        if grants is not None:
                            grants.append({
                                "job": j.job_id, "to": new_r,
                                "kind": "start" if started else "expand"})
        # any remainder simply stays free
        if grants:
            self.decisions.record(
                "redistribute", now, f"granted_{len(grants)}",
                inputs={"freed": freed_slots, "offered": offered,
                        "leftover": num},
                alternatives=grants)
