"""Placement layer: slot -> concrete node ownership (paper: pods on nodes).

The counting :class:`~repro.core.cluster.Cluster` of earlier revisions knew
*how many* slots a job held but not *where*; a spot kill therefore shrank
"some" victims rather than the jobs actually resident on the killed node, and
the autoscaler could not pick the emptiest node to drain.  ``PlacementMap``
closes that gap: every slot has a stable global index, belongs to exactly one
node, and is owned by at most one job.

Concepts
--------
- **node**: a named group of slots with a stable, contiguous index range
  (contiguity within a node is the ICI/pod-affinity locality analog).
- **cordon**: a cordoned node is excluded from capacity and from new
  placement, but existing residents stay until migrated/evicted — the
  ``kubectl cordon``/drain analog used by spot kills and scale-down drains.
- **zone**: every node belongs to a failure zone (cloud: an availability
  zone whose spot capacity is reclaimed in correlated bursts).  Nodes added
  without a zone get a private one (zone == node_id), so zone-aware logic
  degenerates gracefully on zone-oblivious clusters.
- **strategy**: where new slots go.  ``pack`` fills the fullest non-empty
  node first (keeps whole nodes empty so the autoscaler can release them);
  ``spread`` round-robins across the emptiest nodes (minimizes how much of
  any single job one node kill can take out); ``zone_spread`` balances a
  job's slots across zones first (minimizes how much of the job one
  correlated ZONE reclaim can take out), packing within the chosen zone so
  the idle-dollar cost of diversification stays small.

Fleet-scale accounting: free counts, capacity, per-job slot sets, and the
fragmentation aggregate are all maintained incrementally on
place/evict/add_node/remove_node/cordon — ``free()``, ``total_capacity``,
``owned()`` and ``fragmentation()`` are O(1), never node scans.  ``pack``
and ``spread`` pick nodes through lazy min-heaps keyed exactly like the old
per-call sorts (stale entries are validated against the node's current free
count at pop time), so the chosen slot sequence is bit-identical to the
scan-and-sort implementation while each placement costs O(log nodes).

Invariants (property-tested in tests/test_placement_properties.py):
- no slot is ever owned by two jobs;
- per-node residency sums equal the total owned-slot count;
- cordoned capacity is excluded from ``total_capacity`` and ``free()``;
- the incremental aggregates reconcile against a full recount (``check()``).
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_left, insort
from typing import Dict, List, Optional, Set


class PlacementError(RuntimeError):
    """A placement request that cannot be satisfied (not a crash: callers
    that race capacity changes should pre-check with ``free()``)."""


class PlacementMap:
    STRATEGIES = ("pack", "spread", "zone_spread")

    def __init__(self, strategy: str = "pack"):
        assert strategy in self.STRATEGIES, strategy
        self.default_strategy = strategy
        self._next_slot = 0
        self._seq = itertools.count()
        self._slots: Dict[str, List[int]] = {}        # node -> slot indices
        self._node_seq: Dict[str, int] = {}           # deterministic tie-break
        self._cordoned: Set[str] = set()
        self._owner: Dict[int, Optional[str]] = {}    # slot -> job (None free)
        self._slot_node: Dict[int, str] = {}
        self._zone: Dict[str, str] = {}               # node -> failure zone
        # -- incremental aggregates (the fleet-scale hot path) ---------------
        self._free_ids: Dict[str, List[int]] = {}     # node -> SORTED free ids
        self._job_slots: Dict[str, Set[int]] = {}     # job -> owned slot ids
        self._free_sched = 0        # free slots on schedulable nodes
        self._cap_sched = 0         # capacity of schedulable nodes
        self._free_on_empty = 0     # free slots on EMPTY schedulable nodes
        # lazy selection heaps: entries carry the key the node had when
        # pushed; pop-time validation against the current free count drops
        # stale entries, so the min valid entry is the true strategy choice
        self._pack_heap: List[tuple] = []   # (is_empty, free, seq, nid)
        self._spread_heap: List[tuple] = []  # (-free, seq, nid)

    # -- aggregate maintenance ----------------------------------------------
    def _push_keys(self, nid: str) -> None:
        """Re-key a node in the selection heaps after its free count
        changed (lazy update: old entries are invalidated by comparison)."""
        f = len(self._free_ids[nid])
        if f == 0 or nid in self._cordoned:
            return
        seq = self._node_seq[nid]
        heapq.heappush(self._pack_heap,
                       (f == len(self._slots[nid]), f, seq, nid))
        heapq.heappush(self._spread_heap, (-f, seq, nid))
        # bound stale-entry growth: rebuild once the heaps dwarf the fleet
        if len(self._pack_heap) > 64 + 4 * len(self._slots):
            self._rebuild_heaps()

    def _rebuild_heaps(self) -> None:
        pack, spread = [], []
        for nid, fl in self._free_ids.items():
            f = len(fl)
            if f and nid not in self._cordoned:
                seq = self._node_seq[nid]
                pack.append((f == len(self._slots[nid]), f, seq, nid))
                spread.append((-f, seq, nid))
        heapq.heapify(pack)
        heapq.heapify(spread)
        self._pack_heap, self._spread_heap = pack, spread

    def _assign(self, slot: int, job_id: str, push: bool = True) -> None:
        """Give a FREE slot to ``job_id``, updating every aggregate.
        ``push=False`` defers the heap re-key to the caller (batch paths
        re-key each touched node once at the end)."""
        nid = self._slot_node[slot]
        fl = self._free_ids[nid]
        fl.pop(bisect_left(fl, slot))
        self._owner[slot] = job_id
        self._job_slots.setdefault(job_id, set()).add(slot)
        if nid not in self._cordoned:
            if len(fl) + 1 == len(self._slots[nid]):   # node was empty
                self._free_on_empty -= len(self._slots[nid])
            self._free_sched -= 1
            if push:
                self._push_keys(nid)

    def _release(self, slot: int) -> None:
        """Return an owned slot to the free pool, updating every aggregate."""
        job_id = self._owner[slot]
        self._owner[slot] = None
        owned = self._job_slots[job_id]
        owned.discard(slot)
        if not owned:
            del self._job_slots[job_id]
        nid = self._slot_node[slot]
        fl = self._free_ids[nid]
        insort(fl, slot)
        if nid not in self._cordoned:
            self._free_sched += 1
            if len(fl) == len(self._slots[nid]):       # node is empty again
                self._free_on_empty += len(self._slots[nid])
            self._push_keys(nid)

    # -- node lifecycle ------------------------------------------------------
    def add_node(self, node_id: str, slots: int,
                 zone: Optional[str] = None) -> List[int]:
        assert node_id not in self._slots, node_id
        assert slots >= 1, slots
        ids = list(range(self._next_slot, self._next_slot + slots))
        self._next_slot += slots
        self._slots[node_id] = ids
        self._node_seq[node_id] = next(self._seq)
        # zoneless nodes get a private zone so zone_spread degenerates to a
        # per-node spread instead of treating the cluster as one blast domain
        self._zone[node_id] = zone if zone is not None else node_id
        for i in ids:
            self._owner[i] = None
            self._slot_node[i] = node_id
        self._free_ids[node_id] = list(ids)
        self._cap_sched += slots
        self._free_sched += slots
        self._free_on_empty += slots
        self._push_keys(node_id)
        return ids

    def remove_node(self, node_id: str) -> int:
        """Retire an EMPTY node (drain residents first — see cordon/evict/
        migrate).  Raises :class:`PlacementError` while residents remain."""
        res = self.residents(node_id)
        if res:
            raise PlacementError(
                f"remove_node({node_id}): still hosts {res}")
        ids = self._slots.pop(node_id)
        self._node_seq.pop(node_id)
        self._zone.pop(node_id)
        if node_id not in self._cordoned:       # an empty schedulable node
            self._cap_sched -= len(ids)
            self._free_sched -= len(ids)
            self._free_on_empty -= len(ids)
        self._cordoned.discard(node_id)
        del self._free_ids[node_id]
        for i in ids:
            del self._owner[i]
            del self._slot_node[i]
        return len(ids)

    def cordon(self, node_id: str) -> None:
        """Exclude a node from capacity and from new placement; residents
        stay until evicted/migrated (drain)."""
        assert node_id in self._slots, node_id
        if node_id in self._cordoned:
            return
        f = len(self._free_ids[node_id])
        cap = len(self._slots[node_id])
        self._cap_sched -= cap
        self._free_sched -= f
        if f == cap:
            self._free_on_empty -= cap
        self._cordoned.add(node_id)

    def uncordon(self, node_id: str) -> None:
        assert node_id in self._slots, node_id
        if node_id not in self._cordoned:
            return
        self._cordoned.discard(node_id)
        f = len(self._free_ids[node_id])
        cap = len(self._slots[node_id])
        self._cap_sched += cap
        self._free_sched += f
        if f == cap:
            self._free_on_empty += cap
        self._push_keys(node_id)

    def is_cordoned(self, node_id: str) -> bool:
        return node_id in self._cordoned

    # -- queries -------------------------------------------------------------
    def nodes(self) -> List[str]:
        return list(self._slots)

    @property
    def node_count(self) -> int:
        return len(self._slots)

    def capacity(self, node_id: str) -> int:
        return len(self._slots[node_id])

    @property
    def total_capacity(self) -> int:
        """Schedulable slots: cordoned nodes are already on their way out."""
        return self._cap_sched

    def free(self, node_id: Optional[str] = None) -> int:
        """Free slots on schedulable nodes (or on one specific node)."""
        if node_id is not None:
            return len(self._free_ids[node_id])
        return self._free_sched

    def owned(self, job_id: str) -> int:
        return len(self._job_slots.get(job_id, ()))

    def slots_of(self, job_id: str) -> List[int]:
        return sorted(self._job_slots.get(job_id, ()))

    def node_of(self, slot: int) -> str:
        return self._slot_node[slot]

    def residents(self, node_id: str) -> Dict[str, int]:
        """job_id -> slot count resident on this node."""
        out: Dict[str, int] = {}
        for i in self._slots.get(node_id, ()):
            o = self._owner[i]
            if o is not None:
                out[o] = out.get(o, 0) + 1
        return out

    def resident_count(self, node_id: str) -> int:
        return sum(self.residents(node_id).values())

    def job_nodes(self, job_id: str) -> Dict[str, int]:
        """node_id -> slot count this job holds there (its blast footprint)."""
        out: Dict[str, int] = {}
        for i in sorted(self._job_slots.get(job_id, ())):
            nid = self._slot_node[i]
            out[nid] = out.get(nid, 0) + 1
        return out

    def zone_of(self, node_id: str) -> str:
        return self._zone[node_id]

    def job_zones(self, job_id: str) -> Dict[str, int]:
        """zone -> slot count this job holds there (its CORRELATED blast
        footprint: what one zone reclaim can take out at once)."""
        out: Dict[str, int] = {}
        for nid, cnt in self.job_nodes(job_id).items():
            z = self._zone[nid]
            out[z] = out.get(z, 0) + cnt
        return out

    def fragmentation(self) -> float:
        """Fraction of free schedulable capacity stranded on partially-used
        nodes (a whole-node consumer — scale-down, a min_replicas burst —
        cannot use it without a drain).  0 = all free capacity sits on empty
        nodes; 1 = every free slot shares a node with running work."""
        if not self._free_sched:
            return 0.0
        return 1.0 - self._free_on_empty / self._free_sched

    # -- placement -----------------------------------------------------------
    def _pop_pack(self) -> Optional[str]:
        """Fullest non-empty schedulable node with free slots (pack order);
        stale heap entries are discarded by comparing against the node's
        current key."""
        heap = self._pack_heap
        while heap:
            empty, f, seq, nid = heapq.heappop(heap)
            if (self._node_seq.get(nid) == seq
                    and nid not in self._cordoned
                    and len(self._free_ids[nid]) == f):
                return nid
        return None

    def _pop_spread(self) -> Optional[str]:
        """Emptiest schedulable node with free slots (spread order)."""
        heap = self._spread_heap
        while heap:
            negf, seq, nid = heapq.heappop(heap)
            if (self._node_seq.get(nid) == seq
                    and nid not in self._cordoned
                    and len(self._free_ids[nid]) == -negf):
                return nid
        return None

    def place(self, job_id: str, n: int, strategy: Optional[str] = None
              ) -> List[int]:
        """Assign ``n`` free slots to ``job_id`` per the strategy; returns the
        chosen slot indices.  All-or-nothing: raises :class:`PlacementError`
        (mutating nothing) when fewer than ``n`` schedulable slots are free."""
        assert n >= 1, n
        strategy = strategy or self.default_strategy
        assert strategy in self.STRATEGIES, strategy
        if self._free_sched < n:
            raise PlacementError(
                f"place({job_id}, {n}): only {self.free()} slots free")
        chosen: List[int] = []
        if strategy == "zone_spread":
            # one slot at a time into the zone where the job currently holds
            # the fewest slots (ties: most free capacity, then zone name) —
            # bounds the correlated blast: a fresh n-slot placement leaves at
            # most ceil(n / zones_with_capacity) slots in any one zone.
            # Within the chosen zone, pack (fullest non-empty node first) so
            # diversification does not also fragment every node.
            free_ids: Dict[str, List[int]] = {
                nid: list(fl) for nid, fl in self._free_ids.items()
                if fl and nid not in self._cordoned}
            zone_free: Dict[str, List[str]] = {}
            for nid in free_ids:
                zone_free.setdefault(self._zone[nid], []).append(nid)
            held = self.job_zones(job_id)
            touched: Set[str] = set()
            while len(chosen) < n:
                z = min(zone_free, key=lambda k: (
                    held.get(k, 0),
                    -sum(len(free_ids[nid]) for nid in zone_free[k]), k))
                nid = min(zone_free[z], key=lambda k: (
                    len(free_ids[k]) == len(self._slots[k]),  # empties last
                    len(free_ids[k]),                         # least free
                    self._node_seq[k]))
                slot = free_ids[nid].pop(0)
                # selection runs on the local free_ids copies, so the heap
                # re-key can wait until the loop is done (once per node)
                self._assign(slot, job_id, push=False)
                touched.add(nid)
                chosen.append(slot)
                held[z] = held.get(z, 0) + 1
                if not free_ids[nid]:
                    del free_ids[nid]
                    zone_free[z].remove(nid)
                    if not zone_free[z]:
                        del zone_free[z]
            for nid in touched:
                self._push_keys(nid)
        elif strategy == "spread":
            # one slot at a time from the currently-emptiest node
            while len(chosen) < n:
                nid = self._pop_spread()
                slot = self._free_ids[nid][0]
                self._assign(slot, job_id)
                chosen.append(slot)
        else:                                         # pack: fullest first
            # taking slots never raises another node's pack rank, so popping
            # the lazy heap reproduces the one-shot sorted order exactly.
            # Bulk form of _assign: every popped node is either drained to
            # zero (no heap key needed) or is the last node touched (re-keyed
            # once after the loop) — per-slot heap churn drops to zero.
            owner = self._owner
            owned = self._job_slots.setdefault(job_id, set())
            nid = None
            while len(chosen) < n:
                nid = self._pop_pack()                # never cordoned
                fl = self._free_ids[nid]
                k = min(n - len(chosen), len(fl))
                take = fl[:k]
                del fl[:k]
                for i in take:
                    owner[i] = job_id
                owned.update(take)
                cap = len(self._slots[nid])
                if len(fl) + k == cap:                # node was empty
                    self._free_on_empty -= cap
                self._free_sched -= k
                chosen.extend(take)
            if nid is not None and self._free_ids[nid]:
                self._push_keys(nid)
        return sorted(chosen)

    def evict(self, job_id: str, n: Optional[int] = None,
              prefer: Optional[str] = None) -> List[int]:
        """Free ``n`` of the job's slots (all when None).  Order: the
        ``prefer`` node first, then cordoned nodes, then — under pack/spread
        — nodes where the job holds the fewest slots (clearing its footprint
        off marginal nodes).  Under ``zone_spread`` the tail order instead
        drains the job's FATTEST zone first: thin-first eviction would strip
        the minority zones on every shrink and quietly re-concentrate the
        job into one blast domain, undoing exactly what the placement
        diversified for."""
        owned = self.slots_of(job_id)
        presorted = False
        if n is None or n >= len(owned):
            # total eviction: every slot goes, so victim ordering (and the
            # footprint bookkeeping that feeds it) is irrelevant
            victims = owned
            presorted = True            # slots_of returns sorted
        else:
            foot = self.job_nodes(job_id)
            zone_aware = self.default_strategy == "zone_spread"
            def key(slot: int, zfoot):
                nid = self._slot_node[slot]
                return (nid != prefer,             # preferred node first
                        nid not in self._cordoned,  # then draining nodes
                        -zfoot[self._zone[nid]] if zone_aware else 0,
                        foot[nid],                 # then thin footprints
                        self._node_seq[nid],
                        -slot)                     # highest index first
            if not zone_aware and len(foot) == 1:
                # all slots share one node: every key component except -slot
                # is constant, so the victim set is just the n highest indices
                victims = owned[len(owned) - n:]
                presorted = True
            elif zone_aware:
                # pick one victim at a time, re-ranking as zone footprints
                # fall: a one-shot sort against the initial footprint would
                # drain the fattest zone wholesale and re-concentrate the
                # survivor slots
                zfoot = self.job_zones(job_id)
                pool = list(owned)
                victims = []
                for _ in range(min(n, len(pool))):
                    slot = min(pool, key=lambda s: key(s, zfoot))
                    pool.remove(slot)
                    victims.append(slot)
                    nid = self._slot_node[slot]
                    zfoot[self._zone[nid]] -= 1
                    foot[nid] -= 1
            else:
                victims = sorted(owned, key=lambda s: key(s, None))[:n]
        if not victims:
            return []
        # bulk form of _release: aggregates and heap keys update once per
        # touched node instead of once per slot
        job_owned = self._job_slots[job_id]
        job_owned.difference_update(victims)
        if not job_owned:
            del self._job_slots[job_id]
        owner = self._owner
        if len(self._slots) == 1:       # single node: no grouping needed
            for i in victims:
                owner[i] = None
            by_node = {next(iter(self._slots)): victims}
        else:
            by_node: Dict[str, List[int]] = {}
            for i in victims:
                owner[i] = None
                nid = self._slot_node[i]
                g = by_node.get(nid)
                if g is None:
                    by_node[nid] = [i]
                else:
                    g.append(i)
        for nid, group in by_node.items():
            fl = self._free_ids[nid]
            # timsort merges the two sorted runs in one C call
            fl.extend(group)
            fl.sort()
            if nid not in self._cordoned:
                self._free_sched += len(group)
                if len(fl) == len(self._slots[nid]):   # node is empty again
                    self._free_on_empty += len(self._slots[nid])
                self._push_keys(nid)
        return victims if presorted else sorted(victims)

    def migrate(self, job_id: str, from_node: str,
                strategy: Optional[str] = None) -> int:
        """Move as many of the job's slots on ``from_node`` as fit onto free
        schedulable slots elsewhere; returns the number moved.  Cordon the
        node first if new placement must not land back on it."""
        resident = [i for i in self._slots[from_node]
                    if self._owner[i] == job_id]
        # free slots NOT on from_node (it may be uncordoned)
        movable = min(len(resident),
                      self.free() - (0 if from_node in self._cordoned
                                     else self.free(from_node)))
        if movable <= 0:
            return 0
        was_cordoned = from_node in self._cordoned
        self.cordon(from_node)                     # keep place() off it
        try:
            for i in resident[:movable]:
                self._release(i)
            self.place(job_id, movable, strategy)
        finally:
            if not was_cordoned:
                self.uncordon(from_node)
        return movable

    # -- invariants (test hook) ----------------------------------------------
    def check(self) -> None:
        owners: Dict[str, int] = {}
        for i, o in self._owner.items():
            assert i in self._slot_node
            if o is not None:
                owners[o] = owners.get(o, 0) + 1
        per_node = sum(self.resident_count(nid) for nid in self._slots)
        assert per_node == sum(owners.values()), (per_node, owners)
        assert 0.0 <= self.fragmentation() <= 1.0
        # incremental aggregates reconcile against a full recount
        for job_id, slots in self._job_slots.items():
            assert slots, job_id
            assert all(self._owner[i] == job_id for i in slots)
        assert owners == {j: len(s) for j, s in self._job_slots.items()}
        free_sched = cap_sched = free_on_empty = 0
        for nid, ids in self._slots.items():
            fl = self._free_ids[nid]
            assert fl == sorted(i for i in ids if self._owner[i] is None)
            if nid not in self._cordoned:
                free_sched += len(fl)
                cap_sched += len(ids)
                if len(fl) == len(ids):
                    free_on_empty += len(ids)
        assert free_sched == self._free_sched, (free_sched, self._free_sched)
        assert cap_sched == self._cap_sched, (cap_sched, self._cap_sched)
        assert free_on_empty == self._free_on_empty, \
            (free_on_empty, self._free_on_empty)
