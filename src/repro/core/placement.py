"""Placement layer: slot -> concrete node ownership (paper: pods on nodes).

The counting :class:`~repro.core.cluster.Cluster` of earlier revisions knew
*how many* slots a job held but not *where*; a spot kill therefore shrank
"some" victims rather than the jobs actually resident on the killed node, and
the autoscaler could not pick the emptiest node to drain.  ``PlacementMap``
closes that gap: every slot has a stable global index, belongs to exactly one
node, and is owned by at most one job.

Concepts
--------
- **node**: a named group of slots with a stable, contiguous index range
  (contiguity within a node is the ICI/pod-affinity locality analog).
- **cordon**: a cordoned node is excluded from capacity and from new
  placement, but existing residents stay until migrated/evicted — the
  ``kubectl cordon``/drain analog used by spot kills and scale-down drains.
- **zone**: every node belongs to a failure zone (cloud: an availability
  zone whose spot capacity is reclaimed in correlated bursts).  Nodes added
  without a zone get a private one (zone == node_id), so zone-aware logic
  degenerates gracefully on zone-oblivious clusters.
- **strategy**: where new slots go.  ``pack`` fills the fullest non-empty
  node first (keeps whole nodes empty so the autoscaler can release them);
  ``spread`` round-robins across the emptiest nodes (minimizes how much of
  any single job one node kill can take out); ``zone_spread`` balances a
  job's slots across zones first (minimizes how much of the job one
  correlated ZONE reclaim can take out), packing within the chosen zone so
  the idle-dollar cost of diversification stays small.

Invariants (property-tested in tests/test_placement_properties.py):
- no slot is ever owned by two jobs;
- per-node residency sums equal the total owned-slot count;
- cordoned capacity is excluded from ``total_capacity`` and ``free()``.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Set


class PlacementError(RuntimeError):
    """A placement request that cannot be satisfied (not a crash: callers
    that race capacity changes should pre-check with ``free()``)."""


class PlacementMap:
    STRATEGIES = ("pack", "spread", "zone_spread")

    def __init__(self, strategy: str = "pack"):
        assert strategy in self.STRATEGIES, strategy
        self.default_strategy = strategy
        self._next_slot = 0
        self._seq = itertools.count()
        self._slots: Dict[str, List[int]] = {}        # node -> slot indices
        self._node_seq: Dict[str, int] = {}           # deterministic tie-break
        self._cordoned: Set[str] = set()
        self._owner: Dict[int, Optional[str]] = {}    # slot -> job (None free)
        self._slot_node: Dict[int, str] = {}
        self._zone: Dict[str, str] = {}               # node -> failure zone

    # -- node lifecycle ------------------------------------------------------
    def add_node(self, node_id: str, slots: int,
                 zone: Optional[str] = None) -> List[int]:
        assert node_id not in self._slots, node_id
        assert slots >= 1, slots
        ids = list(range(self._next_slot, self._next_slot + slots))
        self._next_slot += slots
        self._slots[node_id] = ids
        self._node_seq[node_id] = next(self._seq)
        # zoneless nodes get a private zone so zone_spread degenerates to a
        # per-node spread instead of treating the cluster as one blast domain
        self._zone[node_id] = zone if zone is not None else node_id
        for i in ids:
            self._owner[i] = None
            self._slot_node[i] = node_id
        return ids

    def remove_node(self, node_id: str) -> int:
        """Retire an EMPTY node (drain residents first — see cordon/evict/
        migrate).  Raises :class:`PlacementError` while residents remain."""
        res = self.residents(node_id)
        if res:
            raise PlacementError(
                f"remove_node({node_id}): still hosts {res}")
        ids = self._slots.pop(node_id)
        self._node_seq.pop(node_id)
        self._zone.pop(node_id)
        self._cordoned.discard(node_id)
        for i in ids:
            del self._owner[i]
            del self._slot_node[i]
        return len(ids)

    def cordon(self, node_id: str) -> None:
        """Exclude a node from capacity and from new placement; residents
        stay until evicted/migrated (drain)."""
        assert node_id in self._slots, node_id
        self._cordoned.add(node_id)

    def uncordon(self, node_id: str) -> None:
        assert node_id in self._slots, node_id
        self._cordoned.discard(node_id)

    def is_cordoned(self, node_id: str) -> bool:
        return node_id in self._cordoned

    # -- queries -------------------------------------------------------------
    def nodes(self) -> List[str]:
        return list(self._slots)

    @property
    def node_count(self) -> int:
        return len(self._slots)

    def capacity(self, node_id: str) -> int:
        return len(self._slots[node_id])

    @property
    def total_capacity(self) -> int:
        """Schedulable slots: cordoned nodes are already on their way out."""
        return sum(len(ids) for nid, ids in self._slots.items()
                   if nid not in self._cordoned)

    def free(self, node_id: Optional[str] = None) -> int:
        """Free slots on schedulable nodes (or on one specific node)."""
        if node_id is not None:
            return sum(1 for i in self._slots[node_id]
                       if self._owner[i] is None)
        return sum(self.free(nid) for nid in self._slots
                   if nid not in self._cordoned)

    def owned(self, job_id: str) -> int:
        return sum(1 for o in self._owner.values() if o == job_id)

    def slots_of(self, job_id: str) -> List[int]:
        return sorted(i for i, o in self._owner.items() if o == job_id)

    def node_of(self, slot: int) -> str:
        return self._slot_node[slot]

    def residents(self, node_id: str) -> Dict[str, int]:
        """job_id -> slot count resident on this node."""
        out: Dict[str, int] = {}
        for i in self._slots.get(node_id, ()):
            o = self._owner[i]
            if o is not None:
                out[o] = out.get(o, 0) + 1
        return out

    def resident_count(self, node_id: str) -> int:
        return sum(self.residents(node_id).values())

    def job_nodes(self, job_id: str) -> Dict[str, int]:
        """node_id -> slot count this job holds there (its blast footprint)."""
        out: Dict[str, int] = {}
        for i, o in self._owner.items():
            if o == job_id:
                nid = self._slot_node[i]
                out[nid] = out.get(nid, 0) + 1
        return out

    def zone_of(self, node_id: str) -> str:
        return self._zone[node_id]

    def job_zones(self, job_id: str) -> Dict[str, int]:
        """zone -> slot count this job holds there (its CORRELATED blast
        footprint: what one zone reclaim can take out at once)."""
        out: Dict[str, int] = {}
        for nid, cnt in self.job_nodes(job_id).items():
            z = self._zone[nid]
            out[z] = out.get(z, 0) + cnt
        return out

    def fragmentation(self) -> float:
        """Fraction of free schedulable capacity stranded on partially-used
        nodes (a whole-node consumer — scale-down, a min_replicas burst —
        cannot use it without a drain).  0 = all free capacity sits on empty
        nodes; 1 = every free slot shares a node with running work."""
        free_total = 0
        free_on_empty = 0
        for nid in self._slots:
            if nid in self._cordoned:
                continue
            f = self.free(nid)
            free_total += f
            if f == len(self._slots[nid]):
                free_on_empty += f
        return 1.0 - free_on_empty / free_total if free_total else 0.0

    # -- placement -----------------------------------------------------------
    def place(self, job_id: str, n: int, strategy: Optional[str] = None
              ) -> List[int]:
        """Assign ``n`` free slots to ``job_id`` per the strategy; returns the
        chosen slot indices.  All-or-nothing: raises :class:`PlacementError`
        (mutating nothing) when fewer than ``n`` schedulable slots are free."""
        assert n >= 1, n
        strategy = strategy or self.default_strategy
        assert strategy in self.STRATEGIES, strategy
        # one scan up front; strategies then work off the free-slot map (the
        # scheduler's hottest path — no per-slot rescans)
        free_ids: Dict[str, List[int]] = {}
        for nid, ids in self._slots.items():
            if nid in self._cordoned:
                continue
            f = [i for i in ids if self._owner[i] is None]
            if f:
                free_ids[nid] = f
        if sum(len(f) for f in free_ids.values()) < n:
            raise PlacementError(
                f"place({job_id}, {n}): only {self.free()} slots free")
        chosen: List[int] = []
        if strategy == "zone_spread":
            # one slot at a time into the zone where the job currently holds
            # the fewest slots (ties: most free capacity, then zone name) —
            # bounds the correlated blast: a fresh n-slot placement leaves at
            # most ceil(n / zones_with_capacity) slots in any one zone.
            # Within the chosen zone, pack (fullest non-empty node first) so
            # diversification does not also fragment every node.
            zone_free: Dict[str, List[str]] = {}
            for nid in free_ids:
                zone_free.setdefault(self._zone[nid], []).append(nid)
            held = self.job_zones(job_id)
            while len(chosen) < n:
                z = min(zone_free, key=lambda k: (
                    held.get(k, 0),
                    -sum(len(free_ids[nid]) for nid in zone_free[k]), k))
                nid = min(zone_free[z], key=lambda k: (
                    len(free_ids[k]) == len(self._slots[k]),  # empties last
                    len(free_ids[k]),                         # least free
                    self._node_seq[k]))
                slot = free_ids[nid].pop(0)
                self._owner[slot] = job_id
                chosen.append(slot)
                held[z] = held.get(z, 0) + 1
                if not free_ids[nid]:
                    del free_ids[nid]
                    zone_free[z].remove(nid)
                    if not zone_free[z]:
                        del zone_free[z]
        elif strategy == "spread":
            # one slot at a time from the currently-emptiest node
            while len(chosen) < n:
                nid = max(free_ids, key=lambda k: (len(free_ids[k]),
                                                   -self._node_seq[k]))
                slot = free_ids[nid].pop(0)
                self._owner[slot] = job_id
                chosen.append(slot)
                if not free_ids[nid]:
                    del free_ids[nid]
        else:                                         # pack: fullest first
            order = sorted(free_ids, key=lambda k: (
                len(free_ids[k]) == len(self._slots[k]),  # empties last
                len(free_ids[k]),                         # least free first
                self._node_seq[k]))
            for nid in order:
                take = free_ids[nid][:n - len(chosen)]
                for i in take:
                    self._owner[i] = job_id
                chosen.extend(take)
                if len(chosen) == n:
                    break
        return sorted(chosen)

    def evict(self, job_id: str, n: Optional[int] = None,
              prefer: Optional[str] = None) -> List[int]:
        """Free ``n`` of the job's slots (all when None).  Order: the
        ``prefer`` node first, then cordoned nodes, then — under pack/spread
        — nodes where the job holds the fewest slots (clearing its footprint
        off marginal nodes).  Under ``zone_spread`` the tail order instead
        drains the job's FATTEST zone first: thin-first eviction would strip
        the minority zones on every shrink and quietly re-concentrate the
        job into one blast domain, undoing exactly what the placement
        diversified for."""
        owned = self.slots_of(job_id)
        if n is None:
            n = len(owned)
        foot = self.job_nodes(job_id)
        zone_aware = self.default_strategy == "zone_spread"

        def key(slot: int, zfoot):
            nid = self._slot_node[slot]
            return (nid != prefer,                 # preferred node first
                    nid not in self._cordoned,     # then draining nodes
                    -zfoot[self._zone[nid]] if zone_aware else 0,
                    foot[nid],                     # then thin footprints
                    self._node_seq[nid],
                    -slot)                         # highest index first
        if zone_aware:
            # pick one victim at a time, re-ranking as zone footprints fall:
            # a one-shot sort against the initial footprint would drain the
            # fattest zone wholesale and re-concentrate the survivor slots
            zfoot = self.job_zones(job_id)
            pool = list(owned)
            victims = []
            for _ in range(min(n, len(pool))):
                slot = min(pool, key=lambda s: key(s, zfoot))
                pool.remove(slot)
                victims.append(slot)
                nid = self._slot_node[slot]
                zfoot[self._zone[nid]] -= 1
                foot[nid] -= 1
        else:
            victims = sorted(owned, key=lambda s: key(s, None))[:n]
        for i in victims:
            self._owner[i] = None
        return sorted(victims)

    def migrate(self, job_id: str, from_node: str,
                strategy: Optional[str] = None) -> int:
        """Move as many of the job's slots on ``from_node`` as fit onto free
        schedulable slots elsewhere; returns the number moved.  Cordon the
        node first if new placement must not land back on it."""
        resident = [i for i in self._slots[from_node]
                    if self._owner[i] == job_id]
        # free slots NOT on from_node (it may be uncordoned)
        movable = min(len(resident),
                      self.free() - (0 if from_node in self._cordoned
                                     else self.free(from_node)))
        if movable <= 0:
            return 0
        was_cordoned = from_node in self._cordoned
        self._cordoned.add(from_node)              # keep place() off it
        try:
            for i in resident[:movable]:
                self._owner[i] = None
            self.place(job_id, movable, strategy)
        finally:
            if not was_cordoned:
                self._cordoned.discard(from_node)
        return movable

    # -- invariants (test hook) ----------------------------------------------
    def check(self) -> None:
        owners: Dict[str, int] = {}
        for i, o in self._owner.items():
            assert i in self._slot_node
            if o is not None:
                owners[o] = owners.get(o, 0) + 1
        per_node = sum(self.resident_count(nid) for nid in self._slots)
        assert per_node == sum(owners.values()), (per_node, owners)
        assert 0.0 <= self.fragmentation() <= 1.0
