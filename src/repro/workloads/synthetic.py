"""Seeded synthetic workload generators — same :class:`Trace` type as the
CSV loaders, so benchmarks swap arrival shapes without touching replay code.

Arrival processes (the axis Zojer et al. show flips scheduler rankings):

- ``uniform``     fixed submission gap — the paper's §4.3.1 stream shape
- ``poisson``     memoryless arrivals at a constant rate
- ``bursty``      2-state Markov-modulated Poisson process (MMPP): long calm
                  stretches punctuated by dense bursts (interarrival CV >> 1)
- ``diurnal``     non-homogeneous Poisson with a sinusoidal day/night rate,
                  sampled by Lewis-Shedler thinning
- ``heavy_tail``  Poisson arrivals, Pareto job sizes AND durations (the
                  elephant-job tail real clusters carry)

Size/duration draws are lognormal unless a generator says otherwise; every
generator is a pure function of its seed (property-tested).  Raw priorities
are drawn from the Google-style 0..11 range so the same
``bucket_priorities`` pass applies to synthetic and loaded traces alike.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

import numpy as np

from repro.workloads.trace import Trace, TraceJob


# ---------------------------------------------------------------------------
# arrival processes (return n sorted arrival times, seconds, starting near 0)
# ---------------------------------------------------------------------------

def _uniform_arrivals(rng, n: int, gap: float) -> np.ndarray:
    return np.arange(n, dtype=float) * gap


def _poisson_arrivals(rng, n: int, rate: float) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _mmpp_arrivals(rng, n: int, rate_calm: float, rate_burst: float,
                   dwell_calm: float, dwell_burst: float) -> np.ndarray:
    """2-state MMPP: alternate Exp-dwell calm/burst phases; within a phase,
    Poisson arrivals at that phase's rate."""
    out, t, burst = [], 0.0, False
    while len(out) < n:
        dwell = float(rng.exponential(dwell_burst if burst else dwell_calm))
        rate = rate_burst if burst else rate_calm
        phase_end = t + dwell
        while len(out) < n:
            t += float(rng.exponential(1.0 / rate))
            if t > phase_end:
                t = phase_end
                break
            out.append(t)
        burst = not burst
    return np.array(out)


def _diurnal_arrivals(rng, n: int, base_rate: float, amplitude: float,
                      period: float) -> np.ndarray:
    """Thinning: candidate Poisson at the peak rate, accept with
    lambda(t)/lambda_max where lambda(t) = base*(1 + A*sin(2*pi*t/T))."""
    assert 0.0 <= amplitude < 1.0
    peak = base_rate * (1.0 + amplitude)
    out, t = [], 0.0
    while len(out) < n:
        t += float(rng.exponential(1.0 / peak))
        lam = base_rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period))
        if rng.random() < lam / peak:
            out.append(t)
    return np.array(out)


# ---------------------------------------------------------------------------
# size / duration draws
# ---------------------------------------------------------------------------

def _lognormal(rng, n: int, median: float, sigma: float) -> np.ndarray:
    return rng.lognormal(mean=math.log(median), sigma=sigma, size=n)


def _pareto(rng, n: int, alpha: float, scale: float) -> np.ndarray:
    """Pareto(alpha) with minimum ``scale`` (numpy's is the Lomax shift)."""
    return scale * (1.0 + rng.pareto(alpha, size=n))


def _assemble(name: str, arrivals: np.ndarray, slots: np.ndarray,
              durations: np.ndarray, priorities: np.ndarray) -> Trace:
    jobs = tuple(
        TraceJob(job_id=f"{name}-{i:04d}", submit_time=float(t),
                 duration=float(d), slots=int(max(1, round(s))),
                 priority=int(p))
        for i, (t, s, d, p) in enumerate(
            zip(arrivals, slots, durations, priorities)))
    return Trace(name=name, jobs=jobs, source="synthetic").sorted()


def _common(rng, n: int, slot_median: float, slot_sigma: float,
            duration_median: float, duration_sigma: float):
    slots = _lognormal(rng, n, slot_median, slot_sigma)
    durations = _lognormal(rng, n, duration_median, duration_sigma)
    priorities = rng.integers(0, 12, size=n)
    return slots, durations, priorities


# ---------------------------------------------------------------------------
# public generators — pure functions of their seed
# ---------------------------------------------------------------------------

def uniform_trace(n_jobs: int = 24, seed: int = 0, *, gap: float = 90.0,
                  slot_median: float = 6.0, slot_sigma: float = 0.5,
                  duration_median: float = 600.0,
                  duration_sigma: float = 0.4) -> Trace:
    rng = np.random.default_rng(seed)
    slots, durations, prio = _common(rng, n_jobs, slot_median, slot_sigma,
                                     duration_median, duration_sigma)
    return _assemble("uniform", _uniform_arrivals(rng, n_jobs, gap),
                     slots, durations, prio)


def poisson_trace(n_jobs: int = 24, seed: int = 0, *, rate: float = 1 / 90.0,
                  slot_median: float = 6.0, slot_sigma: float = 0.5,
                  duration_median: float = 600.0,
                  duration_sigma: float = 0.4) -> Trace:
    rng = np.random.default_rng(seed)
    slots, durations, prio = _common(rng, n_jobs, slot_median, slot_sigma,
                                     duration_median, duration_sigma)
    return _assemble("poisson", _poisson_arrivals(rng, n_jobs, rate),
                     slots, durations, prio)


def bursty_trace(n_jobs: int = 24, seed: int = 0, *,
                 rate_calm: float = 1 / 600.0, rate_burst: float = 1 / 15.0,
                 dwell_calm: float = 900.0, dwell_burst: float = 120.0,
                 slot_median: float = 6.0, slot_sigma: float = 0.5,
                 duration_median: float = 600.0,
                 duration_sigma: float = 0.4) -> Trace:
    rng = np.random.default_rng(seed)
    slots, durations, prio = _common(rng, n_jobs, slot_median, slot_sigma,
                                     duration_median, duration_sigma)
    arrivals = _mmpp_arrivals(rng, n_jobs, rate_calm, rate_burst,
                              dwell_calm, dwell_burst)
    return _assemble("bursty", arrivals, slots, durations, prio)


def diurnal_trace(n_jobs: int = 24, seed: int = 0, *,
                  base_rate: float = 1 / 90.0, amplitude: float = 0.9,
                  period: float = 3600.0, slot_median: float = 6.0,
                  slot_sigma: float = 0.5, duration_median: float = 600.0,
                  duration_sigma: float = 0.4) -> Trace:
    rng = np.random.default_rng(seed)
    slots, durations, prio = _common(rng, n_jobs, slot_median, slot_sigma,
                                     duration_median, duration_sigma)
    arrivals = _diurnal_arrivals(rng, n_jobs, base_rate, amplitude, period)
    return _assemble("diurnal", arrivals, slots, durations, prio)


def heavy_tail_trace(n_jobs: int = 24, seed: int = 0, *,
                     rate: float = 1 / 90.0, size_alpha: float = 1.5,
                     size_scale: float = 2.0, duration_alpha: float = 1.3,
                     duration_scale: float = 120.0) -> Trace:
    """Pareto sizes and durations: a few elephants dominate slot-seconds."""
    rng = np.random.default_rng(seed)
    slots = _pareto(rng, n_jobs, size_alpha, size_scale)
    durations = _pareto(rng, n_jobs, duration_alpha, duration_scale)
    priorities = rng.integers(0, 12, size=n_jobs)
    return _assemble("heavy_tail", _poisson_arrivals(rng, n_jobs, rate),
                     slots, durations, priorities)


def _fleet_arrivals(rng, n: int, horizon: float, amplitude: float,
                    period: float) -> np.ndarray:
    """Vectorized diurnal sampler: ``n`` arrival times in ``[0, horizon)``
    with density proportional to ``1 + A*sin(2*pi*t/T)`` (rejection sampling
    in numpy batches).  The sequential thinning loop in
    :func:`_diurnal_arrivals` is exact too, but at fleet scale (~1M jobs) a
    per-candidate Python iteration dominates the whole replay."""
    assert 0.0 <= amplitude < 1.0
    out = np.empty(0)
    while out.size < n:
        m = int((n - out.size) * 1.8) + 16
        t = rng.random(m) * horizon
        keep = rng.random(m) * (1.0 + amplitude) \
            < 1.0 + amplitude * np.sin(2 * math.pi * t / period)
        out = np.concatenate([out, t[keep]])
    return np.sort(out[:n])


def google_fleet_trace(n_jobs: int = 1_000_000, seed: int = 0, *,
                       days: float = 30.0, nodes: int = 10_000,
                       slots_per_node: int = 8, target_load: float = 0.7,
                       amplitude: float = 0.6, slot_median: float = 24.0,
                       slot_sigma: float = 1.0, duration_sigma: float = 1.1,
                       max_job_fraction: float = 0.02) -> Trace:
    """Month-long Google-shape fleet trace (the ROADMAP fleet-scale bench):
    day/night diurnal arrivals over ``days``, lognormal slot demands capped
    at ``max_job_fraction`` of the cluster, and lognormal durations scaled so
    the offered load — total slot-seconds over capacity x horizon — lands
    exactly on ``target_load`` (< 1, or the backlog never drains).  Raw
    priorities use the Google 0..11 range; replay buckets them like every
    other trace.  Fully vectorized: generating ~1M jobs takes seconds."""
    assert 0.0 < target_load < 1.0
    rng = np.random.default_rng(seed)
    horizon = days * 86400.0
    capacity = nodes * slots_per_node
    arrivals = _fleet_arrivals(rng, n_jobs, horizon, amplitude, 86400.0)
    slots = np.clip(np.round(_lognormal(rng, n_jobs, slot_median,
                                        slot_sigma)),
                    1, max(1, int(capacity * max_job_fraction)))
    # unit-median durations, then one global scale pins the realized load
    d0 = _lognormal(rng, n_jobs, 1.0, duration_sigma)
    need = target_load * capacity * horizon          # slot-seconds to offer
    durations = np.maximum(30.0, d0 * (need / float(np.sum(slots * d0))))
    priorities = rng.integers(0, 12, size=n_jobs)
    return _assemble("fleet", arrivals, slots, durations, priorities)


GENERATORS: Dict[str, Callable[..., Trace]] = {
    "uniform": uniform_trace,
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
    "heavy_tail": heavy_tail_trace,
    "fleet": google_fleet_trace,
}


def generate(kind: str, n_jobs: int = 24, seed: int = 0, **kw) -> Trace:
    """Dispatch by shape name (the table4 grid iterates this registry)."""
    return GENERATORS[kind](n_jobs=n_jobs, seed=seed, **kw)
