"""Workload subsystem: trace ingestion, synthetic generators, characterization
stats, and open-loop replay through the simulators (see README §Workloads).
"""
from repro.workloads.replay import (REPLAY_VARIANTS, ReplayConfig,
                                    TraceScalingModel, compile_job,
                                    compile_trace, replay_cloud,
                                    replay_variant)
from repro.workloads.stats import (WorkloadStats, characterize,
                                   hill_tail_index)
from repro.workloads.synthetic import (GENERATORS, bursty_trace,
                                       diurnal_trace, generate,
                                       google_fleet_trace, heavy_tail_trace,
                                       poisson_trace, uniform_trace)
from repro.workloads.trace import (HIGH_PRIORITY, LOW_PRIORITY, LOADERS,
                                   Trace, TraceJob, fixture_path,
                                   load_azure_trace, load_google_trace)

__all__ = [
    "REPLAY_VARIANTS", "ReplayConfig", "TraceScalingModel", "compile_job",
    "compile_trace", "replay_cloud", "replay_variant",
    "WorkloadStats", "characterize", "hill_tail_index",
    "GENERATORS", "bursty_trace", "diurnal_trace", "generate",
    "google_fleet_trace", "heavy_tail_trace", "poisson_trace",
    "uniform_trace",
    "HIGH_PRIORITY", "LOW_PRIORITY", "LOADERS", "Trace", "TraceJob",
    "fixture_path", "load_azure_trace", "load_google_trace",
]
