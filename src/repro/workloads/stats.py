"""Workload characterization — the numbers that name a workload's *shape*.

Every benchmark row that reports a scheduler verdict should also say what
kind of pressure the scheduler was under; otherwise "elastic wins" is a claim
about one arrival pattern.  :func:`characterize` computes:

- interarrival mean and CV (CV=0 fixed gap, CV=1 Poisson, CV>1 bursty);
- burstiness index B = (sigma - mu)/(sigma + mu) of interarrivals (Goh &
  Barabasi), in [-1, 1): -1 periodic, 0 Poisson, ->1 extreme bursts;
- peak-to-mean arrival rate over fixed windows (how hard the worst burst
  hits an autoscaler's provisioning loop);
- size-tail index: Hill estimator on per-job slot-seconds (the "mass" a job
  drops on the cluster); alpha <= 2 means elephants dominate — infinite for
  degenerate/light tails;
- demand quantiles and total offered slot-seconds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.workloads.trace import Trace


@dataclass(frozen=True)
class WorkloadStats:
    n_jobs: int
    horizon: float                   # first -> last arrival (s)
    interarrival_mean: float
    interarrival_cv: float
    burstiness: float                # (sigma-mu)/(sigma+mu), [-1, 1)
    peak_rate_ratio: float           # max windowed rate / mean rate
    duration_mean: float
    duration_p95: float
    slots_mean: float
    slots_p95: float
    slots_max: int
    tail_index: float                # Hill alpha on slot-seconds; inf = light
    slot_seconds: float              # total offered work

    def kv(self) -> str:
        """Compact characterization for a benchmark row's derived field."""
        tail = "inf" if math.isinf(self.tail_index) else \
            f"{self.tail_index:.2f}"
        return (f"jobs={self.n_jobs};cv={self.interarrival_cv:.2f};"
                f"burst={self.burstiness:.2f};peak={self.peak_rate_ratio:.1f};"
                f"tail={tail};p95_slots={self.slots_p95:.0f}")

    def describe(self) -> str:
        return (f"{self.n_jobs} jobs over {self.horizon:.0f}s | "
                f"interarrival {self.interarrival_mean:.1f}s "
                f"CV={self.interarrival_cv:.2f} B={self.burstiness:.2f} "
                f"peak/mean={self.peak_rate_ratio:.1f} | "
                f"dur mean={self.duration_mean:.0f}s "
                f"p95={self.duration_p95:.0f}s | "
                f"slots mean={self.slots_mean:.1f} max={self.slots_max} "
                f"tail_alpha={self.tail_index:.2f} | "
                f"offered={self.slot_seconds / 3600.0:.1f} slot-h")


def hill_tail_index(values, k: Optional[int] = None) -> float:
    """Hill estimator of the Pareto tail exponent alpha over the top-k order
    statistics (k defaults to the top 20%, floor 3).  Returns +inf when the
    tail is degenerate (top values equal) — i.e. no power-law tail."""
    x = np.sort(np.asarray(values, dtype=float))
    n = len(x)
    if n < 4 or x[0] <= 0.0:
        return math.inf
    k = k if k is not None else max(3, n // 5)
    k = min(k, n - 1)
    top, ref = x[n - k:], x[n - k - 1]
    logs = np.log(top / ref)
    m = float(np.mean(logs))
    return 1.0 / m if m > 0.0 else math.inf


def characterize(trace: Trace, *, window: Optional[float] = None,
                 tail_k: Optional[int] = None) -> WorkloadStats:
    """Compute :class:`WorkloadStats` for a trace.  ``window`` sets the
    arrival-rate bucketing (defaults to horizon/12, floor 1 s)."""
    arr = np.sort(np.asarray(trace.arrivals(), dtype=float))
    durs = np.array([j.duration for j in trace.jobs], dtype=float)
    slots = np.array([j.slots for j in trace.jobs], dtype=float)
    n = len(arr)
    if n < 2:
        return WorkloadStats(
            n_jobs=n, horizon=0.0, interarrival_mean=0.0,
            interarrival_cv=0.0, burstiness=-1.0, peak_rate_ratio=1.0,
            duration_mean=float(durs.mean()) if n else 0.0,
            duration_p95=float(durs.max()) if n else 0.0,
            slots_mean=float(slots.mean()) if n else 0.0,
            slots_p95=float(slots.max()) if n else 0.0,
            slots_max=int(slots.max()) if n else 0,
            tail_index=math.inf,
            slot_seconds=trace.slot_seconds)
    gaps = np.diff(arr)
    mu = float(gaps.mean())
    sigma = float(gaps.std())
    cv = sigma / mu if mu > 0.0 else 0.0
    burst = (sigma - mu) / (sigma + mu) if sigma + mu > 0.0 else -1.0
    horizon = float(arr[-1] - arr[0])
    window = window if window is not None else max(1.0, horizon / 12.0)
    if horizon > 0.0:
        counts, _ = np.histogram(
            arr, bins=max(1, int(math.ceil(horizon / window))),
            range=(arr[0], arr[-1]))
        mean_rate = counts.mean()
        peak = float(counts.max() / mean_rate) if mean_rate > 0.0 else 1.0
    else:
        peak = float(n)                     # everything in one instant
    return WorkloadStats(
        n_jobs=n,
        horizon=horizon,
        interarrival_mean=mu,
        interarrival_cv=cv,
        burstiness=burst,
        peak_rate_ratio=peak,
        duration_mean=float(durs.mean()),
        duration_p95=float(np.percentile(durs, 95)),
        slots_mean=float(slots.mean()),
        slots_p95=float(np.percentile(slots, 95)),
        slots_max=int(slots.max()),
        tail_index=hill_tail_index(slots * durs, k=tail_k),
        slot_seconds=trace.slot_seconds)
