"""Open-loop trace replay: compile a :class:`Trace` into the simulators'
``(JobSpec, SimWorkload)`` streams and drive them.

Open-loop means arrivals come from the trace's ``submit_time`` stamps, never
from scheduler feedback — a slow policy faces the same burst a fast one does
(closed-loop replay hides queueing collapse; cf. the workload-replay
literature and Zojer et al.).

Compilation turns one observed point — "this job ran ``duration`` seconds at
``slots`` replicas" — into the elastic description the paper's scheduler
needs:

- ``min/max_replicas`` bracket the natural size by an ``elasticity`` factor;
- the scaling model is Amdahl-shaped around the natural size, normalized so
  ``time_per_step(natural) == 1 s`` and ``total_work == duration`` steps —
  i.e. replay at the natural size reproduces the observed runtime exactly,
  while shrinks/expands pay/gain per the serial fraction;
- ``data_bytes`` (checkpoint footprint for the rescale-overhead model)
  scales with the natural size.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.cloud.node_autoscaler import NodeAutoscaler
from repro.cloud.provider import CloudProvider
from repro.cloud.sim import CloudSimulator
from repro.core.job import JobSpec
from repro.core.metrics import ScheduleMetrics
from repro.core.perf_model import RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload, Simulator, variant_setup
from repro.workloads.trace import Trace, TraceJob

#: replay variants = the paper's four schedulers + the preempting extension
#: + "rigid": non-malleable replay at each job's OBSERVED request size (what
#: a conventional batch scheduler would have run for this trace)
REPLAY_VARIANTS = ("rigid", "rigid_min", "rigid_max", "moldable", "elastic",
                   "elastic_preempt")


@dataclass(frozen=True)
class TraceScalingModel:
    """Amdahl strong scaling anchored at the trace's observed point:
    ``t(r) = step_seconds * (serial + (1-serial) * natural/r)`` so that
    ``t(natural) == step_seconds`` exactly."""
    natural: int
    serial_fraction: float = 0.05
    step_seconds: float = 1.0

    def time_per_step(self, replicas: int) -> float:
        p = max(1, replicas)
        a = self.serial_fraction
        return self.step_seconds * (a + (1.0 - a) * self.natural / p)

    def rate(self, replicas: int) -> float:
        return 1.0 / self.time_per_step(replicas)


@dataclass(frozen=True)
class ReplayConfig:
    cluster_slots: int              # reference scale the trace was rescaled to
    elasticity: float = 2.0         # min = natural/e, max = natural*e
    serial_fraction: float = 0.05   # Amdahl serial share
    bytes_per_slot: float = 2.0e8   # checkpoint footprint per natural slot
    rescale_gap: float = 180.0      # T_rescale_gap for elastic variants
    fast_lane: bool = True          # checkpoint/reshard fast-lane cost model

    def __post_init__(self):
        assert self.cluster_slots >= 1
        assert self.elasticity >= 1.0
        assert 0.0 <= self.serial_fraction < 1.0


def compile_job(tj: TraceJob, cfg: ReplayConfig
                ) -> Tuple[JobSpec, SimWorkload]:
    natural = min(max(1, tj.slots), cfg.cluster_slots)
    min_r = max(1, int(natural / cfg.elasticity))
    max_r = min(cfg.cluster_slots,
                max(natural, math.ceil(natural * cfg.elasticity)))
    spec = JobSpec(
        job_id=tj.job_id, priority=tj.priority, min_replicas=min_r,
        max_replicas=max_r, submit_time=tj.submit_time, workload=tj)
    wl = SimWorkload(
        scaling=TraceScalingModel(natural, cfg.serial_fraction),
        total_work=tj.duration,                 # steps of 1 s at natural size
        data_bytes=natural * cfg.bytes_per_slot,
        rescale=RescaleModel(fast_lane=cfg.fast_lane))
    return spec, wl


def compile_trace(trace: Trace, cfg: ReplayConfig
                  ) -> List[Tuple[JobSpec, SimWorkload]]:
    return [compile_job(tj, cfg) for tj in trace.jobs]


def _prepare(variant: str, specs: List[JobSpec], cfg: ReplayConfig):
    """Specs transform + policy for one scheduler variant.  The paper's
    variants delegate to :func:`core.simulator.variant_setup` (one source of
    truth); only the trace-specific ``rigid`` baseline lives here."""
    if variant == "rigid":
        # trace-faithful static baseline: exactly the observed request
        # (spec.workload carries the TraceJob compile_job attached)
        specs = [s.rigid(min(max(1, s.workload.slots), cfg.cluster_slots))
                 for s in specs]
        return specs, PolicyConfig(rescale_gap=cfg.rescale_gap), None
    return variant_setup(variant, specs, rescale_gap=cfg.rescale_gap)


def replay_variant(trace: Trace, variant: str, cfg: ReplayConfig,
                   *, slots_per_node: Optional[int] = None, tracer=None,
                   profiler=None, util_series: bool = True,
                   track_phases: bool = True) -> ScheduleMetrics:
    """Replay through the fixed-capacity :class:`Simulator` (the paper's
    §4.3 frame) at ``cfg.cluster_slots`` slots.  ``util_series=False`` /
    ``track_phases=False`` select the simulator's bounded-memory fleet mode
    (O(1) utilization accumulators, no per-job phase ledger) — what the
    ~1M-job bench_simcore replay runs in."""
    pairs = compile_trace(trace, cfg)
    wls: Dict[str, SimWorkload] = {s.job_id: w for s, w in pairs}
    specs, pcfg, policy = _prepare(variant, [s for s, _ in pairs], cfg)
    sim = Simulator(cfg.cluster_slots, pcfg, slots_per_node=slots_per_node,
                    tracer=tracer, profiler=profiler,
                    util_series=util_series, track_phases=track_phases)
    if policy is not None:
        sim.policy = policy
    for s in specs:
        sim.submit(s, wls[s.job_id])
    return sim.run()


def replay_cloud(trace: Trace, cfg: ReplayConfig, provider: CloudProvider,
                 *, variant: str = "elastic",
                 autoscaler: Optional[NodeAutoscaler] = None,
                 placement: str = "pack",
                 pre_run: Optional[Callable[[CloudSimulator], None]] = None,
                 tracer=None, profiler=None) -> CloudSimulator:
    """Replay through :class:`CloudSimulator` (dynamic capacity, spot kills,
    dollars).  Returns the finished simulator — ``.run()`` has been called —
    so callers can read both the metrics and the cost report / kill blasts.
    ``pre_run`` is invoked on the constructed simulator after all arrivals
    are queued and before ``run()`` — the hook deterministic scenarios use
    to inject events (e.g. ``provider.inject_zone_reclaim(..., sim.queue)``
    for the escalating-reclaim bidding benchmark).
    """
    pairs = compile_trace(trace, cfg)
    wls: Dict[str, SimWorkload] = {s.job_id: w for s, w in pairs}
    specs, pcfg, policy = _prepare(variant, [s for s, _ in pairs], cfg)
    sim = CloudSimulator(provider, pcfg, autoscaler=autoscaler,
                         policy=policy, placement=placement, tracer=tracer,
                         profiler=profiler)
    for s in specs:
        sim.submit(s, wls[s.job_id])
    if pre_run is not None:
        pre_run(sim)
    sim.metrics = sim.run()
    return sim
