"""Canonical workload traces: one record type, many sources.

A :class:`Trace` is an immutable, ordered stream of :class:`TraceJob`
arrivals.  Everything downstream — characterization (``stats``), replay
through the simulators (``replay``), the table4 benchmark — consumes this one
type, so a Google-style CSV, an Azure-style CSV, and a seeded synthetic
generator are interchangeable workload descriptions.

Loader adapters accept the *shape* of the public traces (column names are
alias-tolerant), not their multi-GB originals:

- Google cluster-usage style (``load_google_trace``): microsecond timestamps,
  per-task CPU request as a fraction of one machine, priority 0..11;
- Azure VM style (``load_azure_trace``): second-granularity created/deleted
  lifetimes, integer core counts, workload category (Interactive /
  Delay-insensitive / Unknown).

Normalization passes (each returns a NEW ``Trace``; the raw load is never
mutated) map any source onto the paper's experimental frame: rebase time to
t=0, clamp pathological durations, rescale slot demands to a target cluster
size, and bucket raw priorities into the paper's high/low classes.
"""
from __future__ import annotations

import csv
import math
import os
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

#: priority values of the paper's two job classes (§4.3.1 draws U{1..5};
#: the high/low bucketing collapses a trace's raw levels onto the extremes)
LOW_PRIORITY = 1
HIGH_PRIORITY = 5


@dataclass(frozen=True)
class TraceJob:
    """One job arrival: open-loop submit time, observed resource request, and
    the runtime it achieved at that request (the replay layer turns the pair
    into a strong-scaling model around this "natural" size)."""
    job_id: str
    submit_time: float          # seconds from trace start
    duration: float             # seconds of runtime observed at ``slots``
    slots: int                  # resource request (replicas at natural size)
    priority: int               # raw source priority (bucket before replay)
    user: str = ""

    def __post_init__(self):
        assert self.duration > 0.0, self
        assert self.slots >= 1, self

    @property
    def slot_seconds(self) -> float:
        return self.duration * self.slots


@dataclass(frozen=True)
class Trace:
    name: str
    jobs: Tuple[TraceJob, ...]
    source: str = "synthetic"   # file path for loaded traces

    # -- views ---------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.jobs)

    def __iter__(self):
        return iter(self.jobs)

    @property
    def horizon(self) -> float:
        """Last arrival time (arrival horizon, not completion)."""
        return max((j.submit_time for j in self.jobs), default=0.0)

    @property
    def slot_seconds(self) -> float:
        return sum(j.slot_seconds for j in self.jobs)

    def arrivals(self) -> List[float]:
        return [j.submit_time for j in self.jobs]

    # -- normalization passes (each returns a new Trace) ---------------------
    def sorted(self) -> "Trace":
        """Canonical arrival order: time, then job_id for ties."""
        return replace(self, jobs=tuple(sorted(
            self.jobs, key=lambda j: (j.submit_time, j.job_id))))

    def rebase_time(self) -> "Trace":
        """Shift arrivals so the first lands at t=0 (real traces start at an
        arbitrary epoch offset)."""
        if not self.jobs:
            return self
        t0 = min(j.submit_time for j in self.jobs)
        return replace(self, jobs=tuple(
            replace(j, submit_time=j.submit_time - t0) for j in self.jobs))

    def clamp_durations(self, lo: float, hi: float) -> "Trace":
        """Clip runtimes into [lo, hi] — public traces carry sub-second crash
        loops and weeks-long services, both meaningless at benchmark scale."""
        assert 0.0 < lo <= hi
        return replace(self, jobs=tuple(
            replace(j, duration=min(max(j.duration, lo), hi))
            for j in self.jobs))

    def rescale_slots(self, cluster_slots: int,
                      max_fraction: float = 0.5) -> "Trace":
        """Linearly rescale slot demands so the LARGEST request equals
        ``max_fraction`` of a ``cluster_slots`` cluster (floor 1).  Preserves
        the relative size distribution — the tail stays a tail — while
        guaranteeing every job is individually satisfiable."""
        assert cluster_slots >= 1 and 0.0 < max_fraction <= 1.0
        if not self.jobs:
            return self
        peak = max(j.slots for j in self.jobs)
        factor = max(1, int(cluster_slots * max_fraction)) / peak
        return replace(self, jobs=tuple(
            replace(j, slots=max(1, round(j.slots * factor)))
            for j in self.jobs))

    def bucket_priorities(self, high_fraction: float = 0.3,
                          low: int = LOW_PRIORITY,
                          high: int = HIGH_PRIORITY) -> "Trace":
        """Collapse raw source priorities onto the paper's two classes: the
        top ``high_fraction`` of raw levels (by quantile) become ``high``,
        the rest ``low``.  Degenerate traces (one raw level) go all-low."""
        assert 0.0 <= high_fraction <= 1.0
        if not self.jobs:
            return self
        raw = np.array([j.priority for j in self.jobs], dtype=float)
        if high_fraction == 1.0:
            cut = -math.inf
        elif raw.min() == raw.max() or high_fraction == 0.0:
            cut = math.inf
        else:
            cut = float(np.quantile(raw, 1.0 - high_fraction))
            if cut <= raw.min():        # mass at the bottom: strict threshold
                cut = raw.min() + 0.5
        return replace(self, jobs=tuple(
            replace(j, priority=high if j.priority >= cut else low)
            for j in self.jobs))

    def truncate(self, n_jobs: int) -> "Trace":
        """Keep the first ``n_jobs`` arrivals (call on a sorted trace)."""
        return replace(self, jobs=self.jobs[:n_jobs])

    def normalized(self, cluster_slots: int, *, max_fraction: float = 0.5,
                   min_duration: float = 30.0, max_duration: float = 3600.0,
                   high_fraction: float = 0.3,
                   n_jobs: Optional[int] = None) -> "Trace":
        """The standard pipeline every source goes through before replay:
        sort -> truncate -> rebase -> clamp -> rescale -> bucket."""
        t = self.sorted()
        if n_jobs is not None:
            t = t.truncate(n_jobs)
        return (t.rebase_time()
                 .clamp_durations(min_duration, max_duration)
                 .rescale_slots(cluster_slots, max_fraction)
                 .bucket_priorities(high_fraction))


# ---------------------------------------------------------------------------
# CSV loader adapters
# ---------------------------------------------------------------------------

def _col(row: Dict[str, str], *names: str) -> str:
    """Alias-tolerant column lookup (public trace dumps disagree on names)."""
    for n in names:
        if n in row and row[n] != "":
            return row[n]
    raise KeyError(f"none of {names} present in columns {sorted(row)}")


def load_google_trace(path: str, *, slots_per_machine: int = 8) -> Trace:
    """Google cluster-usage-style CSV: one row per task, microsecond
    timestamps, CPU request as a fraction of one machine.

    Expected (alias-tolerant) header columns::

        time|timestamp          submission time, microseconds
        job_id|collection_id    job identifier
        duration|duration_us    observed runtime, microseconds
        cpu_request|resource_request_cpus   fraction of one machine [0, 1+]
        priority                0..11 (larger = more important)
        user                    optional

    ``slots`` is the CPU request projected onto a machine of
    ``slots_per_machine`` schedulable slots (ceil, floor 1).
    """
    jobs = []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            cpu = float(_col(row, "cpu_request", "resource_request_cpus"))
            jobs.append(TraceJob(
                job_id=str(_col(row, "job_id", "collection_id")),
                submit_time=float(_col(row, "time", "timestamp")) * 1e-6,
                duration=float(_col(row, "duration", "duration_us")) * 1e-6,
                slots=max(1, math.ceil(cpu * slots_per_machine)),
                priority=int(_col(row, "priority")),
                user=row.get("user", ""),
            ))
    return Trace(name=_stem(path), jobs=tuple(jobs), source=path)


#: Azure VM categories -> raw priority (bucket_priorities maps these to the
#: paper's classes; Interactive VMs are the latency-sensitive ones)
AZURE_CATEGORY_PRIORITY = {"interactive": 2, "unknown": 1,
                           "delay-insensitive": 0}


def load_azure_trace(path: str) -> Trace:
    """Azure VM-style CSV: one row per VM lifetime, second timestamps.

    Expected (alias-tolerant) header columns::

        vm_id                               VM identifier
        vm_created / vm_deleted             lifetime bounds, seconds
        vm_virtual_core_count|core_count    integer cores -> slots
        vm_category|category                Interactive / Delay-insensitive /
                                            Unknown (or a numeric priority)
        subscription_id                     optional -> user
    """
    jobs = []
    with open(path, newline="") as f:
        for row in csv.DictReader(f):
            created = float(_col(row, "vm_created", "created"))
            deleted = float(_col(row, "vm_deleted", "deleted"))
            if deleted <= created:
                continue    # censored lifetime: VM still alive at the
                #             snapshot end (deleted == created or 0) — no
                #             observed duration to replay, skip the row
            cat = _col(row, "vm_category", "category", "priority")
            try:
                prio = int(cat)
            except ValueError:
                prio = AZURE_CATEGORY_PRIORITY[cat.strip().lower()]
            jobs.append(TraceJob(
                job_id=str(_col(row, "vm_id", "id")),
                submit_time=created,
                duration=deleted - created,
                slots=max(1, int(float(
                    _col(row, "vm_virtual_core_count", "core_count",
                         "cores")))),
                priority=prio,
                user=row.get("subscription_id", ""),
            ))
    return Trace(name=_stem(path), jobs=tuple(jobs), source=path)


LOADERS = {"google": load_google_trace, "azure": load_azure_trace}


def _stem(path: str) -> str:
    return os.path.splitext(os.path.basename(path))[0]


def fixture_path(name: str) -> str:
    """Path to a bundled sample trace (checked-in CSV under fixtures/)."""
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", name)
