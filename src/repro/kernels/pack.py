"""Fused gather/pack kernel — coalesce per-leaf device→host checkpoint copies.

``snapshot_to_host`` used to issue one ``device_get`` per pytree leaf; for
the sharded layouts in ``sharding/specs.py`` that is dozens of small DMA
transfers, each paying latency.  ``pack_leaves_pallas`` gathers all
same-dtype leaves into ONE contiguous device buffer (a single Pallas grid
sweep over output blocks), so the host side becomes one large transfer per
dtype group.  ``packed_snapshot_to_host`` is the drop-in
``snapshot_to_host`` replacement built on it (``fused=True`` there routes
here); the fig5 slow-lane microbench quantifies the win.

Kernel shape: every leaf is flattened to 1-D, padded to a
``block_rows × lane`` tile multiple, and viewed as ``(n_i·block_rows,
lane)``.  The grid runs over the *output* blocks, leaf-major; leaf ``i``
owns grid slots ``[start_i, start_i + n_i)``.  Its input index_map clamps
``g - start_i`` into range (out-of-range slots still prefetch *some* valid
block — harmless, the ``pl.when`` guard never writes it), and the kernel
body copies the active leaf's block to the output tile.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.checkpoint.reshard import flatten_tree

LANE = 128
BLOCK_ROWS = 8


def _interp(override):
    return (jax.default_backend() != "tpu") if override is None else override


def _pack_kernel(*refs, starts: Tuple[int, ...], nblocks: Tuple[int, ...]):
    ins, o_ref = refs[:-1], refs[-1]
    g = pl.program_id(0)
    for i in range(len(ins)):
        @pl.when((g >= starts[i]) & (g < starts[i] + nblocks[i]))
        def _copy(i=i):
            o_ref[...] = ins[i][...]


def pack_leaves_pallas(leaves: Sequence[jax.Array], *,
                       block_rows: int = BLOCK_ROWS, lane: int = LANE,
                       interpret: bool = None) -> jax.Array:
    """Pack same-dtype ``leaves`` into one ``(total_blocks·block_rows, lane)``
    device buffer, leaf-major, each leaf zero-padded to a block multiple."""
    interpret = _interp(interpret)
    block = block_rows * lane
    views, nblocks = [], []
    for leaf in leaves:
        v = jnp.ravel(leaf)
        pad = (-v.size) % block
        if pad:
            v = jnp.pad(v, (0, pad))
        views.append(v.reshape(-1, lane))
        nblocks.append(v.size // block)
    starts = tuple(int(s) for s in np.cumsum([0] + nblocks[:-1]))
    nblocks = tuple(nblocks)
    total = sum(nblocks)
    in_specs = [
        pl.BlockSpec((block_rows, lane),
                     functools.partial(
                         lambda g, s, n: (jnp.clip(g - s, 0, n - 1), 0),
                         s=starts[i], n=nblocks[i]))
        for i in range(len(views))
    ]
    kernel = functools.partial(_pack_kernel, starts=starts, nblocks=nblocks)
    return pl.pallas_call(
        kernel,
        grid=(total,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, lane), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((total * block_rows, lane),
                                       views[0].dtype),
        interpret=interpret,
    )(*views)


def pack_leaves_ref(leaves: Sequence[jax.Array], *,
                    block_rows: int = BLOCK_ROWS,
                    lane: int = LANE) -> jax.Array:
    """Pure-jnp reference for the pack kernel (tests + non-Pallas fallback)."""
    block = block_rows * lane
    parts = []
    for leaf in leaves:
        v = jnp.ravel(leaf)
        pad = (-v.size) % block
        if pad:
            v = jnp.pad(v, (0, pad))
        parts.append(v)
    return jnp.concatenate(parts).reshape(-1, lane)


def packed_snapshot_to_host(tree, *, block_rows: int = BLOCK_ROWS,
                            lane: int = LANE, interpret: bool = None
                            ) -> Dict[str, np.ndarray]:
    """Fused device→host snapshot: one packed transfer per dtype group.

    Returns the same ``{path-key: ndarray}`` dict as ``snapshot_to_host``."""
    flat = flatten_tree(tree)
    block = block_rows * lane
    groups: Dict[str, List[str]] = {}
    arrs = {k: jnp.asarray(v) for k, v in flat.items()}
    out: Dict[str, np.ndarray] = {}
    for k, a in arrs.items():
        if a.size == 0:                       # nothing to transfer
            out[k] = np.zeros(a.shape, a.dtype)
        else:
            groups.setdefault(str(a.dtype), []).append(k)
    for _, ks in groups.items():
        leaves = [arrs[k] for k in ks]
        packed = pack_leaves_pallas(leaves, block_rows=block_rows, lane=lane,
                                    interpret=interpret)
        host = np.asarray(jax.device_get(packed)).reshape(-1)
        off = 0
        for k, a in zip(ks, leaves):
            n_padded = a.size + ((-a.size) % block)
            out[k] = host[off:off + a.size].reshape(a.shape).copy()
            off += n_padded
    return {k: out[k] for k in flat}          # original key order
