"""Blocked (flash-style) attention in pure jnp — the XLA-lowerable twin of
``kernels/flash_attention.py``.

Used whenever the Pallas kernel can't run (CPU container, and the multi-pod
dry-run, which lowers on the CPU backend): a ``lax.scan`` over KV blocks with
online softmax keeps the live working set at one (B,KV,G,Sq,block_k) tile
instead of the full O(Sq x Sk) score matrix (2.1 GB/device/tensor on
yi-6b train_4k — see EXPERIMENTS.md §Perf iteration 1).

The backward pass is the standard flash recomputation: only (out, lse) are
saved; dq/dk/dv are accumulated in a second scan over KV blocks.  FLOPs ~2x
attention fwd, memory O(block).  GQA is handled in grouped form throughout —
repeated KV is never materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = jnp.finfo(jnp.float32).min
DEFAULT_BLOCK_K = 512


def _pad_blocks(x, block: int, axis: int):
    n = x.shape[axis]
    pad = (-n) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, n + pad


def _fwd_scan(qg, k, v, *, causal: bool, scale: float, q_pos0, kv_len,
              block_k: int):
    """qg: (B,Sq,KV,G,hd); k,v: (B,Skp,KV,hd) already padded to block_k.
    Returns (out (B,Sq,KV,G,hd) f32, lse (B,KV,G,Sq) f32)."""
    B, Sq, KV, G, hd = qg.shape
    hdv = v.shape[-1]
    Skp = k.shape[1]
    nb = Skp // block_k
    kb = k.reshape(B, nb, block_k, KV, hd).swapaxes(0, 1)
    vb = v.reshape(B, nb, block_k, KV, hdv).swapaxes(0, 1)
    qf = qg.astype(jnp.float32) * scale
    spos = q_pos0 + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, j = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qf, kblk.astype(jnp.float32))
        tpos = j * block_k + jnp.arange(block_k)
        valid = (tpos < kv_len)[None, None, None, None, :] if kv_len is not None \
            else jnp.ones((1, 1, 1, 1, block_k), bool)
        if causal:
            valid = valid & (spos[:, None] >= tpos[None, :])[None, None, None]
        s = jnp.where(valid, s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, G, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    out = out.transpose(0, 3, 1, 2, 4)        # (B,Sq,KV,G,hd)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def blocked_attention(q, k, v, causal: bool = True,
                      scale: Optional[float] = None, q_pos0: int = 0,
                      kv_len: Optional[int] = None,
                      block_k: int = DEFAULT_BLOCK_K):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd) in q.dtype.

    kv_len: static or traced upper bound on valid kv positions (decode).
    """
    out, _ = _blocked_fwd_impl(q, k, v, causal, scale, q_pos0, kv_len, block_k)
    return out


def _blocked_fwd_impl(q, k, v, causal, scale, q_pos0, kv_len, block_k):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    hdv = v.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    block_k = min(block_k, max(k.shape[1], 1))
    kp, Skp = _pad_blocks(k, block_k, 1)
    vp, _ = _pad_blocks(v, block_k, 1)
    if kv_len is None and Skp != k.shape[1]:
        kv_len = k.shape[1]
    qg = q.reshape(B, Sq, KV, G, hd)
    out, lse = _fwd_scan(qg, kp, vp, causal=causal, scale=scale,
                         q_pos0=q_pos0, kv_len=kv_len, block_k=block_k)
    return out.reshape(B, Sq, H, hdv).astype(q.dtype), lse


def _blocked_vjp_fwd(q, k, v, causal, scale, q_pos0, kv_len, block_k):
    out, lse = _blocked_fwd_impl(q, k, v, causal, scale, q_pos0, kv_len,
                                 block_k)
    return out, (q, k, v, out, lse)


def _blocked_vjp_bwd(causal, scale, q_pos0, kv_len, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    hdv = v.shape[-1]
    scale_v = hd ** -0.5 if scale is None else scale
    block_k = min(block_k, max(k.shape[1], 1))
    Sk = k.shape[1]
    kp, Skp = _pad_blocks(k, block_k, 1)
    vp, _ = _pad_blocks(v, block_k, 1)
    if kv_len is None and Skp != Sk:
        kv_len = Sk
    nb = Skp // block_k
    kb = kp.reshape(B, nb, block_k, KV, hd).swapaxes(0, 1)
    vb = vp.reshape(B, nb, block_k, KV, hdv).swapaxes(0, 1)

    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    og = out.reshape(B, Sq, KV, G, hdv).astype(jnp.float32)
    dog = dout.reshape(B, Sq, KV, G, hdv).astype(jnp.float32)
    # D = rowsum(dout * out): (B,KV,G,Sq)
    delta = jnp.einsum("bskgh,bskgh->bkgs", dog, og)
    spos = q_pos0 + jnp.arange(Sq)

    def body(dq_acc, inp):
        kblk, vblk, j = inp
        kf, vf = kblk.astype(jnp.float32), vblk.astype(jnp.float32)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kf) * scale_v
        tpos = j * block_k + jnp.arange(block_k)
        valid = (tpos < kv_len)[None, None, None, None, :] if kv_len is not None \
            else jnp.ones((1, 1, 1, 1, block_k), bool)
        if causal:
            valid = valid & (spos[:, None] >= tpos[None, :])[None, None, None]
        p = jnp.where(valid, jnp.exp(s - lse[..., None]), 0.0)
        dv_blk = jnp.einsum("bkgst,bskgh->btkh", p, dog)
        dp = jnp.einsum("bskgh,btkh->bkgst", dog, vf)
        ds = p * (dp - delta[..., None]) * scale_v
        dq_acc = dq_acc + jnp.einsum("bkgst,btkh->bskgh", ds, kf)
        dk_blk = jnp.einsum("bkgst,bskgh->btkh", ds, qg)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dk = dks.swapaxes(0, 1).reshape(B, Skp, KV, hd)[:, :Sk]
    dv = dvs.swapaxes(0, 1).reshape(B, Skp, KV, hdv)[:, :Sk]
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


blocked_attention.defvjp(_blocked_vjp_fwd, _blocked_vjp_bwd)
