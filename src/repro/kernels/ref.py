"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float = None):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd). Naive fp32 attention with GQA."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores,
                           jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def ssd_ref(x, dt, a_log, b, c):
    """Naive O(L) SSD recurrence (fp32 state), the slow-but-exact oracle.

    x: (B,L,H,P); dt: (B,L,H) post-softplus; a_log: (H,); b,c: (B,L,G,N).
    h_t = exp(A*dt_t) h_{t-1} + dt_t * (B_t (x) x_t);  y_t = h_t C_t
    """
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    A = -jnp.exp(a_log.astype(jnp.float32))
    bh = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # (B,L,H,N)
    ch = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp            # (B,H,P), (B,H), (B,H,N), (B,H,N)
        a_t = jnp.exp(dtt * A)           # (B,H)
        h = h * a_t[..., None, None] + \
            (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                                    bh.swapaxes(0, 1), ch.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype)             # (B,L,H,P)


def rmsnorm_ref(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
