"""Causal GQA flash attention — Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch, q_head, q_block,
k_block) with the k dimension innermost and *revisiting* the same output
block, so the online-softmax accumulators live in VMEM scratch across k steps.
Tiles are MXU-aligned (block_q x head_dim and block_k x head_dim, both 128 by
default).  Causal q-blocks skip k-blocks entirely above the diagonal.

GQA is handled in the k/v index maps (q head h reads kv head h // group_size),
so repeated KV is never materialized in HBM or VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, nk: int,
                  causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # causal: the whole k-block is masked iff k_start > q_end
    run = (k_start <= q_start + block_q - 1) if causal else (ik >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                                   # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, scale: float = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5 if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k

    qt = q.transpose(0, 2, 1, 3)         # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3)         # (B,KV,S,hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, nk=nk, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
