"""Jitted wrappers around the Pallas kernels, with a global enable switch.

On this CPU container, kernels run in ``interpret=True`` mode for validation;
on a real TPU backend they compile natively.  Model code consults
``pallas_enabled()`` — default off on CPU so the dry-run lowers the pure-XLA
path (a TPU Pallas kernel cannot lower on the CPU backend; see DESIGN.md §5).

The flash-attention wrapper attaches a custom VJP whose backward pass
recomputes attention via the memory-efficient reference path (flash-style
recompute — nothing quadratic is saved between fwd and bwd).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_chunked_pallas

_STATE = {
    "enabled": os.environ.get("REPRO_USE_PALLAS", "0") == "1",
    "interpret": jax.default_backend() != "tpu",
}


def pallas_enabled() -> bool:
    return _STATE["enabled"]


def set_pallas(enabled: bool, *, interpret: bool = None):
    _STATE["enabled"] = enabled
    if interpret is not None:
        _STATE["interpret"] = interpret


def _interp(override):
    return _STATE["interpret"] if override is None else override


# ---------------------------------------------------------------------------
# flash attention (fwd kernel + recompute bwd)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, causal, scale, interpret):
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)


def _flash_fwd(q, k, v, causal, scale, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                              interpret=interpret)
    return out, (q, k, v)


def _flash_bwd(causal, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, scale=scale), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, scale: float = None,
                    interpret: bool = None):
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    return _flash(q, k, v, causal, scale, _interp(interpret))


# ---------------------------------------------------------------------------
# SSD chunked scan (fwd kernel + recompute bwd via the jnp chunked path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, a_log, b, c, chunk, interpret):
    return ssd_chunked_pallas(x, dt, a_log, b, c, chunk=chunk,
                              interpret=interpret)


def _ssd_fwd(x, dt, a_log, b, c, chunk, interpret):
    out = ssd_chunked_pallas(x, dt, a_log, b, c, chunk=chunk,
                             interpret=interpret)
    return out, (x, dt, a_log, b, c)


def _ssd_bwd(chunk, interpret, res, g):
    x, dt, a_log, b, c = res
    from repro.models.ssm import ssd_chunked
    _, vjp = jax.vjp(
        lambda x_, dt_, a_, b_, c_: ssd_chunked(x_, dt_, a_, b_, c_,
                                                chunk=chunk),
        x, dt, a_log, b, c)
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x, dt, a_log, b, c, *, chunk: int = 128, interpret: bool = None):
    return _ssd(x, dt, a_log, b, c, chunk, _interp(interpret))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, w, *, eps: float = 1e-5, interpret: bool = None):
    return rmsnorm_pallas(x, w, eps=eps, interpret=_interp(interpret))
