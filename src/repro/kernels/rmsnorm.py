"""Fused RMSNorm row kernel (Pallas TPU)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x, w, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    n = xf.shape[0] // block_rows
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w)
    if pad:
        out = out[:R]
    return out.reshape(orig_shape)
