"""Compat shims over Pallas TPU API drift.

JAX renamed ``pltpu.CompilerParams`` to ``pltpu.TPUCompilerParams`` (and a
later release renamed it back); resolving the name at import time keeps the
kernels working across the rename in either direction.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "TPUCompilerParams", None)
                  or pltpu.CompilerParams)

__all__ = ["CompilerParams"]
