"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm (arXiv 2405.21060 §6): the sequence is
processed in chunks of Q tokens.  Within a chunk the dual "attention" form is
three MXU matmuls ((Q,N)x(N,Q), (Q,Q)x(Q,P), (Q,N)x(N,P)); across chunks the
(P,N) state is carried in VMEM scratch through the sequentially-iterated chunk
grid dimension.  Cumulative decays use a lower-triangular ones matmul rather
than cumsum so everything maps onto the MXU.

Grid: (batch, head, chunk) with chunk innermost ("arbitrary" = sequential).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_kernel(a_log_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    Q = chunk
    x = x_ref[0, :, 0, :].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)[:, None]    # (Q, 1)
    b = b_ref[0, :, 0, :].astype(jnp.float32)            # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)            # (Q, N)
    A = -jnp.exp(a_log_ref[0].astype(jnp.float32))       # scalar

    dA = dt * A                                          # (Q, 1)
    # inclusive cumulative sum via lower-triangular ones matmul (MXU-friendly)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = (rows >= cols).astype(jnp.float32)
    cum = jax.lax.dot_general(tril, dA, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q,1)

    # --- intra-chunk quadratic term ---
    decay = jnp.where(rows >= cols, jnp.exp(cum - cum.T), 0.0)     # (Q,Q)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    scores = cb * decay * dt.T                                     # dt_s on cols
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)

    # --- inter-chunk contribution: C_t . h_prev, scaled by exp(cum_t) ---
    h_prev = h_ref[...]                                            # (P,N)
    y_inter = jax.lax.dot_general(c, h_prev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q,P)
    y = y + jnp.exp(cum) * y_inter
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # --- state update: h = exp(cum_Q) h_prev + X^T (tail*dt*B) ---
    total = cum[Q - 1, 0]
    tail = jnp.exp(total - cum)                                    # (Q,1)
    hb = jax.lax.dot_general(x, b * (tail * dt), (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (P,N)
    h_ref[...] = jnp.exp(total) * h_prev + hb


def ssd_chunked_pallas(x, dt, a_log, b, c, *, chunk: int = 128,
                       interpret: bool = False):
    """x: (B,L,H,P); dt: (B,L,H); a_log: (H,); b,c: (B,L,G,N) -> (B,L,H,P)."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, h, n: (h,)),
            pl.BlockSpec((1, Q, 1, P), lambda bi, h, n: (bi, n, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, h, n: (bi, n, h)),
            pl.BlockSpec((1, Q, 1, N), lambda bi, h, n: (bi, n, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda bi, h, n: (bi, n, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda bi, h, n: (bi, n, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_log, x, dt, b, c)
