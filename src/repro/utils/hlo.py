"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``compiled.as_text()`` is the partitioned module, so shapes are PER-DEVICE.
For each collective op we count the RESULT shape's bytes — the amount of data
that lands on each device (all-gather: full gathered block; all-reduce:
the reduced buffer; reduce-scatter: the scattered shard; all-to-all /
collective-permute: the exchanged block).  A per-op breakdown is returned so
the roofline can attribute traffic (grad all-reduce vs. FSDP all-gather vs.
MoE exchange).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# e.g.:  %all-gather.3 = bf16[4,128]{1,0} all-gather(...)
#        ROOT %x = (f32[2]{0}, f32[2]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")

_COMP_RE = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*{?\s*$")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_hlo_collectives(hlo_text: str) -> List[Tuple[str, str, int]]:
    """Returns [(computation_name, op_kind, result_bytes_per_device)]."""
    out = []
    comp = "<module>"
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("(" in stripped or "%" in stripped):
            head = stripped.split("(")[0].strip().lstrip("%")
            head = head.split()[0] if head else comp
            if head and not head.startswith("ROOT"):
                comp = head
        m = _OP_RE.search(line)
        if m:
            kind = m.group(2).replace("-start", "")
            out.append((comp, kind, _shape_bytes(m.group(1))))
    return out


def collective_bytes(hlo_text: str, *, body_multipliers: Dict[str, int] = None
                     ) -> Dict[str, int]:
    """Total per-device collective bytes by kind.

    body_multipliers: {computation-name-substring: trip_count} — collectives
    inside a matching computation (e.g. a scanned layer body) are counted
    trip_count times.  Without it, while-loop bodies count once (the caller
    should prefer the unrolled cost-composition path; see launch/dryrun.py).
    """
    body_multipliers = body_multipliers or {}
    totals: Dict[str, int] = defaultdict(int)
    for comp, kind, nbytes in parse_hlo_collectives(hlo_text):
        mult = 1
        for frag, m in body_multipliers.items():
            if frag in comp:
                mult = m
                break
        totals[kind] += nbytes * mult
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return dict(totals)
