"""Roofline terms from dry-run analyses (TPU v5e targets).

Terms (per training/serving step, seconds):
    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` operates on the PARTITIONED module, so its
'flops' / 'bytes accessed' are already per-device — equivalent to the
assignment's HLO_FLOPs / (chips x peak) with global numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip (v5e)
    hbm_bw: float = 819e9            # bytes/s / chip
    ici_bw: float = 50e9             # bytes/s / link (effective per chip)
    hbm_bytes: float = 16e9          # HBM capacity / chip


V5E = HW()


@dataclass
class RooflineTerms:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float        # 6*N*D (or 6*N_active*D for MoE)
    chips: int
    hw: HW = field(default_factory=lambda: V5E)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — remat/padding/dispatch waste detector."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization achievable at the roofline bound."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return self.model_flops_global / (self.chips * self.hw.peak_flops * t)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "chips": self.chips,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "step_time_lower_bound": self.step_time_lower_bound,
        }


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """``compiled.cost_analysis()`` drifted across JAX versions: older
    releases return a list with one properties-dict per program, newer ones
    return the dict directly (and either may be None/empty).  Normalize to a
    flat dict so callers never care."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def roofline_from_analysis(cost, collective_bytes_per_device: float,
                           model_flops_global: float, chips: int,
                           hw: HW = V5E) -> RooflineTerms:
    """``cost`` is a ``cost_analysis()`` result in any JAX flavor (dict,
    [dict], or None) or a hand-built {'flops', 'bytes accessed'} dict."""
    cost = normalize_cost_analysis(cost)
    return RooflineTerms(
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=collective_bytes_per_device,
        model_flops_global=model_flops_global,
        chips=chips, hw=hw)
