"""Analytic FLOPs and HBM-traffic models per (arch x shape) cell.

XLA's ``cost_analysis`` counts while-loop bodies ONCE regardless of trip
count (verified experimentally — see EXPERIMENTS.md §Dry-run methodology), so
the scan-structured models (layer scan, blocked-attention KV scan, SSD chunk
scan, chunked loss) cannot be costed from the compiled module.  These
closed-form models count exactly what the implementation executes:

- blocked attention computes ALL KV blocks (masked, not skipped): fwd QK^T+AV
  = 4*B*S^2*H*hd, bwd ~2x + one recompute of the score matmul;
- SSD chunk math: per token per head 2*Q*(N+P) intra + ~8*P*N state work;
- MoE gather dispatch computes B*E*capacity token slots (padding included);
- vocab padding and remat recompute are included — so
  MODEL_FLOPS / analytic_total is a real waste metric.

Training total = fwd + 2x bwd + 1x remat recompute (full remat policy)
               + optimizer elementwise (~10 flops/param).
Everything is GLOBAL; divide by chips for per-device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import (ATTN, FF_GELU, FF_MOE, FF_NONE, FF_RELU2,
                                FF_SWIGLU, MLA, SSM, ModelConfig, ShapeConfig)


def _ffn_flops_per_tok(cfg, kind: str, d_ff: int) -> float:
    d = cfg.d_model
    return (6.0 if kind == FF_SWIGLU else 4.0) * d * d_ff


def _moe_flops_per_tok(cfg) -> float:
    m, d = cfg.moe, cfg.d_model
    mults = 6.0 if m.ff_kind == FF_SWIGLU else 4.0
    # dispatched token-slots per real token: E * cap / S ~= k * capacity_factor
    # (cap includes padding; mirror cells' cap formula per sequence)
    slots_per_tok = m.experts_per_token * m.capacity_factor
    total = mults * d * m.d_ff_expert * slots_per_tok
    total += 2.0 * d * m.num_experts                       # router
    if m.num_shared_experts:
        total += mults * d * m.num_shared_experts * m.d_ff_expert
    return total


def _attn_proj_flops_per_tok(cfg) -> float:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    return 2.0 * d * hd * (h + 2 * kv) + 2.0 * h * hd * d


def _mla_proj_flops_per_tok(cfg) -> float:
    a, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk = a.qk_nope_head_dim + a.qk_rope_head_dim
    f = 2.0 * d * (a.kv_lora_rank + a.qk_rope_head_dim)        # kv down
    if a.q_lora_rank:
        f += 2.0 * d * a.q_lora_rank + 2.0 * a.q_lora_rank * h * qk
    else:
        f += 2.0 * d * h * qk
    # per-token K/V expansion from the latent (train/prefill path)
    f += 2.0 * a.kv_lora_rank * h * (a.qk_nope_head_dim + a.v_head_dim)
    f += 2.0 * h * a.v_head_dim * d                            # o proj
    return f


def _ssm_flops_per_tok(cfg) -> float:
    ss, d = cfg.ssm, cfg.d_model
    di = ss.expand * d
    nh = ss.num_heads or di // ss.head_dim
    gn = ss.num_groups * ss.d_state
    f = 2.0 * d * (2 * di + 2 * gn + nh)                       # in_proj
    f += 2.0 * ss.conv_width * (di + 2 * gn)                   # conv
    # SSD core: intra-chunk 2*Q*(N+P) per head-token + state update ~8*P*N/Q
    Q, N, P = ss.chunk, ss.d_state, ss.head_dim
    f += nh * (2.0 * Q * (N + P) + 8.0 * P * N)
    f += 2.0 * di * d                                          # out proj
    return f


def _attn_ctx_flops(cfg, B: int, Sq: int, Sk: int) -> float:
    """Score+AV matmuls (all blocks computed, masked)."""
    h = cfg.num_heads
    if cfg.mla is not None:
        a = cfg.mla
        return 2.0 * B * Sq * Sk * h * (a.qk_nope_head_dim + a.qk_rope_head_dim) \
            + 2.0 * B * Sq * Sk * h * a.v_head_dim
    hd = cfg.resolved_head_dim
    return 4.0 * B * Sq * Sk * h * hd


def fwd_flops(cfg: ModelConfig, B: int, S: int, enc_len: int = 0) -> float:
    """Global forward FLOPs for a full sequence pass (train/prefill)."""
    tok = float(B) * S
    total = 0.0
    for i in range(cfg.num_layers):
        mixer = cfg.mixer_at(i)
        if mixer == ATTN:
            total += tok * _attn_proj_flops_per_tok(cfg)
            total += _attn_ctx_flops(cfg, B, S, S)
        elif mixer == MLA:
            total += tok * _mla_proj_flops_per_tok(cfg)
            total += _attn_ctx_flops(cfg, B, S, S)
        elif mixer == SSM:
            total += tok * _ssm_flops_per_tok(cfg)
        ff = cfg.ff_at(i)
        if ff == FF_MOE:
            total += tok * _moe_flops_per_tok(cfg)
        elif ff != FF_NONE:
            total += tok * _ffn_flops_per_tok(cfg, ff, cfg.d_ff)
        if cfg.enc_layers:   # cross attention in every decoder layer
            total += tok * _attn_proj_flops_per_tok(cfg)
            total += _attn_ctx_flops(cfg, B, S, enc_len or S)
    if cfg.enc_layers:
        etok = float(B) * (enc_len or S)
        per = (_attn_proj_flops_per_tok(cfg)
               + _ffn_flops_per_tok(cfg, cfg.ff_kind, cfg.d_ff))
        total += cfg.enc_layers * (etok * per
                                   + _attn_ctx_flops(cfg, B, enc_len or S,
                                                     enc_len or S))
    total += 2.0 * tok * cfg.d_model * cfg.padded_vocab       # lm head
    return total


def decode_flops(cfg: ModelConfig, B: int, ctx: int) -> float:
    """One decode step for B sequences against a ctx-long cache."""
    total = 0.0
    for i in range(cfg.num_layers):
        mixer = cfg.mixer_at(i)
        if mixer == ATTN:
            total += B * _attn_proj_flops_per_tok(cfg)
            total += _attn_ctx_flops(cfg, B, 1, ctx)
        elif mixer == MLA:
            a = cfg.mla
            h = cfg.num_heads
            # absorbed path: q_lat + scores/ctx against the latent cache
            total += B * _mla_proj_flops_per_tok(cfg)
            total += 2.0 * B * h * a.qk_nope_head_dim * a.kv_lora_rank
            total += 2.0 * B * ctx * h * (a.kv_lora_rank + a.qk_rope_head_dim)
            total += 2.0 * B * ctx * h * a.kv_lora_rank
        elif mixer == SSM:
            ss = cfg.ssm
            di = ss.expand * cfg.d_model
            nh = ss.num_heads or di // ss.head_dim
            total += B * (_ssm_flops_per_tok(cfg)
                          + 6.0 * nh * ss.head_dim * ss.d_state)
        ff = cfg.ff_at(i)
        if ff == FF_MOE:
            total += B * _moe_flops_per_tok(cfg)
        elif ff != FF_NONE:
            total += B * _ffn_flops_per_tok(cfg, ff, cfg.d_ff)
        if cfg.enc_layers:
            total += B * _attn_proj_flops_per_tok(cfg)
            total += _attn_ctx_flops(cfg, B, 1, ctx)
    total += 2.0 * B * cfg.d_model * cfg.padded_vocab
    return total


# bwd = 2x fwd; full-remat recompute = +1x fwd; optimizer ~10 flops/param
TRAIN_MULT = 4.0


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    from repro.configs.base import count_params
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return TRAIN_MULT * fwd_flops(cfg, B, S, enc_len=S) \
            + 10.0 * count_params(cfg)
    if shape.kind == "prefill":
        return fwd_flops(cfg, B, S, enc_len=S)
    return decode_flops(cfg, B, S)


# ---------------------------------------------------------------------------
# HBM traffic (global bytes per step) — coarse but explicit
# ---------------------------------------------------------------------------

def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Per-step global HBM traffic:

    train:   params bf16 read 3x (fwd, remat, bwd) + grad write + optimizer
             m/v read+write (fp32) + param rw  ~= 26 bytes/param
             + activation traffic ~= 24 bytes per token per d_model per layer
    prefill: params once + activations fwd + cache write
    decode:  params once + full cache read + tiny activations
    """
    from repro.configs.base import count_params
    P = float(count_params(cfg))
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, cfg.num_layers + cfg.enc_layers

    def act_bytes(tokens, mult):
        return mult * tokens * d * L

    def cache_bytes():
        total = 0.0
        for i in range(cfg.num_layers):
            mixer = cfg.mixer_at(i)
            if mixer == ATTN:
                total += 2.0 * B * S * cfg.num_kv_heads * \
                    cfg.resolved_head_dim * 2
            elif mixer == MLA:
                a = cfg.mla
                total += B * S * (a.kv_lora_rank + a.qk_rope_head_dim) * 2
            elif mixer == SSM:
                ss = cfg.ssm
                di = ss.expand * d
                nh = ss.num_heads or di // ss.head_dim
                total += B * (nh * ss.head_dim * ss.d_state * 4
                              + (ss.conv_width - 1) * (di + 2 * ss.num_groups
                                                       * ss.d_state) * 2)
        return total

    if shape.kind == "train":
        return 26.0 * P + act_bytes(B * S, 24.0)
    if shape.kind == "prefill":
        return 2.0 * P + act_bytes(B * S, 8.0) + cache_bytes()
    # decode: weights (active) + cache read/write dominate
    from repro.configs.base import count_active_params
    return 2.0 * count_active_params(cfg) + cache_bytes() + 8.0 * B * d * L
