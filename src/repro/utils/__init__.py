from repro.utils.hlo import collective_bytes, parse_hlo_collectives
from repro.utils.roofline import HW, RooflineTerms, roofline_from_analysis

__all__ = ["collective_bytes", "parse_hlo_collectives", "HW",
           "RooflineTerms", "roofline_from_analysis"]
