"""Config registry: exact published specs, param counts, layer layouts."""
import pytest

from repro.configs import (ALL_ARCHS, SHAPES, count_active_params,
                           count_params, get_config, shape_applicable,
                           smoke_config)
from repro.configs.base import ATTN, FF_MOE, MLA, SSM

EXPECTED_ARCHS = {
    "mamba2-1.3b", "granite-moe-3b-a800m", "deepseek-v2-236b",
    "seamless-m4t-large-v2", "starcoder2-7b", "yi-9b", "minitron-4b",
    "yi-6b", "jamba-v0.1-52b", "chameleon-34b",
}


def test_all_ten_archs_registered():
    assert set(ALL_ARCHS) == EXPECTED_ARCHS


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_param_count_matches_published_size(arch):
    cfg = get_config(arch)
    n = count_params(cfg)
    assert cfg.expected_params > 0
    err = abs(n - cfg.expected_params) / cfg.expected_params
    assert err < 0.10, f"{arch}: {n/1e9:.2f}B vs expected {cfg.expected_params/1e9:.2f}B"


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_tree_count_equals_analytic(arch):
    from repro.models import param_count
    cfg = get_config(arch)
    assert param_count(cfg) == count_params(cfg)


def test_exact_published_dims():
    c = get_config("deepseek-v2-236b")
    assert (c.num_layers, c.d_model, c.num_heads) == (60, 5120, 128)
    assert c.mla.kv_lora_rank == 512 and c.moe.num_experts == 160
    assert c.moe.experts_per_token == 6 and c.moe.num_shared_experts == 2
    c = get_config("yi-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (48, 4096, 32, 4, 11008, 64000)
    c = get_config("starcoder2-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    c = get_config("mamba2-1.3b")
    assert c.ssm.d_state == 128 and c.num_layers == 48 and c.d_model == 2048
    c = get_config("granite-moe-3b-a800m")
    assert c.moe.num_experts == 40 and c.moe.experts_per_token == 8
    c = get_config("jamba-v0.1-52b")
    assert c.moe.num_experts == 16 and c.moe.experts_per_token == 2
    c = get_config("seamless-m4t-large-v2")
    assert c.enc_layers == 24 and c.vocab_size == 256_206
    c = get_config("chameleon-34b")
    assert c.qk_norm and c.d_model == 8192


def test_moe_active_params():
    c = get_config("granite-moe-3b-a800m")
    assert count_active_params(c) < 1.0e9          # "a800m"
    c = get_config("deepseek-v2-236b")
    assert 18e9 < count_active_params(c) < 25e9    # ~21B active


def test_jamba_layer_layout():
    c = get_config("jamba-v0.1-52b")
    mixers = [c.mixer_at(i) for i in range(8)]
    assert mixers.count(ATTN) == 1 and mixers.count(SSM) == 7
    ffs = [c.ff_at(i) for i in range(8)]
    assert ffs.count(FF_MOE) == 4
    assert c.layer_period() == 8 and c.scan_layers() == (0, 32)


def test_deepseek_first_dense_layer():
    c = get_config("deepseek-v2-236b")
    assert c.ff_at(0) != FF_MOE and c.ff_at(1) == FF_MOE
    assert c.mixer_at(0) == MLA
    assert c.scan_layers() == (1, 59)


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runnable = [a for a in ALL_ARCHS if shape_applicable(get_config(a), long)[0]]
    assert sorted(runnable) == ["jamba-v0.1-52b", "mamba2-1.3b"]
    # all other shapes apply to every arch
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for a in ALL_ARCHS:
            assert shape_applicable(get_config(a), SHAPES[s])[0]


def test_cell_grid_is_40():
    from repro.launch.cells import all_cells
    cells = all_cells()
    assert len(cells) == 40
    # long_500k is skipped for the 8 pure full-attention archs -> 32 runnable
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_smoke_config_is_structurally_faithful(arch):
    full, small = get_config(arch), smoke_config(arch)
    assert small.family == full.family
    assert (small.moe is None) == (full.moe is None)
    assert (small.ssm is None) == (full.ssm is None)
    assert (small.mla is None) == (full.mla is None)
    assert (small.enc_layers > 0) == (full.enc_layers > 0)
    assert small.layer_period() == full.layer_period()
    assert count_params(small) < 2_000_000


def test_padded_vocab():
    c = get_config("mamba2-1.3b")
    assert c.padded_vocab % 256 == 0 and c.padded_vocab >= c.vocab_size
    assert c.padded_vocab % 16 == 0
