"""HLO collective parser and roofline arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import (collective_bytes, parse_hlo_collectives,
                             _shape_bytes)
from repro.utils.roofline import HW, RooflineTerms, roofline_from_analysis


def test_shape_bytes():
    assert _shape_bytes("bf16[4,128]{1,0}") == 4 * 128 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("(f32[2]{0}, bf16[3,3]{1,0})") == 8 + 18
    assert _shape_bytes("pred[7]") == 7


SAMPLE_HLO = """
HloModule jit_f

%region_0.10 (a: f32[4]) -> f32[4] {
  ROOT %add = f32[4]{0} add(...)
}

%while_body.3 (arg: (s32[], bf16[8,16])) -> (s32[], bf16[8,16]) {
  %ag = bf16[8,16]{1,0} all-gather(bf16[8,4]{1,0} %x), dimensions={1}
  ROOT %t = (s32[], bf16[8,16]) tuple(...)
}

ENTRY %main () -> f32[2] {
  %ar = f32[64,32]{1,0} all-reduce(f32[64,32]{1,0} %p), to_apply=%region_0.10
  %rs = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %q), dimensions={0}
  %cp = bf16[128]{0} collective-permute(bf16[128]{0} %r)
  %a2a = f32[4,4]{1,0} all-to-all(f32[4,4]{1,0} %s)
}
"""


def test_parse_collectives_kinds_and_sizes():
    got = parse_hlo_collectives(SAMPLE_HLO)
    kinds = sorted(k for _, k, _ in got)
    assert kinds == sorted(["all-gather", "all-reduce", "reduce-scatter",
                            "collective-permute", "all-to-all"])
    sizes = {k: b for _, k, b in got}
    assert sizes["all-reduce"] == 64 * 32 * 4
    assert sizes["all-gather"] == 8 * 16 * 2
    assert sizes["reduce-scatter"] == 8 * 32 * 4


def test_body_multipliers_scale_loop_collectives():
    base = collective_bytes(SAMPLE_HLO)
    scaled = collective_bytes(SAMPLE_HLO, body_multipliers={"while": 10})
    assert scaled["all-gather"] == 10 * base["all-gather"]
    assert scaled["all-reduce"] == base["all-reduce"]


def test_parser_on_real_compiled_module():
    """End-to-end on an actually compiled SPMD module (1-device fallback:
    no collectives is acceptable; on sharded builds they appear)."""
    f = jax.jit(lambda x: jnp.sum(x * x))
    txt = f.lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)) \
           .compile().as_text()
    got = collective_bytes(txt)
    assert got["total"] >= 0


def test_roofline_terms_and_bottleneck():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0)
    t = RooflineTerms(flops_per_device=1000.0, hbm_bytes_per_device=50.0,
                      collective_bytes_per_device=2.0,
                      model_flops_global=8000.0, chips=16, hw=hw)
    assert t.t_compute == pytest.approx(10.0)
    assert t.t_memory == pytest.approx(5.0)
    assert t.t_collective == pytest.approx(2.0)
    assert t.bottleneck == "compute"
    assert t.step_time_lower_bound == pytest.approx(10.0)
    assert t.useful_flops_fraction == pytest.approx(8000.0 / 16000.0)
    # mfu at the bound: model flops / (chips * peak * t)
    assert t.mfu_bound == pytest.approx(8000.0 / (16 * 100.0 * 10.0))


def test_roofline_from_cost_analysis_dict():
    t = roofline_from_analysis({"flops": 10.0, "bytes accessed": 20.0},
                               collective_bytes_per_device=5.0,
                               model_flops_global=100.0, chips=4)
    assert t.flops_per_device == 10.0
    assert t.hbm_bytes_per_device == 20.0
    assert t.collective_bytes_per_device == 5.0


def test_roofline_normalizes_cost_analysis_jax_flavors():
    """compiled.cost_analysis() drifted across JAX versions: older releases
    return [properties-dict], newer ones the dict itself, either may be
    None/empty — all four shapes must work (the list flavor is the seed
    failure behind test_dryrun_machinery_small_mesh)."""
    from repro.utils.roofline import normalize_cost_analysis
    d = {"flops": 10.0, "bytes accessed": 20.0}
    assert normalize_cost_analysis(d) == d
    assert normalize_cost_analysis([d]) == d
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis([]) == {}
    t = roofline_from_analysis([d], collective_bytes_per_device=5.0,
                               model_flops_global=100.0, chips=4)
    assert t.flops_per_device == 10.0
    assert t.hbm_bytes_per_device == 20.0
