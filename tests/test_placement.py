"""Placement layer: slot->node ownership, pack/spread strategies, cordon +
drain semantics, node-exact spot kills, drain-aware scale-down, and the
node-aware live operator (stub trainers — no JAX needed)."""
import pytest

from repro.cloud import (AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool, SPOT)
from repro.core.cluster import Cluster
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.operator import ElasticClusterController
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.placement import PlacementError, PlacementMap
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload


def wl(steps=100.0, t1=1.0, t_many=1.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t1), (64.0, t_many))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


# ---------------------------------------------------------------------------
# PlacementMap primitives
# ---------------------------------------------------------------------------

def _two_nodes(strategy):
    p = PlacementMap(strategy)
    p.add_node("n0", 4)
    p.add_node("n1", 4)
    return p


def test_pack_fills_fullest_node_first():
    p = _two_nodes("pack")
    p.place("a", 2)                       # n0: a,a,_,_
    p.place("b", 3)                       # fills n0, overflows 1 to n1
    assert p.residents("n0") == {"a": 2, "b": 2}
    assert p.residents("n1") == {"b": 1}


def test_spread_round_robins_emptiest_first():
    p = _two_nodes("spread")
    p.place("a", 2)
    assert p.job_nodes("a") == {"n0": 1, "n1": 1}
    p.place("b", 4)
    assert p.job_nodes("b") == {"n0": 2, "n1": 2}


def test_place_is_all_or_nothing():
    p = _two_nodes("pack")
    p.place("a", 7)
    with pytest.raises(PlacementError):
        p.place("b", 2)
    assert p.owned("b") == 0              # nothing partially assigned
    p.place("b", 1)
    assert p.free() == 0


def test_no_double_ownership_across_ops():
    p = _two_nodes("pack")
    p.place("a", 3)
    p.place("b", 4)
    p.evict("a", 1)
    p.place("c", 2)
    owners = {}
    for nid in p.nodes():
        for job, cnt in p.residents(nid).items():
            owners[job] = owners.get(job, 0) + cnt
    assert owners == {"a": 2, "b": 4, "c": 2}
    assert sum(owners.values()) + p.free() == 8
    p.check()


def test_cordon_excludes_capacity_and_placement():
    p = _two_nodes("pack")
    p.place("a", 4)                       # fills n0
    p.cordon("n1")
    assert p.total_capacity == 4
    assert p.free() == 0
    with pytest.raises(PlacementError):
        p.place("b", 1)
    p.uncordon("n1")
    assert p.free() == 4


def test_evict_vacates_cordoned_node_first():
    p = _two_nodes("pack")
    p.place("a", 6)                       # n0 full, n1 holds 2
    p.cordon("n0")
    freed = p.evict("a", 4)
    assert p.residents("n0") == {}        # the draining node emptied first
    assert p.residents("n1") == {"a": 2}
    assert len(freed) == 4


def test_remove_node_refuses_residents_then_succeeds():
    p = _two_nodes("pack")
    p.place("a", 2)
    with pytest.raises(PlacementError):
        p.remove_node("n0")
    p.evict("a")
    assert p.remove_node("n0") == 4
    assert p.node_count == 1


def test_migrate_moves_residents_off_node():
    p = _two_nodes("pack")
    p.place("a", 3)                       # all on n0
    assert p.migrate("a", "n0") == 3
    assert p.residents("n0") == {}
    assert p.residents("n1") == {"a": 3}
    # b: pack tops up n1's last slot, overflows 3 onto n0
    p.place("b", 4)
    assert p.job_nodes("b") == {"n0": 3, "n1": 1}
    # the only free slot left sits ON n0 itself -> nothing can move off it
    assert p.free() == 1 and p.free("n0") == 1
    assert p.migrate("b", "n0") == 0


def test_fragmentation_pack_vs_spread():
    pack, spread = _two_nodes("pack"), _two_nodes("spread")
    pack.place("a", 2)
    spread.place("a", 2)
    # pack strands 2 free slots on n0; n1 stays whole-node free
    assert pack.fragmentation() == pytest.approx(2 / 6)
    # spread strands ALL free capacity on partially-used nodes
    assert spread.fragmentation() == pytest.approx(1.0)
    empty = _two_nodes("pack")
    assert empty.fragmentation() == 0.0


# ---------------------------------------------------------------------------
# Cluster integration
# ---------------------------------------------------------------------------

def test_cluster_base_capacity_partitions_into_nodes():
    c = Cluster(10, slots_per_node=4)
    assert c.nodes() == ["base00", "base01", "base02"]
    assert c.total_slots == 10            # last node holds the 2-slot tail
    c2 = Cluster(4)
    assert c2.nodes() == ["base"]


def test_cluster_residency_tracks_used_slots():
    sim = Simulator(16, PolicyConfig(rescale_gap=0.0), slots_per_node=8)
    sim.submit(JobSpec("a", 1, 4, 8, 0.0), wl(50))
    sim.submit(JobSpec("b", 2, 4, 8, 1.0), wl(50))
    sim.run()
    # after completion everything is evicted
    assert sim.cluster.used_slots == 0
    assert all(not sim.cluster.residents(n) for n in sim.cluster.nodes())


# ---------------------------------------------------------------------------
# CloudSimulator: node-exact spot kills (acceptance criterion)
# ---------------------------------------------------------------------------

def _spot_prov(nodes=3, slots=8, lifetime=1e12):
    return CloudProvider([NodePool(
        "sp", slots_per_node=slots, market=SPOT, initial_nodes=nodes,
        max_nodes=nodes, spot_lifetime_mean=lifetime)])


def test_spot_kill_displaces_only_killed_nodes_residents():
    prov = _spot_prov(nodes=3)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0))
    # three rigid 8-slot jobs -> pack pins one per node
    for i in range(3):
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, float(i) * 0.001), wl(500))
    victim_node = sorted(prov.nodes)[1]

    resident_snapshot = {}
    # snapshot residency the instant the kill lands, then let it proceed
    prov.inject_spot_kill(victim_node, 10.0, sim.queue)
    orig = sim._on_spot_kill

    def probed(node_id):
        resident_snapshot.update(sim.cluster.residents(node_id))
        orig(node_id)
    sim._on_spot_kill = probed
    sim.run()
    assert len(resident_snapshot) == 1    # exactly one job lived there
    (victim_job,) = resident_snapshot
    for i in range(3):
        j = sim.cluster.jobs[f"j{i}"]
        if j.job_id == victim_job:
            assert j.preempt_count == 1   # rigid: checkpoint-preempted
        else:
            assert j.preempt_count == 0   # bystanders untouched
            assert j.rescale_count == 0
    assert sim.spot_victim_jobs == 1
    assert sim.kill_blasts == [(1, 8, 1, "default-a")]


def test_spot_kill_migrates_residents_when_free_capacity_exists():
    prov = _spot_prov(nodes=3)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0))
    sim.submit(JobSpec("a", 1, 8, 8, 0.0), wl(300))   # one node, rigid
    # pack places on the first bootstrapped node; kill exactly that one
    victim = sorted(prov.nodes)[0]
    prov.inject_spot_kill(victim, 10.0, sim.queue)
    m = sim.run()
    a = sim.cluster.jobs["a"]
    # two empty nodes remained -> workers migrated, no shrink, no preempt
    assert a.preempt_count == 0 and a.rescale_count == 0
    assert sim.migrations == 1
    assert a.status is JobStatus.COMPLETED
    assert m.kill_blast_jobs == 1.0
    assert m.kill_blast_radius == pytest.approx(8.0)
    assert m.kill_preemptions == 0.0
    # migration pays an overhead: slower than the 300 s solo runtime
    assert a.end_time > 300.0


def test_spot_kill_shrink_prefers_killed_node_over_other_cordoned():
    """With another node cordoned (an in-flight drain), a kill's forced
    shrink must still come off the KILLED node, not the draining one —
    otherwise the victim pays a shrink AND a preemption."""
    prov = _spot_prov(nodes=3)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0))
    job = JobState(spec=JobSpec("a", 1, 8, 24, 0.0), work_remaining=100.0)
    sim.workloads["a"] = wl(100)
    sim.cluster.add_job(job)
    assert sim.actions.create(job, 24)        # spans all three nodes
    nodes = sorted(prov.nodes)
    sim.cluster.cordon(nodes[2])              # unrelated drain in flight
    prov.inject_spot_kill(nodes[0], 10.0, sim.queue)
    sim.run()
    a = sim.cluster.jobs["a"]
    assert a.preempt_count == 0               # shrink absorbed the kill
    assert a.rescale_count == 1
    assert sim.kill_blasts == [(1, 8, 0, "default-a")]


def test_spot_kill_shrink_comes_off_killed_node_exactly():
    prov = _spot_prov(nodes=2)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0))
    sim.submit(JobSpec("a", 1, 4, 16, 0.0), wl(100))  # elastic 16 across both
    victim = sorted(prov.nodes)[0]
    prov.inject_spot_kill(victim, 20.0, sim.queue)
    m = sim.run()
    a = sim.cluster.jobs["a"]
    assert a.preempt_count == 0 and a.rescale_count == 1
    assert m.dropped_jobs == 0
    assert sim.kill_blasts == [(1, 8, 0, "default-a")]


# ---------------------------------------------------------------------------
# Drain-aware decommission + autoscaler scale-down
# ---------------------------------------------------------------------------

def test_decommission_returns_false_on_occupied_node():
    prov = _spot_prov(nodes=2)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0))
    job = JobState(spec=JobSpec("a", 1, 8, 8, 0.0))
    sim.workloads["a"] = wl(200)
    sim.cluster.add_job(job)
    assert sim.actions.create(job, 8)
    occupied = [n for n in sim.cluster.nodes() if sim.cluster.residents(n)]
    empty = [n for n in sim.cluster.nodes() if not sim.cluster.residents(n)]
    assert sim.decommission(occupied[0]) is False     # guarded, no crash
    assert sim.decommission(empty[0]) is True


def test_autoscaler_drains_min_residency_node_via_migration():
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=30.0,
                                   teardown_delay=10.0, initial_nodes=3,
                                   max_nodes=3)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, scale_down_cooldown=30.0,
        idle_timeout=60.0))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    # one long rigid 4-slot job: 20 of 24 slots idle, but under `pack` the
    # job pins one node; the other two are empty and must be released; the
    # job's own node must NOT be (its resident cannot migrate forever —
    # free capacity shrinks to zero as nodes retire)
    sim.submit(JobSpec("a", 1, 4, 4, 0.0), wl(1500))
    m = sim.run()
    assert sim.cluster.jobs["a"].status is JobStatus.COMPLETED
    assert asc.scale_downs == 2
    assert sim.cluster.jobs["a"].preempt_count == 0


def test_drain_migrates_then_releases_partially_used_node():
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=30.0,
                                   teardown_delay=10.0, initial_nodes=2,
                                   max_nodes=2)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, scale_down_cooldown=30.0,
        idle_timeout=60.0))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc,
                         placement="spread")
    # spread puts 2+2 on the two nodes; scale-down must pick one, migrate its
    # 2 residents to the survivor, and release it
    sim.submit(JobSpec("a", 1, 4, 4, 0.0), wl(1200))
    m = sim.run()
    assert sim.cluster.jobs["a"].status is JobStatus.COMPLETED
    assert asc.scale_downs == 1
    assert sim.migrations >= 1
    assert sim.cluster.jobs["a"].preempt_count == 0
    assert m.total_cost < 2 * 8 * (1300 / 3600) * 0.048  # beat static-2


# ---------------------------------------------------------------------------
# Live operator: node-aware drain and failure (stub trainers, no JAX)
# ---------------------------------------------------------------------------

class _StubTrainer:
    def __init__(self, total_steps):
        self.total_steps = total_steps
        self.step_idx = 0
        self.devices_history = []

    @property
    def done(self):
        return self.step_idx >= self.total_steps

    def step(self):
        self.step_idx += 1

    def rescale(self, devices):
        from repro.core.elastic import RescaleTimings
        self.devices_history.append(tuple(devices))
        return RescaleTimings()


def _controller(**kw):
    kw.setdefault("slots", 8)
    kw.setdefault("slots_per_node", 4)
    kw.setdefault("policy", PolicyConfig(rescale_gap=0.0))
    return ElasticClusterController(list(range(8)), **kw)


def test_operator_partitions_devices_into_nodes():
    op = _controller()
    assert op.cluster.nodes() == ["base00", "base01"]


def test_operator_drain_node_migrates_live_job():
    op = _controller()
    op.submit(JobSpec("a", 1, 4, 4, 0.0, divides=8),
              lambda devices: _StubTrainer(100))
    op._process_submissions()
    job = op.cluster.jobs["a"]
    (home,) = [n for n in op.cluster.nodes() if op.cluster.residents(n)]
    other = [n for n in op.cluster.nodes() if n != home][0]
    trainer = op.live["a"].trainer
    op.drain_node(home)
    assert op.cluster.residents(home) == {}
    assert op.cluster.residents(other) == {"a": 4}
    assert job.replicas == 4                      # migrated, not shrunk
    assert len(trainer.devices_history) == 1      # live rescale onto new devs
    assert set(job.device_ids) == set(op.cluster.slots_of("a"))


def test_operator_drain_node_shrinks_when_short_on_space():
    op = _controller()
    op.submit(JobSpec("a", 1, 2, 8, 0.0, divides=8),
              lambda devices: _StubTrainer(100))
    op._process_submissions()
    job = op.cluster.jobs["a"]
    assert job.replicas == 8                      # filled both nodes
    op.drain_node("base01")
    assert job.replicas == 4                      # nowhere to migrate: shrink
    assert op.cluster.residents("base01") == {}
    assert op.cluster.jobs["a"].status is JobStatus.RUNNING


def test_operator_node_failure_restarts_only_residents():
    op = _controller()
    op.submit(JobSpec("a", 1, 4, 4, 0.0, divides=8),
              lambda devices: _StubTrainer(100))
    op.submit(JobSpec("b", 1, 4, 4, 0.0, divides=8),
              lambda devices: _StubTrainer(100))
    op._process_submissions()
    homes = {jid: [n for n in op.cluster.nodes()
                   if jid in op.cluster.residents(n)][0]
             for jid in ("a", "b")}
    assert homes["a"] != homes["b"]
    victims = op.inject_node_failure(homes["a"])
    assert victims == ["a"]
    assert op.live["a"].failures == 1
    assert op.live["b"].failures == 0
    assert op.cluster.jobs["b"].status is JobStatus.RUNNING
    # the failed node is offline: the restarted job must land elsewhere —
    # but b owns the other node, so `a` stays pending until recovery
    op._process_submissions()
    assert "a" not in op.cluster.jobs or \
        op.cluster.jobs["a"].status is not JobStatus.RUNNING
    op.recover_node(homes["a"])
    op._process_submissions()
    assert op.cluster.jobs["a"].status is JobStatus.RUNNING
    assert op.cluster.residents(homes["a"]) == {"a": 4}
