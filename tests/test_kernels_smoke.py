"""Kernel smoke subset for the GATING fast lane: 4 float32 cases at the
smallest shapes, interpret mode.  The full dtype/shape sweep stays in
tests/test_kernels.py under the `slow` marker (non-blocking CI lane); this
file exists so a Pallas API drift breaks the build immediately instead of
silently reddening the slow lane (the pltpu.CompilerParams ->
TPUCompilerParams rename sat there as seed debt for four PRs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def test_flash_attention_smoke():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


def test_ssd_smoke():
    ks = jax.random.split(KEY, 5)
    B, L, H, P, G, N = 1, 32, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=8.0))
    b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    out = ops.ssd(x, dt, a_log, b, c, chunk=16, interpret=True)
    exp = ref.ssd_ref(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4,
                               rtol=1e-4)


def test_rmsnorm_smoke():
    x = jax.random.normal(KEY, (7, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64,), jnp.float32)
    out = ops.rmsnorm(x, w, interpret=True)
    exp = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6,
                               rtol=1e-6)


def test_compiler_params_compat_resolves():
    """The shim must resolve to a constructible params class accepting the
    dimension_semantics kwarg both kernels pass."""
    from repro.kernels.pallas_compat import CompilerParams
    p = CompilerParams(dimension_semantics=("parallel", "arbitrary"))
    assert p is not None
