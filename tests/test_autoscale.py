"""Beyond-paper policies (paper §3.2.2 Discussion / §6 Future work)."""
import pytest

from repro.core.autoscale import AgingPolicy, CostBenefitPolicy, PreemptingPolicy
from repro.core.job import JobSpec, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload


def wl(steps=100.0, t1=2.0, t_many=1.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t1), (64.0, t_many))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


def test_aging_promotes_starving_job():
    """§3.2.2: aging lets a low-priority job overtake equal-priority work
    after waiting long enough."""
    aging = AgingPolicy(PolicyConfig(rescale_gap=0.0), age_rate=1.0 / 100.0,
                        max_boost=4.0)
    now = 0.0
    lo = JobSpec("lo", 1, 4, 8, 0.0)
    from repro.core.job import JobState
    j = JobState(spec=lo, status=JobStatus.QUEUED)
    assert aging._priority(j, 0.0) == pytest.approx(1.0)
    assert aging._priority(j, 200.0) == pytest.approx(3.0)
    assert aging._priority(j, 10_000.0) == pytest.approx(5.0)   # capped
    j.status = JobStatus.RUNNING
    assert aging._priority(j, 10_000.0) == pytest.approx(1.0)   # only waiting ages


def test_aging_reduces_max_response_time_under_load():
    def run(policy_cls, **kw):
        pcfg = PolicyConfig(rescale_gap=0.0)
        sim = Simulator(8, pcfg)
        sim.policy = policy_cls(pcfg, **kw) if kw else policy_cls(pcfg)
        # a CONTINUOUS stream of freshly-arriving high-priority jobs: without
        # aging each fresh vip outranks the waiting low-priority job forever;
        # with aging the waiter's effective priority eventually wins (fresh
        # arrivals haven't accumulated any wait).
        sim.submit(JobSpec("vip0", 5, 8, 8, 0.0), wl(30, t1=1.0, t_many=1.0))
        sim.submit(JobSpec("starved", 1, 8, 8, 0.5), wl(10, t1=1.0, t_many=1.0))
        for i in range(1, 7):
            sim.submit(JobSpec(f"vip{i}", 5, 8, 8, 29.0 * i),
                       wl(30, t1=1.0, t_many=1.0))
        sim.run()
        return sim.cluster.jobs["starved"]

    from repro.core.policies import ElasticPolicy
    base = run(ElasticPolicy)
    aged = run(AgingPolicy, age_rate=1.0 / 20.0, max_boost=10.0)
    assert aged.start_time < base.start_time


def test_cost_benefit_declines_unprofitable_expansion():
    """§6: 'a small increase in the number of replicas may not justify the
    overhead of rescaling'."""
    flat = SimWorkload(                      # no speedup from more replicas
        scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
        total_work=100.0, data_bytes=1e9, rescale=RescaleModel())

    def run(use_cb):
        pcfg = PolicyConfig(rescale_gap=0.0)
        sim = Simulator(16, pcfg)
        if use_cb:
            sim.policy = CostBenefitPolicy(pcfg, lambda j: flat)
        sim.submit(JobSpec("b", 3, 8, 8, 0.0), SimWorkload(
            PiecewiseScalingModel(((1.0, 1.0),)), 10.0, 0.0, RescaleModel()))
        sim.submit(JobSpec("a", 3, 4, 16, 0.5), flat)   # starts in the 8 free
        sim.run()
        return sim.cluster.jobs["a"].rescale_count

    # plain elastic expands a 8->16 when b completes; cost-benefit sees zero
    # modeled speedup and declines
    assert run(False) >= 1
    assert run(True) == 0


def test_cost_benefit_protects_nearly_finished_jobs():
    """§6: 'allowing the job to complete would be more efficient than scaling
    it down to start another job'."""
    speedy = wl(steps=100.0, t1=1.0, t_many=1.0)
    pcfg = PolicyConfig(rescale_gap=0.0)

    def run(policy):
        sim = Simulator(16, pcfg)
        if policy is not None:
            sim.policy = policy
        sim.submit(JobSpec("old", 1, 4, 16, 0.0), wl(100, t1=1.0, t_many=1.0))
        # arrives when `old` is ~96% done
        sim.submit(JobSpec("new", 5, 8, 16, 96.0), speedy)
        sim.run()
        return sim.cluster.jobs["old"].rescale_count

    assert run(None) >= 1                     # plain elastic shrinks it
    cb = CostBenefitPolicy(pcfg, lambda j: wl(100, t1=1.0, t_many=1.0),
                           protect_tail=0.10)
    assert run(cb) == 0                       # cost-benefit lets it finish


def test_preemption_frees_room_for_high_priority():
    """§3.2.2: preempt (checkpoint to disk) when shrinking isn't enough."""
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = Simulator(8, pcfg)
    sim.policy = PreemptingPolicy(pcfg)
    sim.submit(JobSpec("lo", 1, 8, 8, 0.0), wl(50, t1=1.0, t_many=1.0))
    sim.submit(JobSpec("hi", 5, 8, 8, 1.0), wl(10, t1=1.0, t_many=1.0))
    m = sim.run()
    lo, hi = sim.cluster.jobs["lo"], sim.cluster.jobs["hi"]
    assert lo.preempt_count == 1
    assert hi.start_time == pytest.approx(1.0 + RescaleModel().preempt_cost(
        8, 1e9), rel=0.05)
    # the preempted job resumed and completed with its progress intact
    assert lo.end_time is not None and m.dropped_jobs == 0
    # resume paid the disk-restore overhead
    assert lo.end_time > 50.0 + 10.0


def test_preemption_never_hits_equal_or_higher_priority():
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = Simulator(8, pcfg)
    sim.policy = PreemptingPolicy(pcfg)
    sim.submit(JobSpec("peer", 5, 8, 8, 0.0), wl(50, t1=1.0, t_many=1.0))
    sim.submit(JobSpec("hi", 5, 8, 8, 1.0), wl(10, t1=1.0, t_many=1.0))
    sim.run()
    assert sim.cluster.jobs["peer"].preempt_count == 0
