"""Optimizer correctness and data-pipeline properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_config
from repro.data import make_stream
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
                      clip_norm=1e9)
    params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5]])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3]), "b": jnp.array([[1.0]])}
    state = adamw_init(params)
    lr = 0.1
    new_p, new_s, _ = adamw_update(cfg, grads, state, params, lr)
    # manual
    for k in params:
        g = np.asarray(grads[k], np.float64)
        m = 0.1 * g
        v = 0.001 * g ** 2
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.999)
        step = mh / (np.sqrt(vh) + 1e-8)
        exp = np.asarray(params[k]) - lr * (step + 0.01 * np.asarray(params[k]))
        np.testing.assert_allclose(np.asarray(new_p[k]), exp, rtol=1e-5)
    assert int(new_s["count"]) == 1


def test_adamw_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    big = {"w": jnp.full(4, 100.0)}            # norm 200
    state = adamw_init(params)
    p1, s1, m1 = adamw_update(cfg, big, state, params, 1.0)
    small = {"w": jnp.full(4, 0.5 * 100.0 / 200.0)}  # same direction, norm 1
    p2, s2, m2 = adamw_update(cfg, small, adamw_init(params), params, 1.0)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(200.0)


def test_warmup_cosine_schedule():
    lr = warmup_cosine(jnp.asarray(0), peak_lr=1.0, warmup_steps=10,
                       total_steps=100)
    assert float(lr) == pytest.approx(0.1)
    lr = warmup_cosine(jnp.asarray(9), peak_lr=1.0, warmup_steps=10,
                       total_steps=100)
    assert float(lr) == pytest.approx(1.0)
    lr_end = warmup_cosine(jnp.asarray(100), peak_lr=1.0, warmup_steps=10,
                           total_steps=100)
    assert float(lr_end) == pytest.approx(0.1, rel=1e-3)   # min_ratio floor


def test_moments_shard_like_params():
    from repro.models import abstract_params, logical_axes
    from repro.optim import abstract_opt_state, opt_logical_axes
    cfg = smoke_config("yi-6b")
    ap = abstract_params(cfg)
    ax = logical_axes(cfg)
    oax = opt_logical_axes(ax)
    os_ = abstract_opt_state(ap)
    flat_p = jax.tree.leaves(ap)
    flat_m = jax.tree.leaves(os_["m"])
    assert len(flat_p) == len(flat_m)
    for p, m in zip(flat_p, flat_m):
        assert p.shape == m.shape and m.dtype == jnp.float32


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 50),
       st.sampled_from([1, 2, 4, 8]))
def test_stream_shards_partition_global_batch(seed, step, replicas):
    """Union of replica shards == the global batch; shards are disjoint; the
    global batch does not depend on the replica count (elastic invariance)."""
    cfg = smoke_config("yi-6b")
    s = make_stream(cfg, seed=seed, global_batch=8, seq_len=16)
    full = s.global_batch_at(step)
    parts = [s.shard_at(step, r, replicas) for r in range(replicas)]
    rebuilt = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(rebuilt, full["tokens"])
    rebuilt_l = np.concatenate([p["labels"] for p in parts], axis=0)
    np.testing.assert_array_equal(rebuilt_l, full["labels"])


def test_stream_deterministic_and_step_dependent():
    cfg = smoke_config("yi-6b")
    s1 = make_stream(cfg, seed=3, global_batch=4, seq_len=16)
    s2 = make_stream(cfg, seed=3, global_batch=4, seq_len=16)
    np.testing.assert_array_equal(s1.global_batch_at(7)["tokens"],
                                  s2.global_batch_at(7)["tokens"])
    assert not np.array_equal(s1.global_batch_at(7)["tokens"],
                              s1.global_batch_at(8)["tokens"])


def test_stream_tokens_in_vocab_and_learnable():
    cfg = smoke_config("yi-6b")
    s = make_stream(cfg, seed=0, global_batch=4, seq_len=64)
    b = s.global_batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size
    # labels at odd target positions are a deterministic function of tokens
    toks, labs = b["tokens"], b["labels"]
    pred = (toks.astype(np.int64) * 2654435761 % cfg.vocab_size)
    hits = (labs == pred).mean()
    assert hits > 0.4     # ~half the positions follow the Markov rule


def test_encdec_stream_has_frames():
    cfg = smoke_config("seamless-m4t-large-v2")
    s = make_stream(cfg, seed=0, global_batch=2, seq_len=8)
    b = s.global_batch_at(0)
    assert b["enc_embeds"].shape == (2, 8, cfg.d_model)
    assert b["enc_embeds"].dtype == np.float32
