"""Subprocess helper: elastic rescale must reproduce the static trajectory.

Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
test harness).  Prints machine-checkable lines; exits nonzero on failure.
"""
import sys

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.elastic import ElasticTrainer, TrainJobConfig

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"

cfg = smoke_config(arch)
job = TrainJobConfig(global_batch=8, seq_len=32, total_steps=12, seed=3)
devs = jax.devices()
assert len(devs) == 8, len(devs)

static = ElasticTrainer(cfg, job, devs[:4])
for _ in range(12):
    m_static = static.step()

elastic = ElasticTrainer(cfg, job, devs[:4])
for _ in range(4):
    elastic.step()
t1 = elastic.rescale(devs[:2], via_host=True)       # shrink (forced host path)
for _ in range(4):
    elastic.step()
t2 = elastic.rescale(devs[:8])                      # expand (auto -> p2p)
for _ in range(4):
    m_elastic = elastic.step()
t3 = elastic.rescale(devs[:4])                      # revisit: warm mesh cache

pa = jax.tree.leaves(jax.device_get(static.params))
pb = jax.tree.leaves(jax.device_get(elastic.params))
perr = max(float(np.max(np.abs(a.astype(np.float32) - b.astype(np.float32))))
           for a, b in zip(pa, pb))
la = [x["loss"] for x in static.metrics_log]
lb = [x["loss"] for x in elastic.metrics_log]
lerr = max(abs(a - b) for a, b in zip(la, lb))

print(f"PARAM_ERR {perr:.3e}")
print(f"LOSS_ERR {lerr:.3e}")
print(f"LOSS_FIRST {la[0]:.4f} LOSS_LAST {la[-1]:.4f}")
print(f"STAGES1 {t1.as_dict()}")
print(f"STAGES2 {t2.as_dict()}")
print(f"STAGES3 {t3.as_dict()}")
assert perr < 5e-5, perr
assert lerr < 5e-5, lerr
assert la[-1] < la[0], "loss did not decrease"
assert all(v >= 0 for v in t1.as_dict().values())
assert t1.restart > 0, "restart (re-jit) must be nonzero"
assert t1.path == "host" and t2.path == "p2p", (t1.path, t2.path)
assert t2.checkpoint == 0.0, "p2p path must skip the host snapshot"
# devs[:4] was compiled at startup: the revisit must hit the mesh cache and
# skip the re-jit entirely (warm restart)
assert t3.restart < 0.5 * t2.restart, (t3.restart, t2.restart)
print("OK")
