"""Subprocess helper: the dry-run machinery on a small (2,4) mesh with reduced
configs — lower + compile + memory/cost/collective extraction end-to-end."""
import sys

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import smoke_config
from repro.launch.cells import make_cell
from repro.utils.hlo import collective_bytes
from repro.utils.roofline import (normalize_cost_analysis,
                                  roofline_from_analysis)

devs = jax.devices()
assert len(devs) == 8, len(devs)
mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))

for arch, shape in [("yi-6b", "train_4k"), ("granite-moe-3b-a800m", "train_4k"),
                    ("mamba2-1.3b", "decode_32k"),
                    ("jamba-v0.1-52b", "long_500k")]:
    cfg = smoke_config(arch)
    # shrink the shape to CPU scale by overriding via the SHAPES entry
    from repro.configs.base import ShapeConfig, SHAPES
    s = SHAPES[shape]
    small = ShapeConfig(s.name, 64 if s.kind != "train" else 32, 8, s.kind)
    import repro.launch.cells as cells
    orig = dict(cells.SHAPES)
    cells.SHAPES = dict(cells.SHAPES)
    cells.SHAPES[shape] = small
    try:
        cell = make_cell(arch, shape, mesh, cfg_override=cfg)
        lowered = cell.lower()
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = normalize_cost_analysis(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
        terms = roofline_from_analysis(ca, coll.get("total", 0),
                                       cell.model_flops, 8)
        assert ma.temp_size_in_bytes >= 0
        assert ca.get("flops", 0) > 0
        assert terms.bottleneck in ("compute", "memory", "collective")
        print(f"{arch}|{shape}: flops/dev={ca.get('flops', 0):.3g} "
              f"coll={coll.get('total', 0)} bottleneck={terms.bottleneck}")
    finally:
        cells.SHAPES = orig
print("OK")
