"""Subprocess helper: live controller end-to-end — priority shrink, expand on
completion, fault-tolerant restart from disk."""
import sys
import tempfile

import jax

from repro.checkpoint import DiskCheckpointStore
from repro.configs import smoke_config
from repro.core import (ElasticClusterController, ElasticTrainer, JobSpec,
                        JobStatus, PolicyConfig, TrainJobConfig)

devs = jax.devices()
assert len(devs) == 8
store = DiskCheckpointStore(tempfile.mkdtemp())


def factory(steps, seed):
    def f(devices):
        return ElasticTrainer(
            smoke_config("yi-6b"),
            TrainJobConfig(global_batch=8, seq_len=16, total_steps=steps,
                           seed=seed), devices)
    return f


# --- scenario 1: priority-driven shrink + expand-back -----------------------
op = ElasticClusterController(devs, slots=8,
                              policy=PolicyConfig(rescale_gap=0.0),
                              steps_per_tick=2)
op.submit(JobSpec("low", 1, 2, 8, 0.0, divides=8), factory(20, 0))
op.submit(JobSpec("high", 5, 4, 8, 0.001, divides=8), factory(8, 1))
m = op.run()
low = op.cluster.jobs["low"]
high = op.cluster.jobs["high"]
assert low.status == JobStatus.COMPLETED and high.status == JobStatus.COMPLETED
assert low.rescale_count >= 2, "low must shrink for high, then expand back"
shrinks = [(old, new) for _, jid, old, new, _ in op.rescale_events
           if jid == "low"]
assert shrinks[0][0] > shrinks[0][1], "first event is a shrink"
assert shrinks[-1][0] < shrinks[-1][1], "last event is an expand"
assert op.live["low"].trainer.step_idx == 20
assert op.live["high"].trainer.step_idx == 8
print("SCENARIO1 OK", m.row())

# --- scenario 2: node-failure -> restart from disk checkpoint ----------------
op2 = ElasticClusterController(devs, slots=8,
                               policy=PolicyConfig(rescale_gap=0.0),
                               disk_store=store, steps_per_tick=2)
op2.submit(JobSpec("victim", 3, 2, 4, 0.0, divides=8), factory(20, 5),
           checkpoint_every=4)
op2._process_submissions()
live = op2.live["victim"]
for _ in range(6):
    live.trainer.step()
live.trainer.save_disk(store, "victim")
op2.inject_failure("victim")
assert live.trainer is None, "process state must be lost on failure"
m2 = op2.run()
assert op2.cluster.jobs["victim"].status == JobStatus.COMPLETED
assert op2.live["victim"].failures == 1
assert op2.live["victim"].trainer.step_idx == 20
print("SCENARIO2 OK", m2.row())
print("OK")
