"""Incremental == offline for :class:`UtilizationLog` (fleet-scale refactor).

The simulator's bounded-memory fleet mode (``keep_series=False``) answers
``average()`` / ``average_fragmentation()`` from O(1) running accumulators
instead of re-integrating a retained step series.  These tests pin the
refactor's contract: on ANY interleaved record / record_capacity /
record_fragmentation sequence — including same-timestamp coalescing — the
accumulator result equals the offline ``_integrate`` result bit-for-bit
over the simulator's query window (t0 <= first record, t1 >= last record).

The hypothesis suite explores arbitrary interleavings; the seeded
stdlib-random sweep below it keeps the property exercised in environments
without hypothesis installed (this container's tier-1 run).
"""
import random

import pytest

from repro.core.metrics import UtilizationLog

#: (kind, dt, value) — dt=0 lands on the previous timestamp (coalescing)
KINDS = ("used", "cap", "frag")


def _apply(ops, *, total_slots=64):
    """Feed one op sequence to a series-keeping and a fleet-mode log."""
    offline = UtilizationLog(total_slots, keep_series=True)
    fleet = UtilizationLog(total_slots, keep_series=False)
    t = 0.0
    for kind, dt, value in ops:
        t += dt
        for log in (offline, fleet):
            if kind == "used":
                log.record(t, int(value))
            elif kind == "cap":
                log.record_capacity(t, int(value))
            else:
                log.record_fragmentation(t, min(1.0, value / 128.0))
    return offline, fleet, t


def _assert_equal(offline, fleet, t_last):
    # the simulator always queries [min submit, max completion], which
    # brackets every record — the window where the accumulator is exact
    for t0, t1 in ((0.0, t_last), (0.0, t_last + 7.5), (-3.0, t_last + 1.0)):
        assert offline.average(t0, t1) == fleet.average(t0, t1)
        assert offline.average_fragmentation(t0, t1) \
            == fleet.average_fragmentation(t0, t1)


# ---------------------------------------------------------------------------
# hypothesis suite (skipped without the dependency, like the other
# property-test modules)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    given = None

needs_hypothesis = pytest.mark.skipif(
    given is None,
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")


if given is not None:
    op_lists = st.lists(
        st.tuples(st.sampled_from(KINDS),
                  st.one_of(st.just(0.0),
                            st.floats(0.0, 500.0, allow_nan=False)),
                  st.floats(0.0, 128.0, allow_nan=False)),
        max_size=60)

    @needs_hypothesis
    @settings(max_examples=200, deadline=None)
    @given(ops=op_lists)
    def test_incremental_matches_offline_hypothesis(ops):
        _assert_equal(*_apply(ops))
else:
    @needs_hypothesis
    def test_incremental_matches_offline_hypothesis():
        raise AssertionError("unreachable: skipped without hypothesis")


# ---------------------------------------------------------------------------
# stdlib-random fallback: same property, seeded sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(40))
def test_incremental_matches_offline_random(seed):
    rng = random.Random(seed)
    ops = []
    for _ in range(rng.randrange(0, 80)):
        dt = 0.0 if rng.random() < 0.3 else rng.uniform(0.0, 500.0)
        ops.append((rng.choice(KINDS), dt, rng.uniform(0.0, 128.0)))
    _assert_equal(*_apply(ops))


def test_same_timestamp_coalescing_exact():
    """Several state changes at one instant: only the last value stands, and
    both modes agree (the zero-width segments contribute 0.0 area)."""
    ops = [("used", 0.0, 8), ("used", 0.0, 16), ("used", 0.0, 4),
           ("used", 10.0, 32), ("frag", 0.0, 64.0), ("frag", 0.0, 16.0),
           ("cap", 5.0, 48), ("cap", 0.0, 64), ("used", 0.0, 10)]
    offline, fleet, t = _apply(ops)
    _assert_equal(offline, fleet, t)
    # the retained series really did coalesce
    assert [u for _, u in offline.events] == [4, 32, 10]


def test_empty_log_agrees():
    _assert_equal(*_apply([]))
