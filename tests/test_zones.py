"""Multi-zone topology: region pricing, correlated zone reclaims (bystander
guarantees, batch cordoning, blast accounting), zone-spread placement, the
per-zone autoscaler spot share, and inter-region checkpoint-transfer billing.
"""
import math

import pytest

from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool, NodeState)
from repro.core.events import EventQueue
from repro.core.job import JobSpec, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.placement import PlacementMap
from repro.core.policies import PolicyConfig

PCFG = PolicyConfig(rescale_gap=0.0)


def wl(steps=100.0, data=1e9):
    from repro.core.simulator import SimWorkload
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


def _zone_pools(spot_a=2, spot_b=1, od=1):
    return [
        NodePool("od-a", slots_per_node=8, initial_nodes=od, max_nodes=od,
                 region="east", zone="east-1a"),
        NodePool("spot-a", slots_per_node=8, market=SPOT, initial_nodes=spot_a,
                 max_nodes=spot_a, spot_lifetime_mean=1e12,
                 region="east", zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, market=SPOT, initial_nodes=spot_b,
                 max_nodes=spot_b, spot_lifetime_mean=1e12,
                 region="east", zone="east-1b"),
    ]


# ---------------------------------------------------------------------------
# Provider topology
# ---------------------------------------------------------------------------

def test_region_price_multiplier_folds_into_pool_price():
    prov = CloudProvider([
        NodePool("e", price_per_slot_hour=0.048, region="east"),
        NodePool("w", price_per_slot_hour=0.048, region="west"),
    ], region_price_multipliers={"west": 1.5})
    assert prov.pools["e"].price_per_slot_hour == pytest.approx(0.048)
    assert prov.pools["w"].price_per_slot_hour == pytest.approx(0.072)


def test_spot_zones_and_zone_slots():
    prov = CloudProvider(_zone_pools())
    q = EventQueue()
    prov.bootstrap(q)
    assert prov.spot_zones() == ["east-1a", "east-1b"]
    assert prov.zone_slots("east-1a") == 24          # od + 2 spot nodes
    assert prov.zone_slots("east-1a", SPOT) == 16
    assert prov.zone_slots("east-1b", SPOT) == 8


def test_zone_reclaim_process_armed_per_spot_zone():
    prov = CloudProvider(_zone_pools(), seed=3, zone_reclaim_interval=600.0)
    q = EventQueue()
    prov.schedule_zone_reclaims(q)
    events = [q.pop() for _ in range(len(q))]
    assert sorted(ev.payload for ev in events) == ["east-1a", "east-1b"]
    assert all(ev.kind == "zone_reclaim" and ev.time > 0.0 for ev in events)


def test_on_zone_reclaim_rearms_and_picks_only_up_spot_in_zone():
    prov = CloudProvider(_zone_pools(), seed=3, zone_reclaim_interval=600.0,
                         zone_reclaim_fraction=1.0)
    q = EventQueue()
    prov.bootstrap(q)
    prov.schedule_zone_reclaims(q)
    # fire the armed stream's own east-1a event
    fire_at = prov._next_fire["east-1a"]
    victims = prov.on_zone_reclaim("east-1a", fire_at, q)
    spot_a = {n.node_id for n in prov.nodes.values()
              if n.pool.name == "spot-a"}
    assert set(victims) == spot_a                   # every UP spot node in a
    # re-armed: a NEW east-1a firing is pending beyond the one just handled
    assert prov._next_fire["east-1a"] > fire_at
    pending = [q.pop() for _ in range(len(q))]
    assert sum(1 for ev in pending
               if ev.kind == "zone_reclaim" and ev.payload == "east-1a") == 2
    # (2 = the original armed event still queued in this synthetic drive +
    # its replacement; the simulator pops the former as it fires)


def test_injected_reclaim_on_unarmed_zone_stays_one_shot():
    """inject_zone_reclaim promises a deterministic ONE-SHOT: on a zone the
    Poisson stream never armed, the event must not self-arm a perpetual
    stream."""
    prov = CloudProvider(_zone_pools(), seed=3, zone_reclaim_interval=600.0,
                         zone_reclaim_fraction=1.0)
    q = EventQueue()
    prov.bootstrap(q)                         # stream NOT scheduled
    prov.inject_zone_reclaim("east-1a", 10.0, q)
    ev = q.pop()
    assert (ev.kind, ev.payload) == ("zone_reclaim", "east-1a")
    prov.on_zone_reclaim("east-1a", 10.0, q)
    assert not any(e.kind == "zone_reclaim" for e in q._heap)


def test_zone_reclaim_fraction_rounds_up():
    prov = CloudProvider(_zone_pools(spot_a=3), seed=0,
                         zone_reclaim_fraction=0.5)
    q = EventQueue()
    prov.bootstrap(q)
    victims = prov.on_zone_reclaim("east-1a", 10.0, q)
    assert len(victims) == math.ceil(0.5 * 3) == 2


# ---------------------------------------------------------------------------
# CloudSimulator zone_reclaim event
# ---------------------------------------------------------------------------

def test_zone_reclaim_kills_zone_spot_only_bystanders_untouched():
    prov = CloudProvider(_zone_pools(), seed=1, zone_reclaim_fraction=1.0)
    sim = CloudSimulator(prov, PCFG)
    sim.submit(JobSpec("a", 1, 4, 4, 0.0), wl(200))
    prov.inject_zone_reclaim("east-1a", 30.0, sim.queue)
    sim.run()
    by_pool = {}
    for n in prov.nodes.values():
        by_pool.setdefault(n.pool.name, []).append(n.state)
    assert all(s is NodeState.DOWN for s in by_pool["spot-a"])
    assert all(s is NodeState.UP for s in by_pool["od-a"])     # on-demand
    assert all(s is NodeState.UP for s in by_pool["spot-b"])   # other zone
    assert sim.zone_reclaims == 1
    assert sim.cost_report.spot_preemptions == 2               # both nodes
    assert sim.cluster.jobs["a"].status is JobStatus.COMPLETED


def test_zone_reclaim_event_blast_is_union_of_batch():
    """The event-level record captures every slot the burst displaced, even
    when a mid-batch preemption evicts a job off LATER dying nodes (whose
    per-node rows then under-count it)."""
    prov = CloudProvider([
        NodePool("spot-a", slots_per_node=8, market=SPOT, initial_nodes=2,
                 max_nodes=2, spot_lifetime_mean=1e12, zone="east-1a"),
    ], seed=1, zone_reclaim_fraction=1.0)
    sim = CloudSimulator(prov, PCFG)
    # rigid 16-slot job spans both zone nodes; the whole zone dies at once
    sim.submit(JobSpec("a", 1, 16, 16, 0.0), wl(200))
    prov.inject_zone_reclaim("east-1a", 30.0, sim.queue)
    sim.run()
    assert len(sim.zone_blasts) == 1
    blast = sim.zone_blasts[0]
    assert (blast.jobs, blast.slots, blast.zone) == (1, 16, "east-1a")
    assert blast.preempts == 1                  # nowhere to go: checkpointed
    # per-node rows: the first kill preempts the job (evicting it from the
    # second node too), so their slot sum is the first node's 8, not 16 —
    # exactly the under-count the event-level record exists to fix
    assert sum(k.slots for k in sim.kill_blasts) == 8


def test_zone_reclaim_batch_never_migrates_onto_dying_node():
    """A worker displaced off one dying node must not land on another node
    of the same burst (it would be displaced twice and pay twice)."""
    prov = CloudProvider([
        NodePool("spot-a", slots_per_node=8, market=SPOT, initial_nodes=2,
                 max_nodes=2, spot_lifetime_mean=1e12, zone="east-1a"),
        NodePool("od-a", slots_per_node=8, initial_nodes=1, max_nodes=1,
                 zone="east-1a"),
    ], seed=1, zone_reclaim_fraction=1.0)
    sim = CloudSimulator(prov, PCFG)
    sim.submit(JobSpec("a", 1, 8, 8, 0.0), wl(200))   # fits one spot node
    prov.inject_zone_reclaim("east-1a", 30.0, sim.queue)
    sim.run()
    a = sim.cluster.jobs["a"]
    # migrated ONCE onto the surviving on-demand node, never preempted
    assert sim.migrations == 1
    assert a.preempt_count == 0
    assert a.status is JobStatus.COMPLETED


def test_zone_reclaim_on_empty_zone_is_harmless():
    prov = CloudProvider(_zone_pools(spot_a=0, spot_b=1), seed=1,
                         zone_reclaim_interval=1e9, zone_reclaim_fraction=1.0)
    sim = CloudSimulator(prov, PCFG)
    sim.submit(JobSpec("a", 1, 4, 4, 0.0), wl(50))
    prov.inject_zone_reclaim("east-1a", 10.0, sim.queue)
    m = sim.run()
    assert sim.zone_reclaims == 0            # no victims: not counted
    assert sim.zone_blasts == []
    assert m.dropped_jobs == 0


def test_injected_reclaim_does_not_double_arm_the_stream():
    """An injected deterministic burst on a provider whose Poisson stream is
    armed must not spawn a SECOND stream (which would silently double the
    zone's reclaim rate for the rest of the run)."""
    prov = CloudProvider([
        NodePool("spot-a", slots_per_node=8, market=SPOT, initial_nodes=1,
                 max_nodes=1, spot_lifetime_mean=1e12, zone="east-1a"),
    ], seed=3, zone_reclaim_interval=600.0, zone_reclaim_fraction=1.0)
    q = EventQueue()
    prov.bootstrap(q)
    prov.schedule_zone_reclaims(q)           # arms ONE stream event
    prov.inject_zone_reclaim("east-1a", 1.0, q)
    for _ in range(6):
        ev = q.pop()
        while ev.kind != "zone_reclaim":     # skip the node's far spot fate
            ev = q.pop()
        prov.on_zone_reclaim(ev.payload, ev.time, q)
    # after any number of firings exactly one stream event is pending: the
    # injected burst never re-armed (two live streams would leave two)
    pending = sum(1 for e in q._heap if e.kind == "zone_reclaim")
    assert pending == 1


# ---------------------------------------------------------------------------
# zone_spread placement
# ---------------------------------------------------------------------------

def test_zone_spread_balances_job_across_zones():
    p = PlacementMap("zone_spread")
    for z in ("a", "b", "c"):
        for i in range(2):
            p.add_node(f"{z}{i}", 8, zone=z)
    p.place("j", 7)
    zones = p.job_zones("j")
    assert max(zones.values()) <= math.ceil(7 / 3)
    # packs within the chosen zone: one node per zone carries the slots
    assert len(p.job_nodes("j")) == 3


def test_zone_spread_evict_drains_fattest_zone_first():
    p = PlacementMap("zone_spread")
    for z in ("a", "b"):
        p.add_node(f"{z}0", 8, zone=z)
    p.place("j", 4)                      # 2 + 2
    p.add_node("c0", 8, zone="c")
    p.place("j", 2)                      # rebalance: c gets the new pair
    assert p.job_zones("j") == {"a": 2, "b": 2, "c": 2}
    p.evict("j", 2)
    # shed one slot from each of two zones — never a whole zone wholesale
    assert sorted(p.job_zones("j").values()) == [1, 1, 2]


def test_zone_spread_evict_interleaves_zones():
    """A multi-slot evict re-ranks per slot: half the footprint leaves HALF
    of each zone, instead of wiping the fattest zone and re-concentrating
    the survivors into one blast domain."""
    p = PlacementMap("zone_spread")
    p.add_node("a0", 8, zone="a")
    p.add_node("b0", 8, zone="b")
    p.place("j", 6)                      # 3 + 3
    p.evict("j", 3)
    assert sorted(p.job_zones("j").values()) == [1, 2]


def test_zoneless_nodes_get_private_zones():
    from repro.core.cluster import Cluster
    c = Cluster(8, slots_per_node=4, placement="zone_spread")
    assert c.zone_of("base00") == "base00"
    c.place("j", 4)
    # degenerates to a per-node spread, not one shared blast domain
    assert c.job_zones("j") == {"base00": 2, "base01": 2}


# ---------------------------------------------------------------------------
# Autoscaler: per-zone spot share
# ---------------------------------------------------------------------------

def _diversify_sim(spot_fraction):
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=60.0, initial_nodes=1, max_nodes=8,
                 zone="east-1a"),
        # zone-b spot is CHEAPER: a global share check would fill it alone
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, boot_latency=60.0, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=60.0, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1c"),
    ], seed=7)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0,
        spot_fraction=spot_fraction))
    sim = CloudSimulator(prov, PCFG, autoscaler=asc)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, 0.0), wl(120))
    return prov, sim


def test_spot_provisioning_diversifies_across_zones():
    prov, sim = _diversify_sim(spot_fraction=0.5)
    sim.run()
    # quota 0.25/zone: both spot zones got capacity instead of the cheapest
    # zone absorbing the whole spot share
    assert prov.pool_census("spot-b") >= 1
    assert prov.pool_census("spot-c") >= 1


def test_full_zone_does_not_strand_its_spot_quota():
    """When one spot zone's pools sit at max_nodes, its slice of the spot
    share redistributes to zones that can still grow — instead of capping
    them at spot_fraction/n_zones and silently buying on-demand."""
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, initial_nodes=4, max_nodes=8,
                 zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, initial_nodes=1, max_nodes=1,
                 spot_lifetime_mean=1e12, zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, initial_nodes=2, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1c"),
    ], seed=0)
    q = EventQueue()
    prov.bootstrap(q)
    asc = NodeAutoscaler(prov, AutoscalerConfig(spot_fraction=0.5))
    # global spot share 24/56 < 0.5; zone-c share 16/56 = 0.29 exceeds the
    # naive per-zone quota 0.25 but zone-b is frozen at max_nodes, so c
    # inherits the headroom and stays the first choice
    assert asc._pool_preference(0.0)[0].name == "spot-c"


def test_spot_fraction_zero_still_means_no_spot():
    prov, sim = _diversify_sim(spot_fraction=0.0)
    sim.run()
    assert prov.pool_census("spot-b") == 0
    assert prov.pool_census("spot-c") == 0


# ---------------------------------------------------------------------------
# Inter-region transfer billing
# ---------------------------------------------------------------------------

def _cross_region_sim(west_region="west"):
    prov = CloudProvider([
        NodePool("spot-east", slots_per_node=8, market=SPOT, boot_latency=0.0,
                 initial_nodes=1, max_nodes=1, spot_lifetime_mean=1e12,
                 region="east", zone="east-1a"),
        NodePool("od-west", slots_per_node=8, boot_latency=60.0,
                 initial_nodes=0, max_nodes=1,
                 region=west_region, zone=f"{west_region}-2a"),
    ], seed=1, transfer_price_per_gb=0.02)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0))
    sim = CloudSimulator(prov, PCFG, autoscaler=asc)
    # rigid 8-slot job on the east spot node; data 4 GB
    sim.submit(JobSpec("a", 1, 8, 8, 0.0), wl(100, data=4e9))
    prov.inject_spot_kill(sorted(prov.nodes)[0], 30.0, sim.queue)
    return prov, sim


def test_cross_region_resume_bills_checkpoint_transfer():
    prov, sim = _cross_region_sim()
    m = sim.run()
    a = sim.cluster.jobs["a"]
    assert a.preempt_count == 1 and a.status is JobStatus.COMPLETED
    # 4 GB x $0.02/GB crossing east -> west
    assert m.transfer_cost == pytest.approx(4.0 * 0.02)
    r = sim.cost_report
    assert r.transfer_cost == pytest.approx(0.08)
    assert r.transfer_costs["a"] == pytest.approx(0.08)
    # itemized ON TOP of capacity dollars, preserving idle = capacity - used
    assert r.total_cost == pytest.approx(
        r.idle_cost + r.used_cost + r.transfer_cost, abs=1e-9)


def test_same_region_resume_is_free():
    prov, sim = _cross_region_sim(west_region="east")
    m = sim.run()
    assert sim.cluster.jobs["a"].preempt_count == 1
    assert m.transfer_cost == 0.0
