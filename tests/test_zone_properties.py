"""Hypothesis property tests for correlated zone reclaims:

- a ``zone_reclaim`` kills only THAT zone's UP spot nodes — on-demand nodes
  and other zones are bystanders at the node level, and running jobs with no
  slots on the dying nodes are bystanders at the job level;
- the event-level displaced-slot accounting (``zone_blasts``) equals the
  union of the victim nodes' resident maps at event time;
- ``zone_spread`` placement never co-locates more than ceil(slots/zones)
  slots of one job in a single zone (zones with capacity).
"""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.cloud import (SPOT, CloudProvider, CloudSimulator, NodePool,
                         NodeState)
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.placement import PlacementMap
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload


def _wl(steps, t_step=1.0):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t_step), (64.0, t_step))),
        total_work=steps, data_bytes=1e6, rescale=RescaleModel())


# ---------------------------------------------------------------------------
# zone_spread co-location bound
# ---------------------------------------------------------------------------

@st.composite
def zone_layouts(draw):
    n_zones = draw(st.integers(2, 4))
    nodes_per_zone = draw(st.integers(1, 3))
    slots_per_node = draw(st.integers(2, 8))
    n = draw(st.integers(1, n_zones * nodes_per_zone * slots_per_node))
    return n_zones, nodes_per_zone, slots_per_node, n


@settings(max_examples=80, deadline=None)
@given(zone_layouts())
def test_zone_spread_never_exceeds_ceil_share(layout):
    n_zones, nodes_per_zone, slots_per_node, n = layout
    p = PlacementMap("zone_spread")
    for z in range(n_zones):
        for i in range(nodes_per_zone):
            p.add_node(f"z{z}n{i}", slots_per_node, zone=f"z{z}")
    p.place("job", n)
    zones = p.job_zones("job")
    # zones differ in REMAINING capacity only once some fill up; with equal
    # capacity everywhere the bound is the fresh-placement ceil share, until
    # a zone's capacity itself becomes the binding constraint
    cap = nodes_per_zone * slots_per_node
    bound = max(math.ceil(n / n_zones), n - (n_zones - 1) * cap)
    assert max(zones.values()) <= bound
    assert sum(zones.values()) == n
    p.check()


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 4), st.lists(st.integers(1, 6), min_size=2, max_size=6))
def test_zone_spread_sequential_placements_stay_balanced(n_zones, sizes):
    """Growing a job slot-by-slot (the elastic expand path) obeys the same
    bound as one fresh placement while every zone still has room."""
    p = PlacementMap("zone_spread")
    for z in range(n_zones):
        p.add_node(f"z{z}", 64, zone=f"z{z}")     # capacity never binds
    total = 0
    for s in sizes:
        p.place("job", s)
        total += s
        assert max(p.job_zones("job").values()) <= math.ceil(total / n_zones)


# ---------------------------------------------------------------------------
# zone reclaims: bystanders + accounting, under random fleets and streams
# ---------------------------------------------------------------------------

@st.composite
def reclaim_scenarios(draw):
    zones = [f"z{i}" for i in range(draw(st.integers(2, 3)))]
    pools = []
    for zi, z in enumerate(zones):
        pools.append(dict(zone=z, market=SPOT,
                          nodes=draw(st.integers(1, 2))))
    pools.append(dict(zone=zones[0], market="on_demand",
                      nodes=draw(st.integers(1, 2))))
    jobs = []
    for i in range(draw(st.integers(1, 6))):
        mn = draw(st.integers(1, 6))
        jobs.append(dict(job_id=f"j{i}", priority=draw(st.integers(1, 5)),
                         min_replicas=mn,
                         max_replicas=draw(st.integers(mn, 12)),
                         submit_time=float(draw(st.integers(0, 100))),
                         work=float(draw(st.integers(5, 80)))))
    target = draw(st.sampled_from(zones))
    kill_at = float(draw(st.integers(5, 150)))
    fraction = draw(st.sampled_from([0.34, 0.5, 1.0]))
    strategy = draw(st.sampled_from(["pack", "spread", "zone_spread"]))
    return pools, jobs, target, kill_at, fraction, strategy


@settings(max_examples=40, deadline=None)
@given(reclaim_scenarios())
def test_zone_reclaim_bystanders_and_displacement_accounting(scn):
    pools, jobs, target, kill_at, fraction, strategy = scn
    np_pools = [
        NodePool(f"p{i}", slots_per_node=8, market=p["market"],
                 initial_nodes=p["nodes"], max_nodes=p["nodes"],
                 spot_lifetime_mean=1e12, zone=p["zone"])
        for i, p in enumerate(pools)]
    prov = CloudProvider(np_pools, seed=11, zone_reclaim_fraction=fraction)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0),
                         placement=strategy)
    for j in jobs:
        sim.submit(JobSpec(j["job_id"], j["priority"], j["min_replicas"],
                           j["max_replicas"], j["submit_time"]),
                   _wl(j["work"]))
    prov.inject_zone_reclaim(target, kill_at, sim.queue)

    probe = {}
    orig = sim._on_zone_reclaim

    def probed(zone):
        up_before = {n.node_id: n.state for n in prov.nodes.values()}
        snapshot = {
            nid: dict(sim.cluster.residents(nid))
            for nid in sim.cluster.nodes()
            if prov.nodes[nid].pool.zone == zone
            and prov.nodes[nid].pool.market == SPOT
            and prov.nodes[nid].state is NodeState.UP}
        bystanders = {j.job_id: (j.replicas, j.preempt_count)
                      for j in sim.cluster.running_jobs()
                      if not any(j.job_id in res for res in snapshot.values())}
        orig(zone)
        probe["snapshot"] = snapshot
        # node-level: every node whose state CHANGED was an UP spot node of
        # the target zone
        for nid, st_before in up_before.items():
            node = prov.nodes[nid]
            if node.state is not st_before:
                assert node.pool.zone == zone
                assert node.pool.market == SPOT
                assert st_before is NodeState.UP
        # job-level: running jobs with no slots on any dying node were never
        # shrunk or preempted by the event (expansion is legitimate: the
        # final redistribution pass hands freed capacity around)
        for jid, (reps, pre) in bystanders.items():
            j = sim.cluster.jobs[jid]
            assert j.replicas >= reps, f"bystander {jid} shrunk"
            assert j.preempt_count == pre, f"bystander {jid} preempted"
    sim._on_zone_reclaim = probed
    sim.run()

    snapshot = probe.get("snapshot")
    if snapshot is None:
        return                              # reclaim fired after _all_done
    # fraction < 1 spares some snapshot nodes: the event's accounting covers
    # exactly the nodes the reclaim actually took DOWN
    snapshot = {nid: res for nid, res in snapshot.items()
                if prov.nodes[nid].state is NodeState.DOWN}
    displaced = {}
    for res in snapshot.values():
        for jid, cnt in res.items():
            displaced[jid] = displaced.get(jid, 0) + cnt
    if not any(displaced.values()):
        # the burst hit only empty nodes: a zero-casualty record is fine
        assert all(b.jobs == 0 for b in sim.zone_blasts)
        return
    assert len(sim.zone_blasts) == 1
    blast = sim.zone_blasts[0]
    # the event's displaced-slot accounting equals the union of the victim
    # nodes' resident maps at event time...
    n_victims = len([nid for nid in snapshot
                     if prov.nodes[nid].state is NodeState.DOWN])
    assert blast.jobs == len(displaced)
    assert blast.slots == sum(displaced.values())
    assert blast.zone == target
    # ...and per-node rows never exceed it (mid-batch preemptions can make
    # them under-count, never over-count)
    assert sum(k.slots for k in sim.kill_blasts) <= blast.slots
    assert len(sim.kill_blasts) == n_victims
