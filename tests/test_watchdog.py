"""Self-profiler (repro.obs.profile) and perf watchdog (repro.obs.watchdog):
the profiler's accumulators/report/merge and its simulator wiring, and the
watchdog's baseline diff — including the acceptance-bar case that an
injected 20% events/sec regression trips the default 15% tolerance — plus
the rolling-median anomaly scan and the CLI exit codes.
"""
import json

import pytest

from repro.core.simulator import make_jacobi_jobs, run_variant
from repro.obs.profile import SimProfiler, current_profiler, install_profiler
from repro.obs.watchdog import (WatchdogConfig, diff_snapshots, main,
                                rolling_median_spikes, scan_trace)

# ---------------------------------------------------------------------------
# SimProfiler
# ---------------------------------------------------------------------------


def test_profiler_accumulates_and_reports():
    p = SimProfiler()
    p.event("complete", 0.002)
    p.event("complete", 0.004)
    p.event("submit", 0.001)
    p.section("heap_push", 0.0005)
    with p.timed("metrics_tick"):
        pass
    p.wall_s = 0.010
    rep = p.report()
    assert rep["events"]["complete"] == {
        "count": 2, "total_s": 0.006, "mean_us": 3000.0}
    assert list(rep["events"]) == ["complete", "submit"]   # sorted by total
    assert rep["events_total"] == 3
    assert rep["handler_s"] == pytest.approx(0.007)
    assert set(rep["sections"]) == {"heap_push", "metrics_tick"}
    assert rep["unattributed_s"] <= rep["wall_s"]


def test_profiler_merge():
    a, b = SimProfiler(), SimProfiler()
    a.event("submit", 0.001)
    b.event("submit", 0.003)
    b.event("complete", 0.002)
    b.section("heap_pop", 0.0001)
    a.wall_s, b.wall_s = 0.5, 1.5
    a.merge(b)
    rep = a.report()
    assert rep["events"]["submit"]["count"] == 2
    assert rep["events"]["submit"]["total_s"] == pytest.approx(0.004)
    assert rep["events"]["complete"]["count"] == 1
    assert rep["wall_s"] == pytest.approx(2.0)


def test_install_profiler_scopes_and_simulator_adopts_it():
    assert current_profiler() is None
    specs = make_jacobi_jobs(seed=3, n_jobs=4, submission_gap=60.0)
    prof = SimProfiler()
    with install_profiler(prof):
        assert current_profiler() is prof
        m = run_variant("elastic", specs, total_slots=32)
    assert current_profiler() is None
    rep = prof.report()
    # every dispatched event was timed by kind (rescales re-schedule
    # completion events, so "complete" dispatches can exceed the job count)
    assert rep["events_total"] == m.counters["events"]
    assert rep["events"]["complete"]["count"] >= 4
    assert {"heap_push", "heap_pop", "metrics_tick"} <= set(rep["sections"])
    # unprofiled runs stay silent
    run_variant("elastic", specs, total_slots=32)
    assert prof.report()["events_total"] == rep["events_total"]


# ---------------------------------------------------------------------------
# watchdog: baseline diff
# ---------------------------------------------------------------------------


def snapshot(events_per_sec=100_000.0, *, null_pct=1.0, active_pct=20.0,
             rss=100_000_000):
    return {
        "bench": "simcore", "schema": 2,
        "throughput": [
            {"n_jobs": n, "wall_s": 0.01, "events": 1000,
             "events_per_sec": events_per_sec, "completions": n}
            for n in (16, 32, 64, 128)],
        "tracing": {"composed_null_overhead_pct": null_pct,
                    "active_overhead_pct": active_pct},
        "profile": {"events": {}, "sections": {}},
        "peak_rss_bytes": rss,
    }


def test_identical_snapshots_pass():
    rep = diff_snapshots(snapshot(), snapshot())
    assert rep.ok, rep.summary()
    assert {"schema", "null_overhead", "active_overhead", "throughput",
            "peak_rss"} <= set(rep.checks)


def test_injected_20pct_throughput_regression_trips_the_watchdog():
    fresh = snapshot(events_per_sec=80_000.0)     # 20% below baseline
    rep = diff_snapshots(fresh, snapshot(events_per_sec=100_000.0))
    assert not rep.ok
    assert len(rep.checks["throughput"]) == 4     # every rung regressed
    assert "20.0% below baseline" in rep.checks["throughput"][0]
    # a 10% dip stays inside the default 15% tolerance
    assert diff_snapshots(snapshot(events_per_sec=90_000.0),
                          snapshot(events_per_sec=100_000.0)).ok


def test_blocking_only_skips_machine_dependent_diffs():
    fresh = snapshot(events_per_sec=10_000.0, rss=10**12)  # way off baseline
    rep = diff_snapshots(fresh, snapshot(), blocking_only=True)
    assert rep.ok
    assert "throughput" not in rep.checks and "peak_rss" not in rep.checks
    assert any("blocking-only" in n for n in rep.notes)


def test_invariant_violations_always_block():
    rep = diff_snapshots(snapshot(null_pct=3.5), snapshot(),
                         blocking_only=True)
    assert rep.checks["null_overhead"]
    rep = diff_snapshots(snapshot(active_pct=91.0), snapshot(),
                         blocking_only=True)
    assert rep.checks["active_overhead"]
    broken = snapshot()
    del broken["profile"]
    assert diff_snapshots(broken, snapshot(),
                          blocking_only=True).checks["schema"]


def test_rss_growth_and_missing_rung_flagged():
    rep = diff_snapshots(snapshot(rss=140_000_000), snapshot(rss=100_000_000))
    assert rep.checks["peak_rss"]
    fresh = snapshot()
    fresh["throughput"] = fresh["throughput"][:-1]
    rep = diff_snapshots(fresh, snapshot())
    assert any("n_jobs=128 missing" in v for v in rep.checks["throughput"])


def fleet_snapshot(retired_per_sec=9_000.0, *, full_row=True, **kw):
    snap = snapshot(**kw)
    snap["schema"] = 3
    snap["fleet"] = [{"name": "smoke", "n_jobs": 20_000, "wall_s": 5.0,
                      "events_retired_per_sec": retired_per_sec}]
    if full_row:
        snap["fleet"].append({"name": "full", "n_jobs": 1_000_000,
                              "wall_s": 700.0,
                              "events_retired_per_sec": 3_500.0})
    return snap


def test_fleet_row_regression_trips_the_watchdog():
    # 30% below baseline: outside the 25% fleet tolerance
    rep = diff_snapshots(fleet_snapshot(6_300.0), fleet_snapshot(9_000.0))
    assert rep.checks["fleet"] and "smoke" in rep.checks["fleet"][0]
    # 20% below: inside tolerance (fleet rows run once — noisier)
    assert diff_snapshots(fleet_snapshot(7_200.0), fleet_snapshot(9_000.0)).ok


def test_missing_full_fleet_row_is_a_note_not_a_failure():
    fresh = fleet_snapshot(full_row=False)     # everyday run: smoke only
    rep = diff_snapshots(fresh, fleet_snapshot())
    assert rep.ok, rep.summary()
    assert any("full" in n and "diff skipped" in n for n in rep.notes)


def test_schema3_without_fleet_rows_blocks():
    broken = fleet_snapshot()
    broken["fleet"] = []
    rep = diff_snapshots(broken, fleet_snapshot(), blocking_only=True)
    assert rep.checks["schema"]


def test_committed_baseline_passes_its_own_blocking_checks():
    with open("benchmarks/baselines/BENCH_simcore.baseline.json") as fh:
        base = json.load(fh)
    rep = diff_snapshots(base, base)
    assert rep.ok, rep.summary()


# ---------------------------------------------------------------------------
# anomaly scan
# ---------------------------------------------------------------------------


def test_rolling_median_spikes():
    values = [100.0] * 12 + [400.0] + [100.0] * 5
    assert rolling_median_spikes(values, window=9, factor=3.0) == [12]
    # a spike inside the warm-up window is never flagged
    assert rolling_median_spikes([900.0] + [100.0] * 10,
                                 window=9, factor=3.0) == []
    assert rolling_median_spikes([], window=9) == []


def test_scan_trace_flags_response_spike():
    records = []
    for i in range(14):
        records.append({"kind": "job_submit", "t": float(i), "job": f"j{i}"})
        took = 1000.0 if i == 12 else 100.0
        records.append({"kind": "job_complete", "t": i + took,
                        "job": f"j{i}"})
    records.sort(key=lambda r: r["t"])
    anomalies = scan_trace(records)
    assert len(anomalies) == 1 and "j12" in anomalies[0]
    assert scan_trace([r for r in records if r["job"] != "j12"]) == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_exit_codes_and_report_artifact(tmp_path):
    fresh, base = tmp_path / "fresh.json", tmp_path / "base.json"
    out = tmp_path / "diff.json"
    base.write_text(json.dumps(snapshot()))

    fresh.write_text(json.dumps(snapshot()))
    assert main(["--fresh", str(fresh), "--baseline", str(base),
                 "--out", str(out)]) == 0
    assert json.loads(out.read_text())["ok"] is True

    fresh.write_text(json.dumps(snapshot(events_per_sec=80_000.0)))
    assert main(["--fresh", str(fresh), "--baseline", str(base),
                 "--out", str(out)]) == 1
    report = json.loads(out.read_text())
    assert report["ok"] is False and report["checks"]["throughput"]
    # the same regression passes --blocking-only (machine-dependent)
    assert main(["--fresh", str(fresh), "--blocking-only"]) == 0
    # a widened tolerance also lets it through
    assert main(["--fresh", str(fresh), "--baseline", str(base),
                 "--throughput-tol", "0.5"]) == 0
