"""Hypothesis property tests over random job streams — the scheduler's
invariants must hold for ANY workload, policy variant, and gap."""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.job import JobSpec, JobStatus
from repro.core.metrics import UtilizationLog
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload


@st.composite
def job_streams(draw):
    n = draw(st.integers(2, 12))
    total_slots = draw(st.sampled_from([8, 16, 64]))
    jobs = []
    for i in range(n):
        mn = draw(st.integers(1, max(1, total_slots // 2)))
        mx = draw(st.integers(mn, total_slots))
        jobs.append(dict(
            job_id=f"j{i:02d}",
            priority=draw(st.integers(1, 5)),
            min_replicas=mn,
            max_replicas=mx,
            submit_time=float(draw(st.integers(0, 500))),
            work=float(draw(st.integers(1, 200))),
            t_step=draw(st.floats(0.1, 5.0)),
        ))
    gap = draw(st.sampled_from([0.0, 30.0, 180.0, math.inf]))
    return total_slots, gap, jobs


class _AuditedSim(Simulator):
    """Simulator that checks invariants after every event."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.max_used = 0

    def _record_util(self):
        super()._record_util()
        used = self.cluster.used_slots
        assert used <= self.cluster.total_slots, "capacity exceeded"
        self.max_used = max(self.max_used, used)
        for j in self.cluster.jobs.values():
            if j.status == JobStatus.RUNNING:
                assert j.spec.min_replicas <= j.replicas <= j.spec.max_replicas
            else:
                assert j.replicas == 0


@settings(max_examples=60, deadline=None)
@given(job_streams())
def test_invariants_hold_for_any_stream(stream):
    total_slots, gap, jobs = stream
    sim = _AuditedSim(total_slots, PolicyConfig(rescale_gap=gap))
    for j in jobs:
        sim.submit(
            JobSpec(j["job_id"], j["priority"], j["min_replicas"],
                    j["max_replicas"], j["submit_time"]),
            SimWorkload(
                scaling=PiecewiseScalingModel(
                    ((1.0, j["t_step"]), (float(total_slots), j["t_step"]))),
                total_work=j["work"], data_bytes=1e6,
                rescale=RescaleModel()))
    m = sim.run()
    # with redistribute_idle (default) every feasible job completes
    assert m.dropped_jobs == 0
    # completed jobs have consistent timestamps
    for j in sim.cluster.jobs.values():
        assert j.status == JobStatus.COMPLETED
        assert j.spec.submit_time <= j.start_time <= j.end_time
    assert 0.0 <= m.utilization <= 1.0


@settings(max_examples=40, deadline=None)
@given(job_streams())
def test_rescale_gap_respected(stream):
    """No two scheduling actions on one RUNNING job within T_rescale_gap."""
    total_slots, _, jobs = stream
    gap = 50.0

    actions_log = {}

    class _GapSim(Simulator):
        class _Act:
            pass

    sim = Simulator(total_slots, PolicyConfig(rescale_gap=gap))
    orig_rescale = sim.actions._rescale

    def audited_rescale(job, replicas):
        prev = actions_log.get(job.job_id)
        if prev is not None and job.replicas != replicas:
            assert sim.now - prev >= gap - 1e-9, \
                f"{job.job_id} rescaled {sim.now - prev:.1f}s after last action"
        ok = orig_rescale(job, replicas)
        if ok:
            actions_log[job.job_id] = sim.now
        return ok

    sim.actions._rescale = audited_rescale
    for j in jobs:
        sim.submit(
            JobSpec(j["job_id"], j["priority"], j["min_replicas"],
                    j["max_replicas"], j["submit_time"]),
            SimWorkload(
                scaling=PiecewiseScalingModel(
                    ((1.0, j["t_step"]), (float(total_slots), j["t_step"]))),
                total_work=j["work"], data_bytes=1e6,
                rescale=RescaleModel()))
        actions_log[j["job_id"]] = None
    actions_log = {}
    sim.run()


@settings(max_examples=30, deadline=None)
@given(job_streams(), st.integers(2, 8))
def test_feasibility_constraint_divides(stream, divisor_base):
    """With spec.divides set, running replica counts always divide it."""
    total_slots, gap, jobs = stream
    divides = divisor_base * 12  # rich divisor structure
    sim = Simulator(total_slots, PolicyConfig(rescale_gap=gap))
    checked = []

    for j in jobs:
        cap = max(1, min(j["max_replicas"], divides))
        mx = max(r for r in range(1, cap + 1) if divides % r == 0)
        spec = JobSpec(j["job_id"], j["priority"], 1, mx, j["submit_time"],
                       divides=divides)
        checked.append(spec.job_id)
        sim.submit(spec, SimWorkload(
            scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
            total_work=j["work"], data_bytes=0.0, rescale=RescaleModel()))

    orig = sim.actions.create

    def audited_create(job, replicas):
        if job.spec.divides:
            assert divides % replicas == 0, (job.job_id, replicas)
        return orig(job, replicas)

    sim.actions.create = audited_create
    m = sim.run()
    assert m.dropped_jobs == 0


def test_utilization_log_integration():
    u = UtilizationLog(10)
    u.record(0.0, 5)
    u.record(10.0, 10)
    u.record(20.0, 0)
    assert u.average(0.0, 20.0) == (5 * 10 + 10 * 10) / (10 * 20)
    assert u.average(10.0, 20.0) == 1.0
    assert u.average(0.0, 10.0) == 0.5
