"""Property tests for the text Gantt renderer: hypothesis drives random job
lifecycles and chart widths against the reference state machine in
tests/test_timeline.py — every rendered bar ('#'/'.') must map to a real
running/queued span of that job, and every marker to a real event.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")

from hypothesis import given, settings, strategies as st  # noqa: E402

try:                                    # pytest rootdir-style import
    from test_timeline import check_bars_map_to_spans  # noqa: E402
except ImportError:                     # invoked from the repo root
    from tests.test_timeline import check_bars_map_to_spans  # noqa: E402

GAPS = st.floats(min_value=0.0, max_value=60.0,
                 allow_nan=False, allow_infinity=False)
DURATIONS = st.floats(min_value=1e-3, max_value=120.0,
                      allow_nan=False, allow_infinity=False)

# one job lifecycle = submit gap, queue wait, then either nothing more
# (never started) or run / preempt+outage+resume / complete durations
JOB = st.tuples(GAPS, GAPS,
                st.none() | st.tuples(DURATIONS,
                                      st.none() | st.tuples(DURATIONS,
                                                            DURATIONS)))


def _records(jobs):
    records = [{"kind": "run_start", "t": 0.0, "run": 1, "slots": 16}]
    flat = []
    for i, (submit_gap, wait, rest) in enumerate(jobs):
        job, t = f"j{i}", submit_gap
        evs = [{"kind": "job_submit", "t": t, "job": job}]
        if rest is not None:
            run_s, preempt = rest
            t += wait
            evs.append({"kind": "job_start", "t": t, "job": job, "slots": 4})
            if preempt is not None:
                run_before, outage = preempt
                t += run_before
                evs.append({"kind": "job_preempt", "t": t, "job": job,
                            "slots": 4, "ckpt_s": 0.5})
                t += outage
                evs.append({"kind": "job_start", "t": t, "job": job,
                            "slots": 4, "resume": True, "overhead_s": 1.0})
            t += run_s
            evs.append({"kind": "job_complete", "t": t, "job": job,
                        "slots": 4})
        flat.append(evs)
    merged = [e for evs in flat for e in evs]
    merged.sort(key=lambda r: r["t"])   # stable: per-job order survives
    records.extend(merged)
    records.append({"kind": "run_end",
                    "t": max(r["t"] for r in records)})
    return records


@settings(max_examples=150, deadline=None)
@given(jobs=st.lists(JOB, min_size=1, max_size=5),
       width=st.integers(min_value=8, max_value=90))
def test_every_rendered_bar_maps_to_a_real_span(jobs, width):
    check_bars_map_to_spans(_records(jobs), width)
