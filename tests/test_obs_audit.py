"""Trace auditor (repro.obs.audit): real traced runs PASS the conservation
invariants, and tampered traces — double-booked slots, shaved dollars,
vanished resumes, unresolved kill victims — are caught.  The auditor sees
nothing but the JSONL records, so these tests are the proof that the trace
alone carries enough to re-derive the physics."""
import copy

import pytest

from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool)
from repro.core.autoscale import PreemptingPolicy
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import (SimWorkload, make_jacobi_jobs, run_variant)
from repro.obs.audit import audit_file, audit_records, split_runs
from repro.obs.trace import Tracer, install


def wl(steps=100.0, t1=1.0, t_many=1.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t1), (64.0, t_many))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


def _traced_core_run():
    specs = make_jacobi_jobs(seed=7, n_jobs=10, submission_gap=60.0)
    with install(Tracer()) as tr:
        run_variant("elastic_preempt", specs, total_slots=32)
    return tr.records


def _traced_cloud_run():
    """table2-style autoscaled spot cell with injected kills."""
    prov = CloudProvider([
        NodePool("sp", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=60.0, teardown_delay=30.0,
                 initial_nodes=2, max_nodes=4, spot_lifetime_mean=1e12),
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=90.0, teardown_delay=30.0, initial_nodes=1,
                 max_nodes=4)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=180.0, headroom_slots=8, spot_fraction=0.3))
    pcfg = PolicyConfig(rescale_gap=0.0)
    tr = Tracer()
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg),
                         autoscaler=asc, tracer=tr)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1 + i % 3, 4, 8, 30.0 * i), wl(600))
    victim = sorted(n for n, nd in prov.nodes.items()
                    if nd.pool.market == SPOT)[0]
    prov.inject_spot_kill(victim, 120.0, sim.queue)
    sim.run()
    return tr.records


@pytest.fixture(scope="module")
def core_records():
    return _traced_core_run()


@pytest.fixture(scope="module")
def cloud_records():
    return _traced_cloud_run()


def _tamper(records, fn):
    recs = copy.deepcopy(records)
    fn(recs)
    return recs


# ---------------------------------------------------------------------------
# real runs PASS
# ---------------------------------------------------------------------------

def test_core_run_passes_all_checks(core_records):
    (rep,) = audit_records(core_records)
    assert rep.ok, rep.summary()
    assert rep.checks == {k: True for k in rep.checks}
    assert rep.counts["submits"] == 10 == rep.counts["completes"]


def test_cloud_run_passes_all_checks(cloud_records):
    (rep,) = audit_records(cloud_records)
    assert rep.ok, rep.summary()
    assert rep.counts["preempts"] == rep.counts["resumes"]


def test_cloud_run_produced_a_kill_blast(cloud_records):
    kinds = [r["kind"] for r in cloud_records]
    assert "spot_kill" in kinds and "kill_blast_end" in kinds
    assert "node_up" in kinds and "run_end" in kinds


def test_audit_file_round_trip(tmp_path, core_records):
    path = str(tmp_path / "t.jsonl")
    with Tracer(path) as tr:
        for r in core_records:
            tr.emit(**r)
    (rep,) = audit_file(path)
    assert rep.ok
    assert rep.source == path


def test_split_runs_separates_streams(core_records):
    two = core_records + core_records
    assert len(split_runs(two)) == 2


# ---------------------------------------------------------------------------
# tampered traces FAIL the right check
# ---------------------------------------------------------------------------

def _first(records, kind):
    return next(r for r in records if r["kind"] == kind)


def test_tampered_double_booked_slots_caught(core_records):
    def boost(recs):
        _first(recs, "job_start")["slots"] += 1000
    reports = audit_records(_tamper(core_records, boost))
    assert not reports[0].checks["slot_ownership"]


def test_tampered_total_cost_caught(cloud_records):
    def shave(recs):
        _first(recs, "run_end")["total_cost"] *= 0.9
    reports = audit_records(_tamper(cloud_records, shave))
    assert not reports[0].checks["dollar_conservation"]


def test_tampered_overhead_itemization_caught(cloud_records):
    def drop(recs):
        r = _first(recs, "cost_preempt_overhead")
        r["dollars"] = 0.0
    reports = audit_records(_tamper(cloud_records, drop))
    assert not reports[0].checks["dollar_conservation"]


def test_tampered_missing_resume_caught(core_records):
    victim = _first(core_records, "job_preempt")["job"]
    assert any(r["kind"] == "job_complete" and r["job"] == victim
               for r in core_records)

    def unresume(recs):
        # vanish every resume of the preempted job: it now "completes
        # while preempted" (or stays preempted past run_end)
        recs[:] = [r for r in recs
                   if not (r["kind"] == "job_start" and r.get("resume")
                           and r["job"] == victim)]
    reports = audit_records(_tamper(core_records, unresume))
    assert not reports[0].checks["preempt_resume"]


def test_tampered_unresolved_blast_victim_caught(cloud_records):
    kill = _first(cloud_records, "spot_kill")
    assert kill["residents"], "kill must have displaced residents"
    victim = sorted(kill["residents"])[0]

    def orphan(recs):
        k = _first(recs, "spot_kill")
        i = recs.index(k)
        end = next(j for j in range(i + 1, len(recs))
                   if recs[j]["kind"] == "kill_blast_end"
                   and recs[j]["node"] == k["node"])
        # delete the victim's resolution records inside the blast window
        del recs[i + 1:end]
    reports = audit_records(_tamper(cloud_records, orphan))
    rep = reports[0]
    assert not rep.ok
    assert (not rep.checks["blast_integrity"]
            or not rep.checks["slot_ownership"]), rep.summary()
    assert any(victim in v for v in rep.violations) or rep.violations


def test_tampered_lifecycle_mismatch_caught(core_records):
    def vanish(recs):
        r = _first(recs, "job_complete")
        recs.remove(r)
    reports = audit_records(_tamper(core_records, vanish))
    assert not reports[0].ok


def test_truncated_trace_caught(core_records):
    reports = audit_records(core_records[:-1])   # drop run_end
    assert not reports[0].checks["lifecycle"]


def test_phantom_capacity_caught(cloud_records):
    def strip_node(recs):
        r = _first(recs, "node_up")
        recs.remove(r)
    reports = audit_records(_tamper(cloud_records, strip_node))
    assert not reports[0].checks["slot_ownership"]
