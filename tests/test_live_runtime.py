"""Live multi-device scenarios (subprocesses with 8 virtual CPU devices —
conftest keeps the main process at 1 device per the assignment)."""
import pytest


@pytest.mark.slow
def test_elastic_rescale_preserves_trajectory(helper):
    out = helper("elastic_trajectory.py", "yi-6b")
    assert "OK" in out


@pytest.mark.slow
def test_elastic_rescale_moe_arch(helper):
    out = helper("elastic_trajectory.py", "granite-moe-3b-a800m")
    assert "OK" in out


@pytest.mark.slow
def test_elastic_rescale_ssm_arch(helper):
    out = helper("elastic_trajectory.py", "mamba2-1.3b")
    assert "OK" in out


@pytest.mark.slow
def test_operator_priority_and_fault_tolerance(helper):
    out = helper("operator_scenario.py")
    assert "SCENARIO1 OK" in out and "SCENARIO2 OK" in out


@pytest.mark.slow
def test_dryrun_machinery_small_mesh(helper):
    out = helper("dryrun_small.py")
    assert "OK" in out
    assert "yi-6b|train_4k" in out
