"""Checkpoint/reshard fast lane: pytree path keys (GetAttrKey + escaping),
delta + async disk checkpoints, crash recovery, rescale target validation,
and the fused Pallas pack kernel (interpret-mode smoke; the shape sweep is
in tests/test_kernels.py under the slow marker).

No hypothesis dependency — tests/test_checkpoint.py is skipped wholesale
where hypothesis is absent, so the fast-lane coverage lives here.
"""
import dataclasses
import os
import threading
import time
from types import SimpleNamespace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, DiskCheckpointStore,
                              flatten_tree, snapshot_to_host,
                              surviving_devices, unflatten_tree)


class Layer(NamedTuple):
    w: object
    b: object


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Block:
    alpha: object
    beta: object


def _assert_roundtrip(tree):
    flat = flatten_tree(tree)
    back = unflatten_tree(tree, flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return flat


# -- path keys (the GetAttrKey bug + '/' escaping) ---------------------------

def test_namedtuple_paths_use_field_names():
    flat = _assert_roundtrip({"layer": Layer(w=jnp.ones((2,)),
                                             b=jnp.zeros((3,)))})
    # GetAttrKey entries must resolve via .name — probing only .key/.idx
    # used to stringify them into fragments like "layer/GetAttrKey(name='w')"
    assert set(flat) == {"layer/w", "layer/b"}


def test_registered_dataclass_paths():
    flat = _assert_roundtrip(Block(alpha=jnp.ones((2,)),
                                   beta=[jnp.zeros((1,)), jnp.ones((1,))]))
    assert set(flat) == {"alpha", "beta/0", "beta/1"}


def test_mixed_container_roundtrip():
    tree = {"a": [Layer(jnp.ones((2,)), Block(jnp.zeros(()), jnp.ones(())))],
            "b": (jnp.full((2, 2), 3.0),)}
    flat = _assert_roundtrip(tree)
    assert set(flat) == {"a/0/w", "a/0/b/alpha", "a/0/b/beta", "b/0"}


def test_slash_in_dict_key_cannot_collide():
    nested = {"a": {"b": jnp.ones((2,))}}
    literal = {"a/b": jnp.zeros((2,))}
    assert set(flatten_tree(nested)) == {"a/b"}
    assert set(flatten_tree(literal)) == {"a%2Fb"}      # escaped, no overlap
    both = {"a": {"b": jnp.ones((2,))}, "a/b": jnp.zeros((2,))}
    flat = _assert_roundtrip(both)
    assert set(flat) == {"a/b", "a%2Fb"}


# -- disk store: delta checkpoints + crash recovery --------------------------

def _state(hot_val: float):
    return {"weights": {"w0": np.arange(64.0, dtype=np.float32),
                        "w1": np.ones((32,), np.float32)},
            "opt": {"m": np.full((16,), hot_val, np.float32)}}


def test_delta_checkpoint_reuses_cold_leaves(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    store.save("j", 1, _state(1.0))
    full_bytes = store.last_bytes_written
    store.save("j", 2, _state(2.0), delta=True)
    assert store.last_bytes_written < full_bytes
    flat, manifest = store.load("j")
    assert manifest["delta"] and manifest["bytes_written"] < full_bytes
    # cold leaves are referenced from step 1's npz, hot from step 2's
    leaves = manifest["leaves"]
    assert leaves["weights/w0"]["file"] == "step_000000001.npz"
    assert leaves["opt/m"]["file"] == "step_000000002.npz"
    np.testing.assert_array_equal(flat["opt/m"],
                                  np.full((16,), 2.0, np.float32))
    np.testing.assert_array_equal(flat["weights/w0"],
                                  np.arange(64.0, dtype=np.float32))
    # the chain extends: a third delta still resolves through step 1
    store.save("j", 3, _state(3.0), delta=True)
    flat3, m3 = store.load("j")
    assert m3["leaves"]["weights/w1"]["file"] == "step_000000001.npz"
    np.testing.assert_array_equal(flat3["opt/m"],
                                  np.full((16,), 3.0, np.float32))


def test_legacy_manifest_without_leaves_still_loads(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    store.save("j", 5, _state(1.0))
    # strip the new fields to simulate a pre-delta manifest on disk
    import json
    mpath = os.path.join(str(tmp_path), "j", "step_000000005.json")
    with open(mpath) as f:
        manifest = json.load(f)
    for k in ("leaves", "delta", "bytes_written"):
        manifest.pop(k)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    flat, _ = store.load("j")
    np.testing.assert_array_equal(flat["weights/w0"],
                                  np.arange(64.0, dtype=np.float32))


def test_orphan_npz_is_invisible(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    store.save("j", 10, _state(1.0))
    # a crash between the npz replace and the manifest replace leaves an
    # orphan npz with no manifest: discovery and load must ignore it
    orphan = os.path.join(str(tmp_path), "j", "step_000000020.npz")
    with open(orphan, "wb") as f:
        f.write(b"half-written garbage")
    assert store.latest_step("j") == 10
    flat, manifest = store.load("j")
    assert manifest["step"] == 10


def test_failed_savez_leaves_no_tmp(tmp_path, monkeypatch):
    store = DiskCheckpointStore(str(tmp_path))
    store.save("j", 1, _state(1.0))

    def boom(*a, **kw):
        raise OSError("disk full")
    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        store.save("j", 2, _state(2.0))
    monkeypatch.undo()
    left = os.listdir(os.path.join(str(tmp_path), "j"))
    assert not [f for f in left if f.endswith(".tmp")], left
    assert store.latest_step("j") == 1                # old step intact
    flat, _ = store.load("j")
    np.testing.assert_array_equal(flat["opt/m"],
                                  np.full((16,), 1.0, np.float32))


def test_concurrent_saves_publish_valid_manifests(tmp_path):
    """Two threads saving different steps of one job concurrently (the old
    fixed `.manifest.tmp` path made this a corruption race)."""
    store = DiskCheckpointStore(str(tmp_path))
    errors = []

    def worker(step):
        try:
            for i in range(5):
                store.save("j", step + i, _state(float(step + i)))
        except BaseException as e:                     # pragma: no cover
            errors.append(e)
    ts = [threading.Thread(target=worker, args=(s,)) for s in (100, 200)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    for step in (104, 204):
        flat, manifest = store.load("j", step=step)
        assert manifest["step"] == step
        np.testing.assert_array_equal(
            flat["opt/m"], np.full((16,), float(step), np.float32))


# -- async checkpointer ------------------------------------------------------

def test_async_barrier_never_publishes_half_written_step(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    store.save("j", 1, _state(1.0))
    gate = threading.Event()
    orig = store.save_flat

    def slow_save(*a, **kw):
        gate.wait(5.0)                     # hold the write mid-flight
        return orig(*a, **kw)
    store.save_flat = slow_save
    ac = AsyncCheckpointer(store, delta=True)
    ac.submit("j", 2, _state(2.0))
    # write in flight: a preempt that skipped the barrier would resume
    # from the OLD complete step, never a torn one
    assert store.latest_step("j") == 1
    gate.set()
    ac.barrier()
    assert store.latest_step("j") == 2
    flat, manifest = store.load("j")
    assert manifest["delta"]
    np.testing.assert_array_equal(flat["opt/m"],
                                  np.full((16,), 2.0, np.float32))
    ac.close()


def test_async_writes_drain_in_submit_order(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    ac = AsyncCheckpointer(store, delta=True)
    for step in (1, 2, 3):
        ac.submit("j", step, _state(float(step)))
    ac.barrier()
    assert store.latest_step("j") == 3
    _, m3 = store.load("j", step=3)
    assert m3["delta"]                     # chained off step 2's manifest
    ac.close()


def test_async_error_surfaces_at_barrier(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))

    def boom(*a, **kw):
        raise OSError("disk full")
    store.save_flat = boom
    ac = AsyncCheckpointer(store)
    ac.submit("j", 1, _state(1.0))
    with pytest.raises(OSError):
        ac.barrier()


# -- rescale target validation + survivor detection --------------------------

def _fake_devs(n):
    return [SimpleNamespace(id=i) for i in range(n)]


def test_surviving_devices_counts_overlap():
    old, new = _fake_devs(8), _fake_devs(4)
    assert surviving_devices(old, new) == 4
    assert surviving_devices(old[:2], old[4:]) == 0
    assert surviving_devices([], old) == 0


def test_validate_devices_rejects_bad_targets_before_any_stage():
    from repro.core.elastic import ElasticTrainer, TrainJobConfig
    # validate_devices only consults job config — exercise it without the
    # (expensive) trainer init; the live path is covered by the slow-lane
    # elastic_trajectory helper
    host = SimpleNamespace(job=TrainJobConfig(global_batch=8, model_axis=1))
    assert ElasticTrainer.validate_devices(host, _fake_devs(4)) == 4
    with pytest.raises(ValueError, match="no devices"):
        ElasticTrainer.validate_devices(host, [])
    with pytest.raises(ValueError, match="not divisible"):
        ElasticTrainer.validate_devices(host, _fake_devs(3))
    host2 = SimpleNamespace(job=TrainJobConfig(global_batch=8, model_axis=2))
    with pytest.raises(ValueError, match="model_axis"):
        ElasticTrainer.validate_devices(host2, _fake_devs(5))


# -- fused pack kernel (interpret smoke; sweep in slow lane) -----------------

def test_pack_kernel_smoke_matches_ref():
    from repro.kernels.pack import pack_leaves_pallas, pack_leaves_ref
    rng = np.random.default_rng(0)
    leaves = [jnp.asarray(rng.standard_normal(s).astype(np.float32))
              for s in [(3, 4), (1,), (9, 130)]]
    out = pack_leaves_pallas(leaves, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(pack_leaves_ref(leaves)))


def test_packed_snapshot_matches_plain():
    from repro.kernels.pack import packed_snapshot_to_host
    tree = {"a": {"w": jnp.arange(12.0).reshape(3, 4),
                  "b": jnp.ones((2,), jnp.int32)},
            "s": jnp.float32(3.5), "e": jnp.zeros((0, 2))}
    fused = packed_snapshot_to_host(tree, interpret=True)
    plain = snapshot_to_host(tree)
    assert list(fused) == list(plain)
    for k in plain:
        assert fused[k].dtype == plain[k].dtype
        np.testing.assert_array_equal(fused[k], plain[k])


def test_fused_disk_save_roundtrips(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(64.0), "b": jnp.ones((7,), jnp.int32)}
    store.save("j", 1, tree, fused=True)
    flat, _ = store.load("j")
    np.testing.assert_array_equal(flat["w"], np.arange(64.0))
    np.testing.assert_array_equal(flat["b"], np.ones((7,), np.int32))
