"""Simulator-level behavior: metrics arithmetic, paper-qualitative orderings
(Fig. 7/8, Table 1), and perf-model shapes (Fig. 4/5)."""
import math

import numpy as np
import pytest

from repro.core.perf_model import (JACOBI_SIZES, JacobiModel,
                                   PiecewiseScalingModel, RescaleModel,
                                   arch_model_from_config)
from repro.core.simulator import (VARIANTS, jacobi_workload, make_jacobi_jobs,
                                  run_variant)


def _avg_metrics(variant, seeds, gap, tgap=180.0):
    rows = []
    for seed in seeds:
        specs = make_jacobi_jobs(seed=seed, n_jobs=16, submission_gap=gap)
        m = run_variant(variant, specs, total_slots=64, rescale_gap=tgap)
        rows.append([m.total_time, m.utilization, m.weighted_mean_response,
                     m.weighted_mean_completion, m.dropped_jobs])
    return np.mean(rows, axis=0)


SEEDS = range(8)


def test_paper_table1_orderings_at_gap90():
    """Table 1 (sim columns): utilization elastic > rigid-max > moldable >
    rigid-min; makespan elastic lowest; response elastic < moldable < max."""
    m = {v: _avg_metrics(v, SEEDS, gap=90.0) for v in VARIANTS}
    util = {v: m[v][1] for v in VARIANTS}
    assert util["elastic"] > util["rigid_max"] > util["moldable"] > util["rigid_min"]
    total = {v: m[v][0] for v in VARIANTS}
    assert total["elastic"] < min(total["rigid_min"], total["moldable"])
    resp = {v: m[v][2] for v in VARIANTS}
    assert resp["elastic"] < resp["moldable"] < resp["rigid_max"]
    compl = {v: m[v][3] for v in VARIANTS}
    assert compl["rigid_min"] == max(compl.values())
    assert all(m[v][4] == 0 for v in VARIANTS)   # no dropped jobs


def test_fig8_tgap_sweep_elastic_approaches_moldable():
    """Fig. 8: 'all the metrics for the elastic scheduler approach the
    moldable scheduler as T_rescale_gap is increased'."""
    seeds = range(6)
    mold = _avg_metrics("moldable", seeds, gap=180.0)
    el_small = _avg_metrics("elastic", seeds, gap=180.0, tgap=10.0)
    el_huge = _avg_metrics("elastic", seeds, gap=180.0, tgap=1e9)
    # identical at infinite gap
    np.testing.assert_allclose(el_huge, mold, rtol=1e-9)
    # and utilization decreases monotonically toward it
    assert el_small[1] >= el_huge[1] - 1e-9


def test_fig7_total_time_converges_at_large_gaps():
    """Fig. 7b: schedulers converge as the submission gap grows (each job
    runs alone at max replicas)."""
    seeds = range(4)
    big = {v: _avg_metrics(v, seeds, gap=3000.0) for v in
           ("rigid_max", "moldable", "elastic")}
    ts = [big[v][0] for v in big]
    assert max(ts) - min(ts) < 0.02 * max(ts)


def test_jacobi_strong_scaling_shape():
    """Fig. 4a: larger grids scale better (communication amortized)."""
    small, large = JacobiModel(512, 1), JacobiModel(16_384, 1)
    def speedup(m):
        return m.time_per_step(1) / m.time_per_step(64)
    assert speedup(large) > speedup(small)
    # time per step decreases monotonically in replicas for the large grid
    ts = [large.time_per_step(p) for p in (1, 2, 4, 8, 16, 32, 64)]
    assert all(a > b for a, b in zip(ts, ts[1:]))


def test_rescale_overhead_asymptotics():
    """Fig. 5 (legacy/paper model): restart grows with replica count;
    checkpoint/restore shrink with replicas (fixed problem); load-balance
    flat in replicas, grows with problem size; in-memory ckpt stays low even
    at 4 GB."""
    rm = RescaleModel(fast_lane=False)
    st16 = rm.stages(16, 8, 4e9)
    st64 = rm.stages(64, 32, 4e9)
    assert st64["restart"] > st16["restart"]
    assert st64["checkpoint"] < st16["checkpoint"]
    assert st64["load_balance"] == st16["load_balance"]
    small = rm.stages(32, 16, 2 * 4.0 * 512 ** 2)
    big = rm.stages(32, 16, 4e9)
    assert big["load_balance"] > small["load_balance"]
    assert big["checkpoint"] + big["restore"] < 1.0       # "significantly low"
    # restart dominates small problems (paper Fig. 5c)
    assert small["restart"] > small["checkpoint"] + small["restore"]


def test_rescale_fast_lane_cuts_overhead():
    """The fast lane (P2P reshard + warm restart + async/delta preempt) must
    cut every modeled cost vs. the legacy synchronous path — the fig5 sweep
    gates the aggregate >=5x; this pins the per-call direction."""
    fast, slow = RescaleModel(), RescaleModel(fast_lane=False)
    for old_r, new_r, nbytes in [(4, 2, 33.5e6), (16, 32, 33.5e6),
                                 (32, 16, 4.2e9), (64, 32, 4e9)]:
        assert fast.total(old_r, new_r, nbytes) < slow.total(
            old_r, new_r, nbytes) / 5.0, (old_r, new_r, nbytes)
    for r, nbytes in [(2, 1e9), (8, 2e9), (64, 4e9)]:
        assert fast.preempt_cost(r, nbytes) < slow.preempt_cost(r, nbytes)
        assert fast.resume_cost(r, nbytes) < slow.resume_cost(r, nbytes)
    # P2P skips the host snapshot entirely
    assert fast.stages(8, 4, 1e9)["checkpoint"] == 0.0


def test_workload_generator_matches_paper_setup():
    specs = make_jacobi_jobs(seed=0, n_jobs=16, submission_gap=90.0)
    assert len(specs) == 16
    assert all(1 <= s.priority <= 5 for s in specs)
    assert [s.submit_time for s in specs] == [90.0 * i for i in range(16)]
    sizes = {s.workload for s in specs}
    assert sizes <= set(JACOBI_SIZES)
    for s in specs:
        d = JACOBI_SIZES[s.workload]
        assert (s.min_replicas, s.max_replicas) == (d["min_replicas"],
                                                    d["max_replicas"])


def test_simulator_progress_accounting_exact():
    """A job rescaled mid-flight finishes at the analytically exact time."""
    from repro.core.job import JobSpec
    from repro.core.policies import PolicyConfig
    from repro.core.simulator import Simulator, SimWorkload
    # rate 1 step/s at 8 reps, 0.5 step/s at 4 reps
    scal = PiecewiseScalingModel(((4.0, 2.0), (8.0, 1.0)))
    sim = Simulator(8, PolicyConfig(rescale_gap=0.0))
    sim.submit(JobSpec("a", 1, 4, 8, 0.0), SimWorkload(scal, 100.0, 0.0))
    sim.submit(JobSpec("b", 5, 4, 4, 10.0), SimWorkload(
        PiecewiseScalingModel(((4.0, 1.0),)), 20.0, 0.0))
    m = sim.run()
    a = sim.cluster.jobs["a"]
    b = sim.cluster.jobs["b"]
    # b starts the moment a's shrink frees the slots (overhead is charged to
    # the shrunk job, not the newcomer): 10.0 + 20 steps at 1 s/step
    assert b.end_time == pytest.approx(30.0, abs=1e-6)
    assert a.rescale_count >= 1
    assert a.end_time > 100.0     # shrink + overhead slowed it down


def test_arch_scaling_model_monotone():
    """TPU training jobs: step time decreases with replica groups but is
    lower-bounded by the gradient all-reduce."""
    from repro.configs import get_config
    m = arch_model_from_config(get_config("yi-6b"))
    ts = [m.time_per_step(g) for g in (1, 2, 4, 8, 16)]
    assert all(a > b for a, b in zip(ts, ts[1:]))
    # communication floor: speedup is sublinear
    assert ts[0] / ts[-1] < 16.0


def test_same_timestamp_admission_cancelling_batched_completion():
    """Regression: ``pop_batch`` drains every same-timestamp event up front,
    so an admission dispatched early in the batch can cancel a completion
    event sitting LATER in the same batch (the shrink it triggers
    reschedules that completion).  The tombstone must be dropped by the
    batch loop, not dispatched — this grid (the Fig. 7 submission-gap
    sweep) used to die with ``unknown event kind '__cancelled__'``."""
    for gap in (0.0, 60.0, 120.0, 180.0, 240.0, 300.0):
        for seed in range(3):
            specs = make_jacobi_jobs(seed=seed, n_jobs=16,
                                     submission_gap=gap)
            m = run_variant("elastic", specs, total_slots=64,
                            rescale_gap=180.0)
            assert m.counters["events"] > 0
            # every job completed; stale drops stay consistent
            assert m.counters["stale_events"] >= 0
