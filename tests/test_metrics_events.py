"""Edge cases for UtilizationLog.average windows and EventQueue ordering —
the two primitives every simulation result rests on."""
import pytest

from repro.core.events import EventQueue
from repro.core.metrics import UtilizationLog


# ---------------------------------------------------------------------------
# UtilizationLog.average window semantics
# ---------------------------------------------------------------------------

def test_average_empty_log_is_zero():
    u = UtilizationLog(8)
    assert u.average(0.0, 100.0) == 0.0


def test_average_degenerate_window_is_zero():
    u = UtilizationLog(8)
    u.record(0.0, 4)
    assert u.average(10.0, 10.0) == 0.0
    assert u.average(10.0, 5.0) == 0.0


def test_average_event_before_window_sets_initial_level():
    u = UtilizationLog(8)
    u.record(0.0, 4)                     # level 4 long before the window
    assert u.average(100.0, 200.0) == pytest.approx(0.5)


def test_average_event_exactly_at_window_start():
    u = UtilizationLog(8)
    u.record(50.0, 8)                    # t == t0: counts as the level AT t0
    assert u.average(50.0, 100.0) == pytest.approx(1.0)


def test_average_event_exactly_at_window_end():
    u = UtilizationLog(8)
    u.record(0.0, 4)
    u.record(100.0, 8)                   # t == t1: contributes zero width
    assert u.average(0.0, 100.0) == pytest.approx(0.5)


def test_average_event_after_window_ignored():
    u = UtilizationLog(8)
    u.record(0.0, 4)
    u.record(150.0, 8)
    assert u.average(0.0, 100.0) == pytest.approx(0.5)


def test_average_piecewise_mixture():
    u = UtilizationLog(10)
    u.record(0.0, 0)
    u.record(10.0, 10)                   # [10, 20): full
    u.record(20.0, 5)                    # [20, 40): half
    # (0*10 + 10*10 + 5*20) / (10*40)
    assert u.average(0.0, 40.0) == pytest.approx(0.5)


def test_average_same_timestamp_record_overwrites():
    u = UtilizationLog(8)
    u.record(0.0, 2)
    u.record(0.0, 8)                     # same t: last write wins, no dup
    assert len(u.events) == 1
    assert u.average(0.0, 10.0) == pytest.approx(1.0)


def test_average_with_dynamic_capacity_denominator():
    u = UtilizationLog(8)                # 8 slots before any capacity event
    u.record(0.0, 8)
    u.record_capacity(50.0, 24)          # cluster tripled mid-window
    # used: 8 for 100 s = 800; capacity: 8*50 + 24*50 = 1600
    assert u.average(0.0, 100.0) == pytest.approx(0.5)


def test_average_capacity_zero_window_safe():
    u = UtilizationLog(0)                # cloud sims start with zero base
    u.record(0.0, 0)
    assert u.average(0.0, 10.0) == 0.0   # no division by zero


# ---------------------------------------------------------------------------
# EventQueue determinism
# ---------------------------------------------------------------------------

def test_event_queue_same_timestamp_is_fifo():
    q = EventQueue()
    for i in range(50):
        q.push(10.0, "k", i)
    assert [q.pop().payload for _ in range(50)] == list(range(50))


def test_event_queue_time_then_insertion_order():
    q = EventQueue()
    q.push(5.0, "a", "late-but-first-pushed")
    q.push(1.0, "b", "early")
    q.push(5.0, "c", "late-second-pushed")
    q.push(0.5, "d", "earliest")
    order = [(q.pop().kind) for _ in range(4)]
    assert order == ["d", "b", "a", "c"]


def test_event_queue_pop_empty_returns_none():
    q = EventQueue()
    assert q.pop() is None
    assert q.peek_time() is None
    assert len(q) == 0


def test_event_queue_interleaved_push_pop_stays_deterministic():
    q = EventQueue()
    q.push(2.0, "x", 1)
    q.push(2.0, "x", 2)
    assert q.pop().payload == 1
    q.push(2.0, "x", 3)                  # same timestamp, pushed after a pop
    assert [q.pop().payload, q.pop().payload] == [2, 3]
    assert q.peek_time() is None
