"""Checkpoint stores: host-RAM (/dev/shm analog), disk (fault tolerance),
tree flatten/unflatten identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (DiskCheckpointStore, MemoryCheckpointStore,
                              flatten_tree, snapshot_to_host, unflatten_tree)


def _tree():
    return {
        "a": {"w": jnp.arange(12.0).reshape(3, 4),
              "b": jnp.ones((2,), jnp.int32)},
        "list": [jnp.zeros((1,)), jnp.full((2, 2), 7.0)],
        "scalar": jnp.float32(3.5),
    }


def test_flatten_unflatten_roundtrip():
    t = _tree()
    flat = flatten_tree(t)
    assert set(flat) == {"a/b", "a/w", "list/0", "list/1", "scalar"}
    t2 = unflatten_tree(t, flat)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_memory_store_roundtrip_and_nbytes():
    store = MemoryCheckpointStore()
    t = _tree()
    dt = store.save("job1", t, meta={"step": 5})
    assert dt >= 0.0
    assert "job1" in store
    flat = store.load("job1")
    np.testing.assert_array_equal(flat["a/w"], np.arange(12.0).reshape(3, 4))
    expected = sum(np.asarray(x).nbytes for x in jax.tree.leaves(t))
    assert store.nbytes("job1") == expected
    assert store.meta("job1")["step"] == 5
    store.delete("job1")
    assert "job1" not in store


def test_disk_store_roundtrip_latest_and_atomic(tmp_path):
    store = DiskCheckpointStore(str(tmp_path))
    t = _tree()
    store.save("jobA", 10, t, meta={"replicas": 4})
    t["a"]["w"] = t["a"]["w"] + 1.0
    store.save("jobA", 20, t)
    assert store.latest_step("jobA") == 20
    flat, manifest = store.load("jobA")
    np.testing.assert_array_equal(flat["a/w"],
                                  np.arange(12.0).reshape(3, 4) + 1.0)
    flat10, m10 = store.load("jobA", step=10)
    np.testing.assert_array_equal(flat10["a/w"], np.arange(12.0).reshape(3, 4))
    assert m10["meta"]["replicas"] == 4
    assert store.latest_step("missing") is None
    with pytest.raises(FileNotFoundError):
        store.load("missing")


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 4), st.integers(1, 4)), min_size=1,
                max_size=5), st.integers(0, 2 ** 31 - 1))
def test_snapshot_preserves_arbitrary_trees(shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"k{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
            for i, s in enumerate(shapes)}
    host = snapshot_to_host(tree)
    back = unflatten_tree(tree, host)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
