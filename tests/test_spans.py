"""Causal span graphs (repro.obs.spans): hand-built traces assemble into the
expected span trees and cause chains (zone_reclaim -> spot_kill -> outage ->
resumed compute; scale_down drain -> migrate), the live SpanTap sees the
same graph a loaded trace does, and a real cloud run with correlated zone
reclaims produces the full length-4 chain end to end.
"""
from repro.cloud import (SPOT, AutoscalerConfig, BidderConfig, CloudProvider,
                         CloudSimulator, DemandAwareBidder, NodeAutoscaler,
                         NodePool)
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload, make_jacobi_jobs, run_variant
from repro.obs import Tracer, install
from repro.obs.spans import (SpanGraphBuilder, SpanTap, build_span_graph,
                             render_chains)


def _kill_chain_records():
    """Minimal recorder-shaped stream: one job displaced by a spot kill that
    a zone reclaim caused, then resumed and completed."""
    return [
        {"kind": "run_start", "t": 0.0, "run": 1, "slots": 16},
        {"kind": "job_submit", "t": 0.0, "job": "j1", "priority": 3,
         "min": 4, "max": 8},
        {"kind": "job_start", "t": 5.0, "job": "j1", "slots": 8},
        {"kind": "zone_reclaim", "t": 100.0, "zone": "z-a",
         "victims": ["n1"]},
        {"kind": "spot_kill", "t": 100.0, "node": "n1", "zone": "z-a",
         "residents": {"j1": 8}},
        {"kind": "job_preempt", "t": 101.0, "job": "j1", "slots": 8,
         "ckpt_s": 1.0},
        {"kind": "kill_blast_end", "t": 101.0, "node": "n1", "jobs": 1,
         "slots": 8, "preempts": 1},
        {"kind": "zone_reclaim_end", "t": 101.0, "zone": "z-a"},
        {"kind": "job_start", "t": 160.0, "job": "j1", "slots": 8,
         "resume": True, "overhead_s": 2.0},
        {"kind": "job_complete", "t": 400.0, "job": "j1", "slots": 8},
        {"kind": "run_end", "t": 400.0},
    ]


def test_job_tree_structure_and_intervals():
    g = build_span_graph(_kill_chain_records())
    root = g.job_tree("j1")
    assert root is not None and (root.t0, root.t1) == (0.0, 400.0)
    assert root.meta == {"priority": 3, "min": 4, "max": 8}
    names = [c.name for c in root.children]
    assert names == ["queue_wait", "compute", "ckpt", "outage", "restore",
                     "compute"]
    by = {}
    for c in root.children:
        by.setdefault(c.name, []).append(c)
    assert (by["queue_wait"][0].t0, by["queue_wait"][0].t1) == (0.0, 5.0)
    assert (by["compute"][0].t0, by["compute"][0].t1) == (5.0, 101.0)
    assert (by["ckpt"][0].t0, by["ckpt"][0].t1) == (100.0, 101.0)
    assert (by["outage"][0].t0, by["outage"][0].t1) == (101.0, 160.0)
    assert (by["restore"][0].t0, by["restore"][0].t1) == (160.0, 162.0)
    assert by["compute"][1].t1 == 400.0


def test_cause_edges_stitch_the_full_chain():
    g = build_span_graph(_kill_chain_records())
    root = g.job_tree("j1")
    outage = next(c for c in root.children if c.name == "outage")
    assert outage.cause is not None and outage.cause.name == "spot_kill"
    assert outage.cause.cause is not None
    assert outage.cause.cause.name == "zone_reclaim"
    resumed = [c for c in root.children if c.name == "compute"][1]
    chain = [s.name for s in g.chain_of(resumed)]
    assert chain == ["zone_reclaim", "spot_kill", "outage", "compute"]
    assert g.longest_causal_chain() == 4
    art = render_chains(g)
    assert "zone_reclaim[z-a]" in art and " -> " in art
    assert "compute[j1]" in art


def test_drain_decision_causes_migrate():
    b = SpanGraphBuilder()
    for r in [
        {"kind": "job_submit", "t": 0.0, "job": "m1"},
        {"kind": "job_start", "t": 0.0, "job": "m1", "slots": 4},
        {"kind": "decision", "t": 50.0, "point": "scale_down",
         "verdict": "drain_started", "inputs": {"node": "n7"}},
        {"kind": "job_migrate", "t": 60.0, "job": "m1", "from_node": "n7",
         "moved": 4, "overhead_s": 3.0},
        {"kind": "decision", "t": 60.0, "point": "scale_down",
         "verdict": "drain_complete", "inputs": {"node": "n7"}},
        {"kind": "job_complete", "t": 100.0, "job": "m1", "slots": 4},
    ]:
        b.feed(r)
    g = b.build()
    mig = next(c for c in g.job_tree("m1").children if c.name == "migrate")
    assert mig.cause is not None and mig.cause.name == "scale_down"
    assert mig.cause.meta["node"] == "n7"
    assert mig.cause.t1 == 60.0          # drain_complete closed the drain


def test_open_spans_visible_mid_stream():
    b = SpanGraphBuilder()
    b.feed({"kind": "job_submit", "t": 0.0, "job": "live"})
    b.feed({"kind": "job_start", "t": 10.0, "job": "live", "slots": 4})
    g = b.build()
    root = g.job_tree("live")
    assert root.t1 is None               # still running
    seg = next(c for c in root.children if c.name == "compute")
    assert seg.t1 is None and seg.duration == 0.0
    assert g.longest_causal_chain() == 1  # no cause edges yet


def test_span_tap_matches_offline_graph_and_forwards():
    specs = make_jacobi_jobs(seed=7, n_jobs=6, submission_gap=60.0)
    tap = SpanTap(delegate=Tracer())
    with install(tap):
        run_variant("elastic_preempt", specs, total_slots=24)
    live = tap.graph()
    offline = build_span_graph(tap.delegate.records)
    assert set(live.jobs) == {s.job_id for s in specs}
    assert set(live.jobs) == set(offline.jobs)
    for job_id, root in live.jobs.items():
        assert root.t1 is not None, f"{job_id} never closed"
        assert [c.name for c in root.children] == \
            [c.name for c in offline.jobs[job_id].children]
    assert live.longest_causal_chain() == offline.longest_causal_chain()


def _reclaim_sim(tracer):
    """Three-zone fleet with a hot zone under whole-zone reclaims — the
    scenario that produces real zone_reclaim -> spot_kill -> outage chains."""
    def wl():
        return SimWorkload(
            scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
            total_work=1500.0, data_bytes=1e9, rescale=RescaleModel())
    pools = [NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                      boot_latency=60.0, teardown_delay=30.0,
                      initial_nodes=1, max_nodes=2, zone="east-1a")]
    for zone in ("east-1b", "east-1c"):
        pools.append(NodePool(
            f"sp-{zone}", slots_per_node=8, price_per_slot_hour=0.016,
            market=SPOT, boot_latency=60.0, teardown_delay=30.0,
            initial_nodes=1, max_nodes=4, spot_lifetime_mean=1e12,
            zone=zone))
    prov = CloudProvider(
        pools, seed=3,
        zone_reclaim_interval={"east-1b": 300.0}, zone_reclaim_fraction=1.0)
    bidder = DemandAwareBidder(BidderConfig(
        half_life=900.0, hysteresis=0.25, risk_aversion=10.0,
        min_evidence_kills=1.0, spot_fraction_max=0.5))
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=240.0, spot_fraction=0.6, bidder=bidder))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0),
                         autoscaler=asc, tracer=tracer)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1 + i % 3, 8, 8, 60.0 * i), wl())
    return sim


def test_cloud_run_produces_length_four_causal_chain():
    tr = Tracer()
    _reclaim_sim(tr).run()
    g = build_span_graph(tr.records)
    assert g.longest_causal_chain() >= 4
    # at least one outage is attributed to a kill that a reclaim caused
    attributed = [s for s in g.all_spans()
                  if s.name == "outage" and s.cause is not None
                  and s.cause.name == "spot_kill"
                  and s.cause.cause is not None
                  and s.cause.cause.name == "zone_reclaim"]
    assert attributed
    art = render_chains(g, min_len=3)
    assert "zone_reclaim" in art and "spot_kill" in art
