"""Shape-aware logical-axis rules."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.sharding import AxisRules, RULE_SETS, make_param_shardings
from repro.sharding.specs import _base_rules


@pytest.fixture(scope="module")
def mesh1d():
    # single real device: a (1,1) mesh is enough to exercise spec logic
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def _fake_mesh_rules(data=16, model=16):
    """AxisRules with a fake mesh object (spec_for only reads axis_names and
    shape) so divisibility logic is testable without 256 devices."""
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": data, "model": model}
    return AxisRules(mesh=FakeMesh(), rules=_base_rules())


def test_divisible_dims_get_sharded():
    r = _fake_mesh_rules()
    spec = r.spec_for(("vocab", "embed"), (64_000, 4096))
    assert spec == P("model", None)


def test_indivisible_dim_falls_back_to_replication():
    r = _fake_mesh_rules()
    # 50280 % 16 != 0 -> vocab cannot shard
    spec = r.spec_for(("vocab", "embed"), (50_280, 2048))
    assert spec == P(None, None)


def test_freed_axis_flows_to_later_dim():
    """kv_heads=4 can't shard 16-way; the qk head_dim picks up 'model'."""
    r = _fake_mesh_rules()
    spec = r.spec_for(("embed", "kv_heads", "qk"), (4096, 4, 128))
    assert spec == P(None, None, "model")
    # but when heads CAN shard, qk must not reuse the axis
    spec = r.spec_for(("embed", "heads", "qk"), (4096, 32, 128))
    assert spec == P(None, "model", None)


def test_tuple_axis_prefix_fallback():
    r = _fake_mesh_rules()
    r.rules["batch"] = ("pod", "data")

    class FakeMesh3:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    r.mesh = FakeMesh3()
    # 32 % (2*16) == 0 -> both axes
    assert r.spec_for(("batch",), (32,)) == P(("pod", "data"))
    # 2 % 2 == 0 but 2 % 32 != 0 -> only the 'pod' prefix
    assert r.spec_for(("batch",), (2,)) == P("pod")
    # batch=1: replicate
    assert r.spec_for(("batch",), (1,)) == P(None)


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
@pytest.mark.parametrize("rules_name", ["tp", "tp_fsdp_sp", "decode"])
def test_rules_produce_valid_shardings_for_all_params(arch, rules_name):
    """Every param's sharding divides its shape (the GSPMD requirement the
    dry-run enforces for real)."""
    from repro.models import abstract_params, logical_axes
    cfg = get_config(arch)
    r = _fake_mesh_rules()
    r.rules = RULE_SETS[rules_name]()
    ap = abstract_params(cfg)
    ax = logical_axes(cfg)
    flat_ax, treedef = jax.tree.flatten(
        ax, is_leaf=lambda l: isinstance(l, tuple))
    flat_sh = treedef.flatten_up_to(ap)
    for axes, spec_shape in zip(flat_ax, flat_sh):
        spec = r.spec_for(axes, tuple(spec_shape.shape))
        for dim, entry in zip(spec_shape.shape, tuple(spec)):
            if entry is None:
                continue
            axs = (entry,) if isinstance(entry, str) else entry
            prod = 1
            for a in axs:
                prod *= r.mesh.shape[a]
            assert dim % prod == 0, (arch, axes, spec_shape.shape, spec)


def test_no_rules_is_noop(mesh1d):
    from repro.sharding import axis_rules, shard_constraint
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard_constraint(x, "batch", "embed") is x  # no context -> no-op
