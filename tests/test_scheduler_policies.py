"""Policy scenario tests — each pinned to a sentence of the paper.

All scenarios run through the real simulator (virtual clock) with trivial
constant-rate workloads so slot arithmetic is exact.
"""
import math

import pytest

from repro.core.job import JobSpec, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator, SimWorkload


def wl(steps=1000.0, t=1.0):
    """Constant time-per-step workload with zero-ish rescale overhead."""
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t), (128.0, t))),
        total_work=steps, data_bytes=0.0, rescale=RescaleModel())


def sim(slots=16, gap=0.0, reserve=0, redistribute_idle=True):
    return Simulator(slots, PolicyConfig(rescale_gap=gap,
                                         launcher_reserve=reserve,
                                         redistribute_idle=redistribute_idle))


def test_job_starts_at_max_when_cluster_empty():
    s = sim()
    s.submit(JobSpec("a", 3, 2, 8, 0.0), wl(10))
    s.run()
    a = s.cluster.jobs["a"]
    assert a.start_time == 0.0
    # started at max_replicas (8 <= 16 free)
    assert a.end_time == pytest.approx(10.0, abs=1e-6)


def test_new_job_starts_at_min_instead_of_shrinking():
    """§3.2.1: 'our scheduling algorithm will run the higher priority job at
    its minimum replicas configuration to avoid a shrink call'."""
    s = sim(slots=16)
    s.submit(JobSpec("low", 1, 4, 12, 0.0), wl(1000))   # takes 12, leaves 4
    s.submit(JobSpec("high", 5, 2, 8, 1.0), wl(10))
    s.queue.push(2.0, "noop", None)
    # run only the submissions
    while len(s.queue):
        ev = s.queue.pop()
        s.now = max(s.now, ev.time)
        if ev.kind == "submit":
            s.cluster.add_job(ev.payload)
            s.policy.on_new_job(s.cluster, ev.payload, s.now, s.actions)
        if s.now >= 2.0:
            break
    low, high = s.cluster.jobs["low"], s.cluster.jobs["high"]
    assert low.replicas == 12          # NOT shrunk
    assert high.replicas == 4          # started in the free gap (>= min 2)
    assert high.status == JobStatus.RUNNING


def test_shrink_happens_when_min_cannot_fit():
    """§3.2.1: 'if enough slots are not available to start the higher priority
    job even at its minimum replicas configuration, the lower priority job
    will be scaled down'."""
    s = sim(slots=16)
    s.submit(JobSpec("low", 1, 4, 16, 0.0), wl(1000))   # takes all 16
    s.submit(JobSpec("high", 5, 8, 12, 1.0), wl(10))
    s.run()
    low, high = s.cluster.jobs["low"], s.cluster.jobs["high"]
    assert low.rescale_count >= 1
    assert high.start_time == pytest.approx(1.0, abs=1e-6)
    # low was shrunk toward min to give high its max config if possible
    # (16 - 4 = 12 freed = high's max)
    assert high.end_time is not None


def test_rescale_gap_blocks_shrink():
    """§3.2.1: 'a configurable minimum gap between any two scheduling
    events'. A job inside its cool-down cannot be shrunk; the newcomer
    queues."""
    s = sim(slots=16, gap=100.0)
    s.submit(JobSpec("low", 1, 4, 16, 0.0), wl(1000))
    s.submit(JobSpec("high", 5, 8, 12, 1.0), wl(10))
    # process just the two submits
    for _ in range(2):
        ev = s.queue.pop()
        s.now = max(s.now, ev.time)
        s.cluster.add_job(ev.payload)
        s.policy.on_new_job(s.cluster, ev.payload, s.now, s.actions)
    assert s.cluster.jobs["low"].replicas == 16    # protected by T_rescale_gap
    assert s.cluster.jobs["high"].status == JobStatus.QUEUED


def test_higher_priority_jobs_never_shrunk_for_lower():
    """Fig. 2 guard: only jobs with priority <= the newcomer's may shrink."""
    s = sim(slots=16)
    s.submit(JobSpec("vip", 5, 4, 16, 0.0), wl(1000))
    s.submit(JobSpec("pleb", 1, 8, 8, 1.0), wl(10))
    for _ in range(2):
        ev = s.queue.pop()
        s.now = max(s.now, ev.time)
        s.cluster.add_job(ev.payload)
        s.policy.on_new_job(s.cluster, ev.payload, s.now, s.actions)
    assert s.cluster.jobs["vip"].replicas == 16
    assert s.cluster.jobs["pleb"].status == JobStatus.QUEUED


def test_completion_expands_highest_priority_first():
    """Fig. 3: freed slots go to running/queued jobs in priority order."""
    s = sim(slots=16)
    s.submit(JobSpec("short", 4, 8, 8, 0.0), wl(5))          # rigid 8
    s.submit(JobSpec("p3", 3, 4, 16, 0.0), wl(1000))         # gets 8, wants 16
    s.submit(JobSpec("p2", 2, 4, 16, 0.0), wl(1000))         # queued
    # run until `short` completes
    while len(s.queue):
        ev = s.queue.pop()
        s.now = max(s.now, ev.time)
        if ev.kind == "submit":
            s.cluster.add_job(ev.payload)
            s.policy.on_new_job(s.cluster, ev.payload, s.now, s.actions)
        elif ev.kind == "complete":
            jid, ver = ev.payload
            job = s.cluster.jobs[jid]
            if job.version != ver:
                continue
            s._sync_progress(job)
            freed = job.replicas
            s.cluster.evict(jid)         # completion frees node-backed slots
            job.status = JobStatus.COMPLETED
            job.end_time = s.now
            job.replicas = 0
            s.policy.on_job_complete(s.cluster, freed, s.now, s.actions)
            break
    # p3 (higher priority) expanded to max before p2 got anything
    assert s.cluster.jobs["p3"].replicas == 16
    assert s.cluster.jobs["p2"].status == JobStatus.QUEUED


def test_fcfs_among_equal_priorities():
    s = sim(slots=8)
    s.submit(JobSpec("b_later", 3, 8, 8, 1.0), wl(50))
    s.submit(JobSpec("a_early", 3, 8, 8, 0.5), wl(50))
    s.submit(JobSpec("running", 3, 8, 8, 0.0), wl(10))
    s.run()
    a, b = s.cluster.jobs["a_early"], s.cluster.jobs["b_later"]
    assert a.start_time < b.start_time


def test_launcher_reserve_reproduces_paper_freeslots_minus_one():
    s = sim(slots=8, reserve=1)
    s.submit(JobSpec("a", 3, 2, 8, 0.0), wl(10, t=1.0))
    s.run()
    # with the launcher slot reserved only 7 replicas fit
    assert s.cluster.jobs["a"].end_time == pytest.approx(10.0, abs=1e-6)
    assert s.util.events[0][1] == 7


def test_pseudocode_faithful_redistribution_can_strand_slots():
    """DESIGN.md §6.3: Fig. 3 redistributes only freed slots; a queued job
    whose min exceeds every later completion starves even on an idle
    cluster. redistribute_idle=False reproduces the paper behavior."""
    specs = [
        JobSpec("big", 5, 12, 16, 0.0),       # holds 16
        JobSpec("small1", 4, 2, 2, 1.0),      # queued, then gets slots
        JobSpec("wide", 3, 16, 16, 2.0),      # needs 16 at once
    ]
    workloads = {"big": wl(10), "small1": wl(3), "wide": wl(5)}

    def run(redistribute_idle):
        s = sim(slots=16, redistribute_idle=redistribute_idle)
        for sp in specs:
            s.submit(sp, workloads[sp.job_id])
        m = s.run()
        return s, m

    s_fixed, m_fixed = run(True)
    assert m_fixed.dropped_jobs == 0
    s_paper, m_paper = run(False)
    # with faithful redistribution `wide` never reaches 16 freed at once
    assert s_paper.cluster.jobs["wide"].end_time is None
    assert m_paper.dropped_jobs == 1


def test_moldable_never_rescales_but_starts_queued_jobs():
    """§4.3.2: moldable = elastic with an infinite T_rescale_gap; queued jobs
    must still start when slots free up."""
    s = Simulator(16, PolicyConfig.moldable())
    s.submit(JobSpec("a", 3, 8, 16, 0.0), wl(10))
    s.submit(JobSpec("b", 3, 8, 16, 1.0), wl(10))
    m = s.run()
    a, b = s.cluster.jobs["a"], s.cluster.jobs["b"]
    assert a.rescale_count == 0 and b.rescale_count == 0
    assert b.end_time is not None
    assert m.dropped_jobs == 0
