"""Pallas kernels (interpret mode) and the blocked-jnp twin vs. ref oracles:
shape/dtype sweeps per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# interpret-mode sweeps take minutes: slow lane (CI runs it non-blocking;
# the 22 failing cases are known seed debt — see ROADMAP "Open items")
pytestmark = pytest.mark.slow

from repro.kernels import ops, ref
from repro.kernels.blocked import blocked_attention

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 512, 8, 2, 32),     # long-ish, high group ratio
    (2, 128, 6, 3, 128),    # non-pow2 heads, MXU-width head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(B, S, H, KV, hd, dtype):
    q, k, v = _qkv(B, S, H, KV, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 32), (32, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    q, k, v = _qkv(2, 256, 4, 2, 64, jnp.float32)
    from repro.kernels.flash_attention import flash_attention_fwd
    out = flash_attention_fwd(q, k, v, causal=True, block_q=block_q,
                              block_k=block_k, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_grad_flows():
    q, k, v = _qkv(1, 128, 4, 2, 32, jnp.float32)
    g = jax.grad(lambda q_: jnp.sum(
        ops.flash_attention(q_, k, v, causal=True, interpret=True) ** 2))(q)
    gr = jax.grad(lambda q_: jnp.sum(
        ref.flash_attention_ref(q_, k, v, causal=True) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (1, 64, 4, 16, 1, 16, 16),
    (2, 64, 4, 16, 2, 16, 16),
    (1, 128, 8, 32, 1, 32, 32),
    (2, 96, 6, 16, 3, 8, 32),      # non-pow2, chunk > some dims
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_naive_recurrence(B, L, H, P, G, N, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (B, L, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=8.0))
    b = (jax.random.normal(ks[3], (B, L, G, N)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[4], (B, L, G, N)) * 0.3).astype(dtype)
    exp = ref.ssd_ref(x, dt, a_log, b, c)
    out = ops.ssd(x, dt, a_log, b, c, chunk=chunk, interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_ssd_jnp_chunked_matches_kernel_math():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, L, H, P, G, N = 2, 64, 4, 16, 2, 16
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=8.0))
    b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    got = ssd_chunked(x, dt, a_log, b, c, chunk=16)
    exp = ops.ssd(x, dt, a_log, b, c, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5,
                               rtol=1e-5)


def test_ssd_grad_matches_chunked_jnp():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, L, H, P, G, N = 1, 32, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jnp.log(jax.random.uniform(ks[2], (H,), minval=1.0, maxval=4.0))
    b = jax.random.normal(ks[3], (B, L, G, N)) * 0.3
    c = jax.random.normal(ks[4], (B, L, G, N)) * 0.3
    g1 = jax.grad(lambda x_: jnp.sum(
        ops.ssd(x_, dt, a_log, b, c, chunk=8, interpret=True) ** 2))(x)
    g2 = jax.grad(lambda x_: jnp.sum(
        ssd_chunked(x_, dt, a_log, b, c, chunk=8) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("shape", [(7, 64), (8, 33, 128), (2, 3, 4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], jnp.float32)
    out = ops.rmsnorm(x, w, interpret=True)
    exp = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# blocked (XLA) flash twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,bk", [
    (2, 128, 4, 2, 32, 32),
    (1, 100, 6, 2, 16, 48),    # Sk not a multiple of block
    (2, 64, 4, 4, 32, 64),
])
def test_blocked_attention_fwd_and_grads(B, S, H, KV, hd, bk):
    q, k, v = _qkv(B, S, H, KV, hd, jnp.float32)
    out = blocked_attention(q, k, v, True, None, 0, None, bk)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)
    gb = jax.grad(lambda *a: jnp.sum(
        blocked_attention(*a, True, None, 0, None, bk) ** 2), (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(
        ref.flash_attention_ref(*a, causal=True) ** 2), (0, 1, 2))(q, k, v)
    for a, b in zip(gb, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   rtol=5e-4)


# ---------------------------------------------------------------------------
# fused checkpoint pack (fast-lane gather/pack)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shapes,dtype", [
    ([(128,), (8, 128), (1000,)], jnp.float32),       # uneven lane padding
    ([(256, 256), (1,), (3, 5, 7)], jnp.float32),     # big + scalarish + odd
    ([(64, 64), (4096,)], jnp.bfloat16),              # sub-word dtype
    ([(17,), (129,), (130, 2)], jnp.int32),           # all off-lane
])
def test_pack_kernel_sweep_vs_ref(shapes, dtype):
    from repro.kernels.pack import pack_leaves_pallas, pack_leaves_ref
    ks = jax.random.split(KEY, len(shapes))
    if jnp.issubdtype(dtype, jnp.integer):
        leaves = [jax.random.randint(k, s, -100, 100, dtype)
                  for k, s in zip(ks, shapes)]
    else:
        leaves = [jax.random.normal(k, s, dtype) for k, s in zip(ks, shapes)]
    out = pack_leaves_pallas(leaves, interpret=True)
    exp = pack_leaves_ref(leaves)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("block_rows", [4, 8, 16])
def test_pack_kernel_block_rows(block_rows):
    from repro.kernels.pack import pack_leaves_pallas, pack_leaves_ref
    leaves = [jax.random.normal(k, (n,))
              for k, n in zip(jax.random.split(KEY, 3), (700, 129, 2048))]
    out = pack_leaves_pallas(leaves, block_rows=block_rows, interpret=True)
    exp = pack_leaves_ref(leaves, block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_blocked_attention_non_causal_and_hdv():
    """Cross-attention form: no mask, v head dim differs from qk head dim."""
    B, Sq, Sk, H, hd, hdv = 2, 32, 48, 4, 16, 24
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Sk, H, hd))
    v = jax.random.normal(ks[2], (B, Sk, H, hdv))
    out = blocked_attention(q, k, v, False, None, 0, None, 16)
    # naive reference with distinct v dim
    s = jnp.einsum("bshd,bthd->bhst", q, k) * hd ** -0.5
    p = jax.nn.softmax(s, -1)
    exp = jnp.einsum("bhst,bthv->bshv", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=2e-5)
