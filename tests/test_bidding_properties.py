"""Hypothesis property tests for demand-aware spot bidding:

- emitted per-zone shares always lie in ``[0, spot_fraction_max]`` and sum
  to at most the global ``spot_fraction``, whatever the ledger ingested;
- the ledger's undecayed audit totals equal the sum of the ingested records
  under arbitrary event interleavings (shuffled times, mixed zones);
- a bidder fed zero kills converges to (stays at) the static even split.
"""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.cloud import (SPOT, BidderConfig, CloudProvider, DemandAwareBidder,
                         NodePool, SpotRiskLedger)

ZONES = ["z-a", "z-b", "z-c", "z-d"]


def _provider(zones):
    pools = [NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                      initial_nodes=1, max_nodes=4, zone="od-zone")]
    for z in zones:
        pools.append(NodePool(
            f"spot-{z}", slots_per_node=8, price_per_slot_hour=0.016,
            market=SPOT, max_nodes=4, spot_lifetime_mean=1e12, zone=z))
    return CloudProvider(pools)


@st.composite
def ledger_events(draw):
    n = draw(st.integers(0, 25))
    events = []
    for _ in range(n):
        events.append((
            draw(st.sampled_from(["kill", "cost"])),
            draw(st.sampled_from(ZONES)),
            draw(st.floats(0.0, 1e5)),                  # time (any order)
            draw(st.integers(1, 3)),                    # nodes (kill only)
            draw(st.floats(0.0, 5.0)),                  # dollars
            draw(st.floats(0.0, 300.0)),                # lost seconds
            draw(st.floats(0.0, 1.0)),                  # transfer dollars
        ))
    return events


def _ingest(ledger, events):
    for kind, zone, t, nodes, dollars, lost, xfer in events:
        if kind == "kill":
            ledger.record_kill(zone, t, nodes=nodes, dollars=dollars,
                               lost_seconds=lost)
        else:
            ledger.record_cost(zone, t, dollars=dollars, lost_seconds=lost,
                               transfer_dollars=xfer)


@settings(max_examples=100, deadline=None)
@given(ledger_events())
def test_ledger_totals_equal_sum_of_ingested_records(events):
    ledger = SpotRiskLedger(half_life=600.0)
    _ingest(ledger, events)
    for zone in ZONES:
        kills = sum(e[3] for e in events if e[0] == "kill" and e[1] == zone)
        dollars = sum(e[4] for e in events if e[1] == zone)
        lost = sum(e[5] for e in events if e[1] == zone)
        xfer = sum(e[6] for e in events if e[0] == "cost" and e[1] == zone)
        t = ledger.totals(zone)
        assert t.kills == kills
        assert t.dollars == pytest.approx(dollars, abs=1e-9)
        assert t.lost_s == pytest.approx(lost, abs=1e-9)
        assert t.transfer_dollars == pytest.approx(xfer, abs=1e-9)
        # decayed estimators never exceed what was ingested (decay only
        # shrinks) and never go negative
        if zone in ledger.zones:
            s = ledger.zones[zone]
            assert -1e-12 <= s.decayed_dollars <= t.total_dollars + 1e-9
            assert -1e-12 <= s.decayed_kills <= t.kills + 1e-9


@st.composite
def share_scenarios(draw):
    n_zones = draw(st.integers(1, 4))
    zones = ZONES[:n_zones]
    spot_fraction = draw(st.floats(0.0, 1.0))
    cap = draw(st.floats(0.05, 1.0))
    hysteresis = draw(st.floats(0.0, 0.9))
    events = draw(ledger_events())
    eval_times = draw(st.lists(st.floats(0.0, 2e5), min_size=1, max_size=5))
    return zones, spot_fraction, cap, hysteresis, events, eval_times


@settings(max_examples=100, deadline=None)
@given(share_scenarios())
def test_shares_bounded_per_zone_and_sum_capped_globally(scn):
    zones, spot_fraction, cap, hysteresis, events, eval_times = scn
    prov = _provider(zones)
    bidder = DemandAwareBidder(BidderConfig(
        half_life=600.0, hysteresis=hysteresis, spot_fraction_max=cap))
    _ingest(bidder.ledger, [e for e in events if e[1] in zones])
    for t in eval_times:
        shares = bidder.zone_quotas(zones, t, prov, spot_fraction)
        assert set(shares) == set(zones)
        for share in shares.values():
            assert 0.0 <= share <= cap + 1e-12
        assert sum(shares.values()) <= spot_fraction + 1e-9
        assert shares == bidder.last_shares


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 4), st.floats(0.0, 1.0),
       st.lists(st.floats(0.0, 1e5), min_size=1, max_size=6))
def test_zero_kill_bidder_stays_at_the_static_fraction(n_zones, spot_fraction,
                                                       eval_times):
    """With no kills ever recorded every zone keeps the prior (open) and the
    emitted shares are exactly the static even split, at every evaluation
    time — the bidder converges to (never leaves) the static policy."""
    zones = ZONES[:n_zones]
    prov = _provider(zones)
    bidder = DemandAwareBidder(BidderConfig(half_life=600.0))
    static = spot_fraction / n_zones
    for t in sorted(eval_times):
        shares = bidder.zone_quotas(zones, t, prov, spot_fraction)
        for z in zones:
            assert shares[z] == pytest.approx(static)
    assert bidder.adjustments == 0


@settings(max_examples=60, deadline=None)
@given(st.floats(1e-3, 1e4), st.floats(0.1, 100.0),
       st.lists(st.floats(0.0, 1e4), min_size=2, max_size=8))
def test_decayed_cost_monotone_between_records(half_life, dollars, times):
    """Between records the decayed estimate only shrinks (half-life decay),
    and a query never mutates the audit totals."""
    ledger = SpotRiskLedger(half_life=half_life)
    ledger.record_kill("z", 0.0, dollars=dollars)
    prev = ledger.cost_rate("z", 0.0)
    for t in sorted(times):
        cur = ledger.cost_rate("z", t)
        assert cur <= prev + 1e-12
        prev = cur
    assert ledger.totals("z").dollars == pytest.approx(dollars)
