"""Property tests for the step-function integrator behind UtilizationLog.

``_integrate`` is the one piece of arithmetic every utilization /
fragmentation figure flows through; here hypothesis drives it against a
brute-force Riemann reference over adversarial event sets — events before,
at and after the window, duplicate timestamps, zero-width windows.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.metrics import UtilizationLog, _coalesce, _integrate  # noqa: E402

TIMES = st.floats(min_value=-50.0, max_value=150.0,
                  allow_nan=False, allow_infinity=False)
VALUES = st.floats(min_value=0.0, max_value=64.0,
                   allow_nan=False, allow_infinity=False)


def _step_value(events, t, initial):
    """Step-series value at time t: last event at or before t."""
    cur = initial
    for et, ev in events:
        if et <= t:
            cur = ev
        else:
            break
    return cur


def _brute_force(events, t0, t1, initial):
    """Exact area: split [t0, t1] at every event timestamp and sum the
    constant rectangles (sampling each piece just after its left edge)."""
    cuts = sorted({t0, t1, *(t for t, _ in events if t0 < t < t1)})
    area = 0.0
    for a, b in zip(cuts, cuts[1:]):
        area += _step_value(events, a, initial) * (b - a)
    return area


def _sorted_events(draw_events):
    """Order by time; later duplicates win, matching _coalesce semantics."""
    out = []
    for t, v in sorted(draw_events, key=lambda e: e[0]):
        _coalesce(out, t, v)
    return out


@settings(max_examples=300, deadline=None)
@given(events=st.lists(st.tuples(TIMES, VALUES), max_size=12),
       t0=TIMES, t1=TIMES, initial=VALUES)
def test_integrate_matches_brute_force(events, t0, t1, initial):
    if t1 < t0:
        t0, t1 = t1, t0
    evs = _sorted_events(events)
    got = _integrate(evs, t0, t1, initial)
    want = _brute_force(evs, t0, t1, initial)
    assert got == pytest.approx(want, rel=1e-9, abs=1e-7)


@settings(max_examples=100, deadline=None)
@given(events=st.lists(st.tuples(TIMES, VALUES), max_size=8), t=TIMES,
       initial=VALUES)
def test_integrate_zero_width_window_is_zero(events, t, initial):
    assert _integrate(_sorted_events(events), t, t, initial) == 0.0


@settings(max_examples=100, deadline=None)
@given(events=st.lists(st.tuples(TIMES, VALUES), max_size=8),
       t0=TIMES, t1=TIMES, initial=VALUES)
def test_integrate_additive_over_split(events, t0, t1, initial):
    """∫[t0,t1] == ∫[t0,mid] + ∫[mid,t1] — no area lost at the seam."""
    if t1 < t0:
        t0, t1 = t1, t0
    mid = (t0 + t1) / 2.0
    evs = _sorted_events(events)
    whole = _integrate(evs, t0, t1, initial)
    parts = (_integrate(evs, t0, mid, initial)
             + _integrate(evs, mid, t1, initial))
    assert whole == pytest.approx(parts, rel=1e-9, abs=1e-7)


@settings(max_examples=100, deadline=None)
@given(draws=st.lists(st.tuples(TIMES, VALUES), min_size=1, max_size=20))
def test_coalesce_keeps_last_value_per_timestamp(draws):
    series = []
    for t, v in sorted(draws, key=lambda e: e[0]):
        _coalesce(series, t, v)
    # strictly increasing timestamps, each carrying the LAST value drawn
    assert all(a < b for (a, _), (b, _) in zip(series, series[1:]))
    last = {}
    for t, v in sorted(draws, key=lambda e: e[0]):
        last[t] = v
    assert series == sorted(last.items())


@settings(max_examples=100, deadline=None)
@given(events=st.lists(
    st.tuples(st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
              st.integers(min_value=0, max_value=32)), max_size=10),
    t1=st.floats(min_value=1.0, max_value=100.0, allow_nan=False))
def test_utilization_log_average_bounded(events, t1):
    log = UtilizationLog(total_slots=32)
    for t, used in sorted(events, key=lambda e: e[0]):
        log.record(t, used)
    avg = log.average(0.0, t1)
    assert 0.0 <= avg <= 1.0 + 1e-9
