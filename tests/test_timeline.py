"""Golden-output and consistency tests for the text Gantt renderer
(repro.obs.timeline): exact rows for hand-built traces (overlapping jobs,
preemption gaps, event markers), the empty-run edge cases, and a seeded
randomized check that every rendered bar maps to a real span of the job's
lifecycle (the hypothesis twin lives in test_timeline_properties.py).
"""
import random

from repro.obs.timeline import render, render_last_run

# ---------------------------------------------------------------------------
# shared reference model: independent re-derivation of per-job state spans
# ---------------------------------------------------------------------------


def job_intervals(records):
    """job -> {queued: [(a,b)], running: [(a,b)], marks: [(char, t)]} derived
    from the record stream by a tiny state machine that shares no code with
    the renderer.  Open states are closed at +inf."""
    out = {}
    for r in records:
        kind = r.get("kind", "")
        if not kind.startswith("job_") or "job" not in r:
            continue
        st = out.setdefault(r["job"], {"queued": [], "running": [],
                                       "marks": [], "_state": None,
                                       "_since": None})
        t = r["t"]

        def flip(new, st=st, t=t):
            if st["_state"] is not None:
                st[st["_state"]].append((st["_since"], t))
            st["_state"], st["_since"] = new, t

        if kind in ("job_submit", "job_queue"):
            flip("queued")
        elif kind == "job_start":
            flip("running")
        elif kind in ("job_preempt", "job_fail"):
            st["marks"].append(("x", t))
            flip("queued")
        elif kind == "job_complete":
            flip(None)
        elif kind == "job_rescale":
            st["marks"].append(("*", t))
        elif kind == "job_migrate":
            st["marks"].append((">", t))
    for st in out.values():
        if st["_state"] is not None:
            st[st["_state"]].append((st["_since"], float("inf")))
    return out


def check_bars_map_to_spans(records, width):
    """Render and assert every non-blank cell corresponds to a real span or
    event of that job in the cell's time bucket."""
    art = render(records, width=width)
    lines = art.splitlines()
    job_recs = [r for r in records
                if r.get("kind", "").startswith("job_") and "job" in r]
    t0 = min(r["t"] for r in job_recs)
    t1 = max(r["t"] for r in records if "t" in r)
    dt = max((t1 - t0) / width, 1e-9)
    ref = job_intervals(records)
    order, seen = [], set()
    for r in job_recs:
        if r["job"] not in seen:
            seen.add(r["job"])
            order.append(r["job"])
    eps = dt * 1e-6 + 1e-9
    for job, line in zip(order, lines[1:]):
        row = line.split("|")[1]
        assert len(row) == width
        for i, ch in enumerate(row):
            lo, hi = t0 + i * dt, t0 + (i + 1) * dt
            if ch == "#":
                assert any(a <= hi + eps and b >= lo - eps
                           for a, b in ref[job]["running"]), \
                    f"{job}: '#' at col {i} maps to no running span"
            elif ch == ".":
                assert any(a <= hi + eps and b >= lo - eps
                           for a, b in ref[job]["queued"]), \
                    f"{job}: '.' at col {i} maps to no queued span"
            elif ch in "x*>":
                assert any(m == ch and lo - eps <= t <= hi + eps
                           for m, t in ref[job]["marks"]), \
                    f"{job}: '{ch}' at col {i} maps to no event"
            else:
                assert ch == " "
    return art


# ---------------------------------------------------------------------------
# golden outputs
# ---------------------------------------------------------------------------


def _overlap_trace():
    return [
        {"kind": "run_start", "t": 0.0, "run": 1, "slots": 8},
        {"kind": "job_submit", "t": 0.0, "job": "a"},
        {"kind": "job_start", "t": 0.0, "job": "a", "slots": 4},
        {"kind": "job_submit", "t": 4.0, "job": "b"},
        {"kind": "job_start", "t": 8.0, "job": "b", "slots": 4},
        {"kind": "job_complete", "t": 8.0, "job": "a", "slots": 4},
        {"kind": "job_complete", "t": 16.0, "job": "b", "slots": 4},
        {"kind": "run_end", "t": 16.0},
    ]


def test_golden_overlapping_jobs():
    art = render(_overlap_trace(), width=16)
    lines = art.splitlines()
    assert lines[0].startswith("timeline t0=0.0s t1=16.0s")
    assert lines[1] == "       a |########        |"
    assert lines[2] == "       b |    ....####### |"
    assert lines[3] == "capacity |9999999999999999|"
    assert len(lines) == 4              # no kill row without kills


def test_golden_preemption_gap_and_markers():
    records = [
        {"kind": "run_start", "t": 0.0, "run": 1, "slots": 8},
        {"kind": "job_submit", "t": 0.0, "job": "p"},
        {"kind": "job_start", "t": 0.0, "job": "p", "slots": 8},
        {"kind": "job_preempt", "t": 4.0, "job": "p", "slots": 8,
         "ckpt_s": 1.0},
        {"kind": "job_start", "t": 8.0, "job": "p", "slots": 8,
         "resume": True, "overhead_s": 1.0},
        {"kind": "job_rescale", "t": 10.0, "job": "p", "from": 8, "to": 4,
         "overhead_s": 0.5},
        {"kind": "job_complete", "t": 12.0, "job": "p", "slots": 4},
        {"kind": "run_end", "t": 16.0},
    ]
    art = render(records, width=16)
    row = art.splitlines()[1]
    # run, preempt marker, queued gap, resumed run with rescale marker, idle
    assert row == "       p |####x...##*#    |"
    check_bars_map_to_spans(records, width=16)


def test_golden_kill_rows():
    records = _overlap_trace() + [
        {"kind": "spot_kill", "t": 6.0, "node": "n1", "slots": 8,
         "residents": {}},
        {"kind": "zone_reclaim", "t": 12.0, "zone": "z", "victims": []},
    ]
    records.sort(key=lambda r: r.get("t", 0.0))
    lines = render(records, width=16).splitlines()
    kills = next(ln for ln in lines if ln.lstrip().startswith("kills"))
    assert kills.split("|")[1] == "      K     Z   "


def test_empty_and_degenerate_runs():
    assert render([]) == "(no job records in trace)"
    assert render([{"kind": "run_start", "t": 0.0, "run": 1, "slots": 4},
                   {"kind": "run_end", "t": 9.0}]) \
        == "(no job records in trace)"
    assert render_last_run([]) == "(no runs in trace)"
    # zero-width run: everything at one instant must not divide by zero
    instant = [
        {"kind": "run_start", "t": 5.0, "run": 1, "slots": 4},
        {"kind": "job_submit", "t": 5.0, "job": "z"},
        {"kind": "job_start", "t": 5.0, "job": "z", "slots": 4},
        {"kind": "job_complete", "t": 5.0, "job": "z", "slots": 4},
        {"kind": "run_end", "t": 5.0},
    ]
    art = render(instant, width=12)
    assert "timeline" in art and "z" in art


def test_never_started_job_renders_queued_to_the_end():
    records = [
        {"kind": "run_start", "t": 0.0, "run": 1, "slots": 4},
        {"kind": "job_submit", "t": 0.0, "job": "stuck"},
        {"kind": "job_submit", "t": 0.0, "job": "ok"},
        {"kind": "job_start", "t": 0.0, "job": "ok", "slots": 4},
        {"kind": "job_complete", "t": 8.0, "job": "ok", "slots": 4},
        {"kind": "run_end", "t": 8.0},
    ]
    art = check_bars_map_to_spans(records, width=8)
    stuck = next(ln for ln in art.splitlines()
                 if ln.lstrip().startswith("stuck"))
    assert stuck.split("|")[1] == "........"


# ---------------------------------------------------------------------------
# seeded randomized property (deterministic; no hypothesis needed)
# ---------------------------------------------------------------------------


def random_job_trace(rng):
    """Synthesize one run: 1-5 jobs, some preempted once, one possibly never
    started.  Returns time-sorted records."""
    records = [{"kind": "run_start", "t": 0.0, "run": 1, "slots": 16}]
    per_job = []
    for i in range(rng.randint(1, 5)):
        job = f"j{i}"
        t = rng.uniform(0.0, 100.0)
        evs = [{"kind": "job_submit", "t": t, "job": job}]
        if rng.random() < 0.15:
            per_job.append(evs)         # never starts: queued forever
            continue
        t += rng.uniform(0.0, 30.0)
        evs.append({"kind": "job_start", "t": t, "job": job, "slots": 4})
        if rng.random() < 0.4:
            t += rng.uniform(1.0, 50.0)
            evs.append({"kind": "job_preempt", "t": t, "job": job,
                        "slots": 4, "ckpt_s": 1.0})
            t += rng.uniform(1.0, 40.0)
            evs.append({"kind": "job_start", "t": t, "job": job, "slots": 4,
                        "resume": True, "overhead_s": 2.0})
        if rng.random() < 0.5:
            t += rng.uniform(1.0, 30.0)
            evs.append({"kind": "job_rescale", "t": t, "job": job,
                        "from": 4, "to": 8, "overhead_s": 1.0})
        t += rng.uniform(1.0, 80.0)
        evs.append({"kind": "job_complete", "t": t, "job": job, "slots": 4})
        per_job.append(evs)
    flat = [e for evs in per_job for e in evs]
    flat.sort(key=lambda r: r["t"])     # stable: per-job order survives
    records.extend(flat)
    records.append({"kind": "run_end",
                    "t": max(r["t"] for r in records) + rng.uniform(0, 10)})
    return records


def test_random_traces_bars_map_to_spans():
    rng = random.Random(1234)
    for _ in range(60):
        records = random_job_trace(rng)
        for width in (13, 40, 72):
            check_bars_map_to_spans(records, width)
