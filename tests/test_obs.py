"""Observability subsystem (repro.obs): streaming P2 percentiles, the JSONL
flight recorder + install() hook, decision-audit completeness (every bidder
zone flip and every spot-kill victim has a matching structured record), and
the machine-readable ScheduleMetrics surface the benchmark tables emit."""
import json

import numpy as np
import pytest

from repro.cloud import (SPOT, AutoscalerConfig, BidderConfig, CloudProvider,
                         CloudSimulator, DemandAwareBidder, NodeAutoscaler,
                         NodePool)
from repro.core.autoscale import PreemptingPolicy
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import (SimWorkload, Simulator, make_jacobi_jobs,
                                  run_variant)
from repro.obs import (NULL_TRACER, Counters, LatencyRecorder, P2Quantile,
                       Tracer, current_tracer, decision_records, install)


def wl(steps=100.0, t1=1.0, t_many=1.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t1), (64.0, t_many))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


# ---------------------------------------------------------------------------
# P2 streaming quantiles
# ---------------------------------------------------------------------------

def test_p2_exact_for_small_samples():
    est = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0):
        est.observe(x)
    assert est.value() == 3.0           # exact median of {1,3,5}
    assert est.count == 3


def test_p2_empty_is_zero():
    assert P2Quantile(0.99).value() == 0.0


@pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
def test_p2_tracks_uniform_distribution(q):
    rng = np.random.default_rng(42)
    xs = rng.uniform(0.0, 100.0, size=20_000)
    est = P2Quantile(q)
    for x in xs:
        est.observe(float(x))
    exact = float(np.quantile(xs, q))
    assert est.value() == pytest.approx(exact, abs=2.5)


def test_p2_tracks_heavy_tail():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(mean=3.0, sigma=1.0, size=20_000)
    est = P2Quantile(0.95)
    for x in xs:
        est.observe(float(x))
    exact = float(np.quantile(xs, 0.95))
    assert est.value() == pytest.approx(exact, rel=0.08)


def test_counters_registry():
    c = Counters()
    c.inc("events")
    c.inc("events", 2)
    assert c.get("events") == 3
    assert c.get("missing") == 0
    assert c.as_dict() == {"events": 3}


def test_latency_recorder_prio_classes():
    class J:
        pass
    rec = LatencyRecorder()
    rec.mark_queued("a", 0.0)
    rec.mark_started("a", 10.0)
    job = J()
    job.job_id = "a"
    job.spec = J()
    job.spec.priority = 5
    job.spec.submit_time = 0.0
    job.start_time = 10.0
    job.end_time = 30.0
    rec.observe_completed(job)
    fields = rec.percentile_fields()
    assert fields["resp_p99"] == 10.0
    assert fields["compl_p50_prio5"] == 30.0
    assert fields["wait_p95"] == 10.0


# ---------------------------------------------------------------------------
# Tracer + install hook
# ---------------------------------------------------------------------------

def test_tracer_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with Tracer(path) as tr:
        tr.emit("run_start", t=0.0, run=tr.next_run_id(), slots=8)
        tr.emit("job_start", t=1.5, job="j0", slots=4)
    records = Tracer.load(path)
    assert records == [
        {"kind": "run_start", "t": 0.0, "run": 1, "slots": 8},
        {"kind": "job_start", "t": 1.5, "job": "j0", "slots": 4}]


def test_install_scopes_and_restores():
    assert current_tracer() is NULL_TRACER
    tr = Tracer()                        # in-memory
    with install(tr):
        assert current_tracer() is tr
        inner = Tracer()
        with install(inner):
            assert current_tracer() is inner
        assert current_tracer() is tr
    assert current_tracer() is NULL_TRACER


def test_null_tracer_is_inert():
    NULL_TRACER.emit("anything", t=1.0, x=2)
    assert NULL_TRACER.next_run_id() == 0
    assert not NULL_TRACER.enabled


def test_simulator_picks_up_installed_tracer():
    specs = make_jacobi_jobs(seed=3, n_jobs=4, submission_gap=60.0)
    with install(Tracer()) as tr:
        run_variant("elastic", specs, total_slots=32)
    kinds = {r["kind"] for r in tr.records}
    assert {"run_start", "job_submit", "job_start", "job_complete",
            "run_end"} <= kinds
    # untraced runs stay silent
    run_variant("elastic", specs, total_slots=32)
    assert current_tracer() is NULL_TRACER


# ---------------------------------------------------------------------------
# ScheduleMetrics machine-readable surface
# ---------------------------------------------------------------------------

def test_metrics_percentiles_and_counters_populated():
    specs = make_jacobi_jobs(seed=7, n_jobs=8, submission_gap=60.0)
    m = run_variant("elastic", specs, total_slots=32)
    assert m.counters["completions"] == 8
    assert m.counters["events"] > 0
    assert "resp_p99" in m.percentiles
    assert "wait_p50" in m.percentiles
    # at least one per-priority class key rides along
    assert any(k.startswith("resp_p99_prio") for k in m.percentiles)
    # percentile ordering is internally consistent
    assert m.percentiles["resp_p50"] <= m.percentiles["resp_p99"] + 1e-9


def test_metrics_to_dict_is_json_safe():
    specs = make_jacobi_jobs(seed=7, n_jobs=4, submission_gap=60.0)
    m = run_variant("elastic", specs, total_slots=32)
    d = m.to_dict()
    assert d["rescale_count"] == m.rescale_count
    assert d["percentiles"] == m.percentiles
    json.dumps(d)                        # round-trippable


def test_metrics_kv_flattens_and_skips_missing():
    from benchmarks.common import metrics_kv
    specs = make_jacobi_jobs(seed=7, n_jobs=4, submission_gap=60.0)
    m = run_variant("elastic", specs, total_slots=32)
    s = metrics_kv(m, "total_time", "percentiles.resp_p99",
                   "percentiles.no_such_key", prefixes=("counters.events",))
    assert "total_time=" in s and "resp_p99=" in s and "events=" in s
    assert "no_such_key" not in s


# ---------------------------------------------------------------------------
# Decision-audit records
# ---------------------------------------------------------------------------

def test_admit_and_redistribute_decisions_recorded():
    specs = make_jacobi_jobs(seed=7, n_jobs=8, submission_gap=60.0)
    with install(Tracer()) as tr:
        run_variant("elastic", specs, total_slots=32)
    admits = decision_records(tr.records, "admit")
    assert len(admits) == 8              # one verdict per submitted job
    for d in admits:
        assert d["verdict"] in ("start", "enqueue", "enqueue_raced",
                                "start_after_shrink")
        assert {"job", "priority", "free", "min", "max"} <= set(d["inputs"])
    # a 32-slot cluster under 8 jobs redistributes at least once
    assert decision_records(tr.records, "redistribute")


def test_preempt_select_decision_names_victims():
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim_tr = Tracer()
    with install(sim_tr):
        sim = Simulator(8, pcfg)
        sim.policy = PreemptingPolicy(pcfg)
        sim.submit(JobSpec("lo", 1, 8, 8, 0.0), wl(100))
        sim.submit(JobSpec("hi", 5, 8, 8, 1.0), wl(50))
        sim.run()
    sel = decision_records(sim_tr.records, "preempt_select")
    assert len(sel) == 1
    d = sel[0]
    assert d["verdict"] == "preempted_started"
    assert d["inputs"]["job"] == "hi"
    assert d["inputs"]["victims"] == ["lo"]
    assert any(a.get("eligible") for a in d["alternatives"])


def _bidding_sim(tracer=None):
    """Three-zone fleet with one hot zone (table6's one_hot in miniature)."""
    pools = [NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                      boot_latency=60.0, teardown_delay=30.0,
                      initial_nodes=1, max_nodes=2, zone="east-1a")]
    for zone, init in (("east-1b", 1), ("east-1c", 1)):
        pools.append(NodePool(
            f"sp-{zone}", slots_per_node=8, price_per_slot_hour=0.016,
            market=SPOT, boot_latency=60.0, teardown_delay=30.0,
            initial_nodes=init, max_nodes=4, spot_lifetime_mean=1e12,
            zone=zone))
    prov = CloudProvider(
        pools, seed=3,
        zone_reclaim_interval={"east-1b": 300.0}, zone_reclaim_fraction=1.0)
    bidder = DemandAwareBidder(BidderConfig(
        half_life=900.0, hysteresis=0.25, risk_aversion=10.0,
        min_evidence_kills=1.0, spot_fraction_max=0.5))
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=240.0, spot_fraction=0.6, bidder=bidder))
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(prov, pcfg, autoscaler=asc, tracer=tracer)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1 + i % 3, 8, 8, 60.0 * i), wl(1500))
    return sim


def test_every_bid_flip_has_a_decision_record_with_risk_inputs():
    tr = Tracer()
    sim = _bidding_sim(tracer=tr)
    sim.run()
    flips = decision_records(tr.records, "bid_flip")
    assert sim.bidder.adjustments > 0, "scenario must exercise the bidder"
    assert len(flips) == sim.bidder.adjustments
    for d in flips:
        assert d["verdict"] in ("open", "close")
        ins = d["inputs"]
        # the flip carries the risk-vs-discount evidence that triggered it
        assert {"zone", "risk_ratio", "risk_cost_rate", "kill_rate",
                "savings_rate", "close_above", "open_below"} <= set(ins)
    # the hot zone closes at least once under 300 s whole-zone wipes
    assert any(d["verdict"] == "close" and
               d["inputs"]["zone"] == "east-1b" for d in flips)


def test_scale_decisions_record_preference_and_attempts():
    tr = Tracer()
    sim = _bidding_sim(tracer=tr)
    sim.run()
    ups = decision_records(tr.records, "scale_up")
    assert ups
    for d in ups:
        assert d["verdict"] in ("provisioned", "blocked")
        assert isinstance(d["inputs"]["preference"], list)
        assert d["alternatives"] is None or isinstance(d["alternatives"], list)


# ---------------------------------------------------------------------------
# Per-victim kill-blast spans
# ---------------------------------------------------------------------------

def test_every_spot_kill_victim_has_a_resolution_span():
    tr = Tracer()
    sim = _bidding_sim(tracer=tr)
    sim.run()
    kills = [r for r in tr.records if r["kind"] == "spot_kill"]
    assert kills, "scenario must produce spot kills"
    recs = tr.records
    resolved_kinds = ("job_migrate", "job_rescale", "job_preempt", "job_fail",
                      "job_complete")
    saw_victim = False
    for k in kills:
        i = recs.index(k)
        end = next(j for j in range(i + 1, len(recs))
                   if recs[j]["kind"] == "kill_blast_end"
                   and recs[j]["node"] == k["node"])
        window = recs[i + 1:end]
        for victim in k["residents"]:
            saw_victim = True
            assert any(r["kind"] in resolved_kinds and r.get("job") == victim
                       for r in window), \
                f"victim {victim} of {k['node']} has no resolution span"
    assert saw_victim, "at least one kill must displace a resident"


def test_timeline_renders_traced_run():
    from repro.obs.timeline import render_last_run
    specs = make_jacobi_jobs(seed=7, n_jobs=6, submission_gap=60.0)
    with install(Tracer()) as tr:
        run_variant("elastic", specs, total_slots=32)
    art = render_last_run(tr.records, width=48)
    assert "timeline" in art and "capacity" in art
    assert "#" in art                     # at least one job ran
    for s in specs:
        assert s.job_id[:20] in art
