"""Per-arch smoke tests (reduced configs, one fwd/train step on CPU) and
model-level correctness: decode == teacher forcing, MoE gather == dense."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ~3 min of per-arch jit compiles: slow lane (CI runs it non-blocking)
pytestmark = pytest.mark.slow

from repro.configs import ALL_ARCHS, smoke_config
from repro.models import (decode_step, forward_hidden, init_params, loss_fn,
                          pad_cache, prefill)
from repro.models.model import _head_weight
from repro.models.moe import moe_forward, set_moe_impl

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
    if cfg.enc_layers:
        b["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32)
    return b


@pytest.mark.parametrize("arch", sorted(ALL_ARCHS))
def test_smoke_forward_and_train_step(arch):
    """Assignment requirement: reduced config, one forward/train step on CPU,
    output shapes + no NaNs."""
    cfg = smoke_config(arch).with_(dtype="float32")
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    hidden, aux = forward_hidden(cfg, params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-1.3b", "jamba-v0.1-52b",
                                  "deepseek-v2-236b", "chameleon-34b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_teacher_forcing(arch):
    """prefill + step-by-step decode reproduces the full-forward logits
    (with the exact dense-MoE path — capacity dispatch is batch-dependent)."""
    set_moe_impl("dense")
    try:
        cfg = smoke_config(arch).with_(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(42))
        B, S, S0 = 2, 16, 8
        toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                  cfg.vocab_size)
        fb = {"tokens": toks, "labels": toks}
        if cfg.enc_layers:
            fb["enc_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model))
        hid, _ = forward_hidden(cfg, params, fb, mode="train")
        full = jnp.einsum("bsd,dv->bsv", hid,
                          _head_weight(cfg, params))[..., :cfg.vocab_size]
        pb = {"tokens": toks[:, :S0]}
        if cfg.enc_layers:
            pb["enc_embeds"] = fb["enc_embeds"]
        cache, logits = prefill(cfg, params, pb)
        cache = pad_cache(cfg, cache, S0, S)
        errs = [float(jnp.max(jnp.abs(logits - full[:, S0 - 1])))]
        for t in range(S0, S):
            logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                        jnp.int32(t))
            errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
        assert max(errs) < 2e-4, errs
    finally:
        set_moe_impl("gather")


def test_moe_gather_matches_dense_at_high_capacity():
    cfg = smoke_config("granite-moe-3b-a800m").with_(dtype="float32")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = init_params(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["decoder"]["blocks"])["sub0"]["ff"]
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.3
    set_moe_impl("dense")
    yd, auxd = moe_forward(cfg, p, x)
    set_moe_impl("gather")
    yg, auxg = moe_forward(cfg, p, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg), atol=1e-5,
                               rtol=1e-5)
    assert float(abs(auxd - auxg)) < 1e-6


def test_moe_capacity_drops_tokens():
    """At low capacity the gather path drops overflow tokens (GShard-style);
    output differs from dense but stays finite."""
    cfg = smoke_config("granite-moe-3b-a800m").with_(dtype="float32")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = init_params(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["decoder"]["blocks"])["sub0"]["ff"]
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.3
    y, aux = moe_forward(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_router_aux_loss_balanced_vs_collapsed():
    from repro.models.moe import load_balance_loss
    E = 8
    probs_bal = jnp.full((4, 16, E), 1.0 / E)
    ids_bal = jnp.tile(jnp.arange(E)[None, None, :2], (4, 16, 1)) + \
        (jnp.arange(16) % E)[None, :, None]
    ids_bal = ids_bal % E
    probs_col = jnp.zeros((4, 16, E)).at[..., 0].set(1.0)
    ids_col = jnp.zeros((4, 16, 2), jnp.int32)
    bal = load_balance_loss(probs_bal, ids_bal, E)
    col = load_balance_loss(probs_col, ids_col, E)
    assert float(col) > float(bal)
    assert float(bal) == pytest.approx(1.0, rel=1e-5)


def test_rope_relative_position_property():
    """RoPE inner products depend only on relative distance."""
    from repro.models.layers import apply_rope
    hd = 32
    q = jax.random.normal(KEY, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def score(pq, pk):
        qr = apply_rope(q, jnp.array([pq]), 10_000.0)
        kr = apply_rope(k, jnp.array([pk]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert score(5, 3) == pytest.approx(score(105, 103), abs=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), abs=1e-4)


def test_loss_decreases_on_learnable_stream():
    """Few steps of AdamW on the synthetic stream reduce loss."""
    from repro.core.elastic import ElasticTrainer, TrainJobConfig
    cfg = smoke_config("yi-6b")
    tr = ElasticTrainer(cfg, TrainJobConfig(global_batch=4, seq_len=32,
                                            total_steps=15, seed=0),
                        jax.devices()[:1])
    first = tr.step()["loss"]
    for _ in range(14):
        last = tr.step()["loss"]
    assert last < first


def test_vocab_padding_masked_in_loss():
    """Padded vocab columns must not affect the loss."""
    cfg = smoke_config("yi-6b").with_(dtype="float32", vocab_pad_to=1)
    cfg_pad = cfg.with_(vocab_pad_to=96)
    assert cfg_pad.padded_vocab > cfg.vocab_size
    params = init_params(cfg, KEY)
    params_pad = init_params(cfg_pad, KEY)
    # overwrite the padded model's valid rows with the unpadded weights
    params_pad["embed"] = params_pad["embed"].at[:cfg.vocab_size].set(
        params["embed"])
    params_pad["lm_head"] = params_pad["lm_head"].at[:, :cfg.vocab_size].set(
        params["lm_head"])
    for k_ in ("decoder", "final_norm"):
        params_pad[k_] = params[k_]
    batch = _batch(cfg)
    l1, _ = loss_fn(cfg, params, batch)
    l2, _ = loss_fn(cfg_pad, params_pad, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
