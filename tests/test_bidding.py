"""Demand-aware spot bidding: the SpotRiskLedger (decay math, zone
attribution, transfer folding), the DemandAwareBidder (shares follow observed
risk, hysteresis band, priors, caps), the autoscaler wiring (per-zone quota
math backfill, the zero-open-zones fix, bidder-driven preference), and the
CloudSimulator feed (kills/resumes/transfers -> ledger; metrics surface).
"""
import math
import types

import pytest

from repro.cloud import (SPOT, AutoscalerConfig, BidderConfig, CloudProvider,
                         CloudSimulator, DemandAwareBidder, NodeAutoscaler,
                         NodeAutoscalerConfig, NodePool, SpotRiskLedger)
from repro.core.job import JobSpec, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload

PCFG = PolicyConfig(rescale_gap=0.0)
HL = 1000.0
LAM = math.log(2.0) / HL


def wl(steps=100.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


def two_zone_provider(**kw):
    """od anchor (0.048) + two equal spot zones (0.016): discount rate per
    8-slot node = 0.032 * 8 / 3600 $/s."""
    return CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 initial_nodes=1, max_nodes=4, zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, max_nodes=4, spot_lifetime_mean=1e12,
                 zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, max_nodes=4, spot_lifetime_mean=1e12,
                 zone="east-1c"),
    ], **kw)


SAVINGS_PER_NODE = 0.032 * 8 / 3600.0      # $/s one spot node saves vs od


def dollars_for_ratio(ratio):
    """Decayed-dollar tally that makes cost_rate / savings_rate == ratio
    for a one-node zone of the two_zone_provider at the record time."""
    return ratio * SAVINGS_PER_NODE / LAM


# ---------------------------------------------------------------------------
# SpotRiskLedger
# ---------------------------------------------------------------------------

def test_ledger_kill_rate_is_decayed_count_over_window():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0)
    assert led.kill_rate("z", 0.0) == pytest.approx(LAM)
    assert led.kill_rate("z", HL) == pytest.approx(LAM / 2.0)
    assert led.kill_rate("z", 2 * HL) == pytest.approx(LAM / 4.0)


def test_ledger_cost_decays_with_the_same_half_life():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0, dollars=8.0)
    assert led.cost_rate("z", 0.0) == pytest.approx(8.0 * LAM)
    assert led.cost_rate("z", 3 * HL) == pytest.approx(LAM)  # 8 -> 1


def test_ledger_records_accumulate_between_decays():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0, dollars=4.0)
    led.record_kill("z", HL, dollars=2.0)        # 4/2 + 2 = 4 at t=HL
    assert led.cost_rate("z", HL) == pytest.approx(4.0 * LAM)


def test_ledger_zone_attribution_is_isolated():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("a", 10.0, dollars=5.0)
    assert led.kill_rate("b", 10.0) == 0.0
    assert led.cost_rate("b", 10.0) == 0.0
    assert not led.observed("b")
    assert led.observed("a")
    assert led.totals("b").kills == 0


def test_ledger_transfer_dollars_fold_into_rate_but_stay_itemized():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0, dollars=1.0)
    led.record_cost("z", 0.0, dollars=2.0, transfer_dollars=0.5)
    t = led.totals("z")
    assert t.dollars == pytest.approx(3.0)
    assert t.transfer_dollars == pytest.approx(0.5)
    assert t.total_dollars == pytest.approx(3.5)
    # the decision rate sees transfer dollars too (the kill caused them)
    assert led.cost_rate("z", 0.0) == pytest.approx(3.5 * LAM)


def test_ledger_audit_totals_never_decay():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0, dollars=2.0, lost_seconds=30.0)
    led.record_cost("z", 50 * HL, dollars=1.0, lost_seconds=10.0)
    t = led.totals("z")
    assert (t.kills, t.dollars, t.lost_s) == (1, pytest.approx(3.0),
                                              pytest.approx(40.0))
    assert led.cost_rate("z", 50 * HL) == pytest.approx(1.0 * LAM, rel=1e-6)


def test_ledger_batch_kills_count_nodes():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", 0.0, nodes=3)
    assert led.totals("z").kills == 3
    assert led.kill_rate("z", 0.0) == pytest.approx(3 * LAM)


def test_ledger_out_of_order_record_folds_in_without_negative_decay():
    led = SpotRiskLedger(half_life=HL)
    led.record_kill("z", HL, dollars=1.0)
    led.record_kill("z", 0.0, dollars=1.0)       # late-arriving older event
    t = led.totals("z")
    assert t.kills == 2 and t.dollars == pytest.approx(2.0)
    # folded at current decay level: no exp(+lambda*dt) amplification
    assert led.cost_rate("z", HL) == pytest.approx(2.0 * LAM)


# ---------------------------------------------------------------------------
# DemandAwareBidder shares
# ---------------------------------------------------------------------------

def _bidder(**kw):
    kw.setdefault("half_life", HL)
    return DemandAwareBidder(BidderConfig(**kw))


def test_zero_history_zones_get_the_prior_static_split():
    b = _bidder()
    prov = two_zone_provider()
    shares = b.zone_quotas(["east-1b", "east-1c"], 0.0, prov, 0.6)
    assert shares == {"east-1b": pytest.approx(0.3),
                      "east-1c": pytest.approx(0.3)}
    assert b.adjustments == 0


def test_prior_ratio_above_band_starts_zones_closed():
    b = _bidder(prior_ratio=2.0, hysteresis=0.25)
    prov = two_zone_provider()
    shares = b.zone_quotas(["east-1b", "east-1c"], 0.0, prov, 0.6)
    assert shares == {"east-1b": 0.0, "east-1c": 0.0}


def test_share_falls_when_observed_risk_outruns_the_discount():
    b = _bidder(hysteresis=0.25)
    prov = two_zone_provider()
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(3.0))
    shares = b.zone_quotas(["east-1b", "east-1c"], 0.0, prov, 0.6)
    # the risky zone closes; its share redistributes to the healthy zone
    assert shares["east-1b"] == 0.0
    assert shares["east-1c"] == pytest.approx(0.6)
    assert b.adjustments == 1


def test_share_recovers_once_risk_decays_below_the_band():
    b = _bidder(hysteresis=0.25)
    prov = two_zone_provider()
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(3.0))
    assert b.zone_quotas(["east-1b"], 0.0, prov, 0.6)["east-1b"] == 0.0
    # ratio 3 halves per half-life: after 3 half-lives it is 0.375 < 0.75
    later = 3 * HL
    shares = b.zone_quotas(["east-1b"], later, prov, 0.6)
    assert shares["east-1b"] == pytest.approx(0.6)
    assert b.adjustments == 2                    # close + reopen


def test_hysteresis_band_holds_state_between_thresholds():
    prov = two_zone_provider()
    # ratio 1.2 sits inside the band (1 +- 0.25): an open zone STAYS open
    b = _bidder(hysteresis=0.25)
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(1.2))
    assert b.zone_quotas(["east-1b"], 0.0, prov, 0.6)["east-1b"] > 0.0
    # a closed zone with ratio 0.9 (> 1 - 0.25) STAYS closed
    b2 = _bidder(hysteresis=0.25, prior_ratio=10.0)
    b2.zone_quotas(["east-1b"], 0.0, prov, 0.6)          # closes on prior
    b2.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(0.9))
    assert b2.zone_quotas(["east-1b"], 0.0, prov, 0.6)["east-1b"] == 0.0
    assert b2.adjustments == 1                   # the initial close only


def test_adjustments_count_once_per_flip_not_per_tick():
    b = _bidder(hysteresis=0.25)
    prov = two_zone_provider()
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(5.0))
    for t in (0.0, 10.0, 20.0, 30.0):
        b.zone_quotas(["east-1b", "east-1c"], t, prov, 0.6)
    assert b.adjustments == 1


def test_spot_fraction_max_caps_redistribution():
    b = _bidder(hysteresis=0.25, spot_fraction_max=0.4)
    prov = two_zone_provider()
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(3.0))
    shares = b.zone_quotas(["east-1b", "east-1c"], 0.0, prov, 0.6)
    # the survivor would inherit 0.6; the per-zone cap holds it at 0.4
    assert shares["east-1c"] == pytest.approx(0.4)


def test_all_zones_closed_emits_zero_everywhere():
    b = _bidder(hysteresis=0.25)
    prov = two_zone_provider()
    for z in ("east-1b", "east-1c"):
        b.ledger.record_kill(z, 0.0, dollars=dollars_for_ratio(4.0))
    shares = b.zone_quotas(["east-1b", "east-1c"], 0.0, prov, 0.6)
    assert shares == {"east-1b": 0.0, "east-1c": 0.0}


def test_risk_aversion_scales_the_observed_cost():
    prov = two_zone_provider()
    cautious = _bidder(risk_aversion=4.0)
    bold = _bidder(risk_aversion=1.0)
    for b in (cautious, bold):
        b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(0.5))
    assert cautious.risk_ratio("east-1b", 0.0, prov) == pytest.approx(2.0)
    assert bold.risk_ratio("east-1b", 0.0, prov) == pytest.approx(0.5)


def test_min_evidence_below_gate_holds_state_not_prior():
    """A zone whose decayed evidence falls under ``min_evidence_kills`` is
    NOT reclassified: one catastrophic kill is an anecdote (zone stays
    open), and a closed zone with no remaining exposure must not snap back
    to the open prior as its evidence decays."""
    prov = two_zone_provider()
    b = _bidder(min_evidence_kills=2.0, hysteresis=0.25)
    # 1 kill with huge dollars: dk=1 < 2 -> anecdote, stays open
    b.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(50.0))
    assert b.risk_ratio("east-1b", 0.0, prov) is None
    assert b.zone_quotas(["east-1b"], 0.0, prov, 0.6)["east-1b"] > 0.0
    # two more kills: evidence crosses the gate, the zone closes
    b.ledger.record_kill("east-1b", 1.0, nodes=2,
                         dollars=dollars_for_ratio(5.0))
    assert b.zone_quotas(["east-1b"], 1.0, prov, 0.6)["east-1b"] == 0.0
    # far later the evidence has decayed below the gate again (no exposure,
    # no new kills): the zone HOLDS closed instead of reopening on the prior
    later = 20 * HL
    assert b.ledger.decayed_kills("east-1b", later) < 2.0
    assert b.zone_quotas(["east-1b"], later, prov, 0.6)["east-1b"] == 0.0


def test_kill_cost_floor_is_the_replacement_boot_burn():
    b = _bidder()
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 initial_nodes=1, max_nodes=2, zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=300.0, max_nodes=2,
                 spot_lifetime_mean=1e12, zone="east-1b"),
    ])
    assert b.kill_cost_floor("east-1b", prov) == pytest.approx(
        0.016 * 8 * 300.0 / 3600.0)


def test_kill_frequency_alone_can_close_a_zone():
    """Kills that happened to hit empty nodes carry zero realized dollars,
    but their cadence (priced at the replacement boot burn) is still risk —
    the self-limiting hot zone must not look safe just because its nodes
    die before work lands on them."""
    prov = two_zone_provider()
    b = _bidder(risk_aversion=10.0, hysteresis=0.25)
    for k in range(6):                     # a kill every 100 s, $0 realized
        b.ledger.record_kill("east-1b", 100.0 * k)
    t = 500.0
    assert b.ledger.totals("east-1b").dollars == 0.0
    assert b.risk_ratio("east-1b", t, prov) > 1.25
    assert b.zone_quotas(["east-1b", "east-1c"], t, prov, 0.6) == {
        "east-1b": 0.0, "east-1c": pytest.approx(0.6)}


def test_savings_rate_floors_at_one_node_for_an_empty_zone():
    b = _bidder()
    prov = two_zone_provider()                   # no spot provisioned yet
    assert b.savings_rate("east-1b", prov) == pytest.approx(SAVINGS_PER_NODE)


def test_no_discount_plus_observed_cost_closes_the_zone():
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.016,
                 initial_nodes=1, max_nodes=2, zone="east-1a"),
        # spot NOT cheaper than on-demand: the "discount" buys nothing
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, max_nodes=2, spot_lifetime_mean=1e12,
                 zone="east-1b"),
    ])
    b = _bidder()
    b.ledger.record_kill("east-1b", 0.0, dollars=1e-6)
    assert b.risk_ratio("east-1b", 0.0, prov) == math.inf
    assert b.zone_quotas(["east-1b"], 0.0, prov, 0.6)["east-1b"] == 0.0


# ---------------------------------------------------------------------------
# _pool_preference quota math (backfill: previously only covered indirectly)
# ---------------------------------------------------------------------------

def _asc(prov, **cfg):
    return NodeAutoscaler(prov, AutoscalerConfig(**cfg))


def test_pool_preference_least_saturated_zone_first():
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, initial_nodes=2, max_nodes=8,
                 zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, initial_nodes=1, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, initial_nodes=0, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1c"),
    ])
    from repro.core.events import EventQueue
    prov.bootstrap(EventQueue())
    order = _asc(prov, spot_fraction=0.9)._pool_preference(0.0)
    # zone c holds nothing yet: least saturated, despite the higher price
    assert [p.name for p in order[:2]] == ["spot-c", "spot-b"]


def test_pool_preference_excludes_closed_zone_from_preferred():
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, initial_nodes=2, max_nodes=8,
                 zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, initial_nodes=1, max_nodes=1,    # frozen
                 spot_lifetime_mean=1e12, zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, initial_nodes=0, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1c"),
    ])
    from repro.core.events import EventQueue
    prov.bootstrap(EventQueue())
    order = _asc(prov, spot_fraction=0.9)._pool_preference(0.0)
    assert order[0].name == "spot-c"
    assert order[-1].name == "spot-b"            # saturated tail


def test_pool_preference_global_share_cap_blocks_all_spot():
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, initial_nodes=1, max_nodes=8,
                 zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, initial_nodes=1, max_nodes=4,
                 spot_lifetime_mean=1e12, zone="east-1b"),
    ])
    from repro.core.events import EventQueue
    prov.bootstrap(EventQueue())
    # spot already holds 1/2 the slots >= spot_fraction 0.5: od first
    order = _asc(prov, spot_fraction=0.5)._pool_preference(0.0)
    assert order[0].name == "od"


def test_zone_quotas_even_split_without_bidder():
    prov = two_zone_provider()
    asc = _asc(prov, spot_fraction=0.6)
    q = asc._zone_quotas({"east-1b", "east-1c"}, 0.0)
    assert q == {"east-1b": pytest.approx(0.3),
                 "east-1c": pytest.approx(0.3)}


def test_zero_open_zones_yield_zero_quotas_not_a_phantom_split():
    """The old ``spot_fraction / max(1, len(open_zones))`` treated ZERO open
    zones as one; a fully saturated spot fleet must produce no quota at
    all."""
    prov = two_zone_provider()
    asc = _asc(prov, spot_fraction=0.6)
    assert asc._zone_quotas(set(), 0.0) == {}


def test_fully_saturated_spot_fleet_provisions_no_spot():
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, initial_nodes=1, max_nodes=8,
                 zone="east-1a"),
        # every spot pool at max_nodes: zero OPEN zones
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, initial_nodes=2, max_nodes=2,
                 spot_lifetime_mean=1e12, zone="east-1b"),
    ])
    from repro.core.events import EventQueue
    q = EventQueue()
    prov.bootstrap(q)
    asc = _asc(prov, spot_fraction=0.9)
    order = asc._pool_preference(0.0)
    assert order[0].name == "od"                 # no spot preferred
    # and requesting through the preference can never mint a spot node
    assert prov.request_node("spot-b", 0.0, q) is None


def test_bidder_quota_feeds_pool_preference():
    prov = two_zone_provider()
    bidder = _bidder(hysteresis=0.25)
    bidder.ledger.record_kill("east-1b", 0.0,
                              dollars=dollars_for_ratio(5.0))
    asc = _asc(prov, spot_fraction=0.6, bidder=bidder)
    order = asc._pool_preference(0.0)
    # the risky zone's pool is no longer preferred; the healthy zone leads
    assert order[0].name == "spot-c"
    assert order[-1].name == "spot-b"


# ---------------------------------------------------------------------------
# CloudSimulator feed + metrics surface
# ---------------------------------------------------------------------------

def _kill_sim(bidder, *, od_boot=60.0):
    """One spot node in east-1b carrying a rigid job, killed at t=30; an od
    pool boots replacements so the victim resumes (and pays restore)."""
    prov = CloudProvider([
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=0.0, initial_nodes=1, max_nodes=1,
                 spot_lifetime_mean=1e12, region="east", zone="east-1b"),
        NodePool("od-w", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=od_boot, initial_nodes=0, max_nodes=2,
                 region="west", zone="west-2a"),
    ], seed=1, transfer_price_per_gb=0.02)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, bidder=bidder))
    sim = CloudSimulator(prov, PCFG, autoscaler=asc)
    sim.submit(JobSpec("a", 1, 8, 8, 0.0), wl(100, data=4e9))
    prov.inject_spot_kill(sorted(prov.nodes)[0], 30.0, sim.queue)
    return prov, sim


def test_spot_kill_feeds_ledger_with_zone_and_checkpoint_dollars():
    bidder = _bidder()
    prov, sim = _kill_sim(bidder)
    sim.run()
    t = bidder.ledger.totals("east-1b")
    assert t.kills == 1
    assert t.dollars > 0.0                       # ckpt write was priced
    assert bidder.ledger.totals("west-2a").kills == 0


def test_resume_attributes_outage_and_transfer_to_killing_zone():
    bidder = _bidder()
    prov, sim = _kill_sim(bidder)
    m = sim.run()
    assert sim.cluster.jobs["a"].preempt_count == 1
    assert sim.cluster.jobs["a"].status is JobStatus.COMPLETED
    t = bidder.ledger.totals("east-1b")
    # outage lost-work landed (kill -> resume gap x 8 slots > boot latency)
    assert t.lost_s >= 8 * 60.0
    # the east->west resume's transfer dollars folded into the SAME zone
    assert t.transfer_dollars == pytest.approx(m.transfer_cost)
    assert m.transfer_cost == pytest.approx(4.0 * 0.02)


def test_accountant_itemizes_preempt_overhead_without_inflating_total():
    prov, sim = _kill_sim(None)
    m = sim.run()
    r = sim.cost_report
    assert r.preempt_overhead_cost > 0.0
    assert r.preempt_overhead_costs["a"] == pytest.approx(
        r.preempt_overhead_cost)
    assert r.preempt_overhead_slot_s > 0.0
    # attribution, not an extra charge: the billing identity still holds
    assert r.total_cost == pytest.approx(
        r.used_cost + r.idle_cost + r.transfer_cost, abs=1e-9)
    assert m.preempt_overhead_cost == pytest.approx(r.preempt_overhead_cost)


def test_metrics_surface_spot_share_by_zone_and_bid_adjustments():
    bidder = _bidder(hysteresis=0.25)
    # poison one zone so the first tick closes it: at least one flip
    bidder.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(9.0))
    prov, sim = _kill_sim(bidder)
    m = sim.run()
    assert m.bid_adjustments >= 1
    assert "east-1b" in m.spot_share_by_zone
    assert 0.0 < m.spot_share_by_zone["east-1b"] <= 1.0
    # observed shares are a share of ALL billed slot-hours
    assert sum(m.spot_share_by_zone.values()) <= 1.0 + 1e-9


def test_saturated_zone_is_still_reclassified_each_tick():
    """A spot zone parked at max_nodes still takes kills; the per-tick
    bidder refresh must classify it anyway, so its state is current by the
    time the zone can grow again (an open-zones-only refresh would leave it
    stale-open and buy straight back into it)."""
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=30.0, initial_nodes=1, max_nodes=2,
                 zone="east-1a"),
        # saturated from t=0: never in the growable set
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, initial_nodes=1, max_nodes=1,
                 spot_lifetime_mean=1e12, zone="east-1b"),
    ], seed=2)
    bidder = _bidder(hysteresis=0.25)
    bidder.ledger.record_kill("east-1b", 0.0, dollars=dollars_for_ratio(9.0))
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, spot_fraction=0.6,
        bidder=bidder))
    sim = CloudSimulator(prov, PCFG, autoscaler=asc)
    sim.submit(JobSpec("a", 1, 4, 4, 0.0), wl(60))
    m = sim.run()
    assert bidder.is_open("east-1b") is False
    assert m.bid_adjustments == 1


def test_bidder_shifts_provisioning_away_from_poisoned_zone():
    prov = two_zone_provider(seed=5)
    bidder = _bidder(hysteresis=0.25)
    bidder.ledger.record_kill("east-1b", 0.0,
                              dollars=dollars_for_ratio(9.0))
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, spot_fraction=0.6,
        bidder=bidder))
    sim = CloudSimulator(prov, PCFG, autoscaler=asc)
    for i in range(4):
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, 0.0), wl(200))
    sim.run()
    assert prov.pool_census("spot-b") == 0       # closed zone never bought
    assert prov.pool_census("spot-c") >= 1       # healthy zone absorbed it


# ---------------------------------------------------------------------------
# Regression: the None-bidder path is byte-identical to the legacy code
# ---------------------------------------------------------------------------

def _legacy_pool_preference(self):
    """Verbatim copy of the pre-bidder `_pool_preference` (PR 4) — including
    its `max(1, len(open_zones))` quirk — as the reference the refactored
    None-bidder path must reproduce exactly."""
    from repro.cloud.provider import ON_DEMAND
    pools = sorted(self.provider.pools.values(),
                   key=lambda p: p.price_per_slot_hour)
    spot = [p for p in pools if p.market == SPOT]
    on_demand = [p for p in pools if p.market != SPOT]
    total = self.provider.market_slots(SPOT) + \
        self.provider.market_slots(ON_DEMAND)
    spot_share = self.provider.market_slots(SPOT) / total if total else 0.0
    open_zones = {p.zone for p in spot
                  if self.provider.pool_census(p.name) < p.max_nodes}
    quota = self.cfg.spot_fraction / max(1, len(open_zones))

    def zone_share(pool):
        return (self.provider.zone_slots(pool.zone, SPOT) / total
                if total else 0.0)
    preferred = sorted(
        (p for p in spot
         if p.zone in open_zones
         and spot_share < self.cfg.spot_fraction
         and zone_share(p) < quota),
        key=lambda p: (zone_share(p), p.price_per_slot_hour))
    saturated = [p for p in spot if p not in preferred]
    return preferred + on_demand + saturated


def _busy_zone_sim(seed, legacy=False):
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=120.0, initial_nodes=1, max_nodes=3,
                 region="east", zone="east-1a"),
        NodePool("spot-b", slots_per_node=8, price_per_slot_hour=0.012,
                 market=SPOT, boot_latency=120.0, initial_nodes=1,
                 max_nodes=3, spot_lifetime_mean=2400.0, region="east",
                 zone="east-1b"),
        NodePool("spot-c", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=120.0, initial_nodes=1,
                 max_nodes=3, spot_lifetime_mean=2400.0, region="west",
                 zone="west-2a"),
    ], seed=seed, zone_reclaim_interval=1500.0, zone_reclaim_fraction=0.5,
        region_price_multipliers={"west": 1.1})
    asc = NodeAutoscaler(prov, NodeAutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=180.0, spot_fraction=0.6))
    if legacy:
        asc._pool_preference = types.MethodType(
            lambda s, now=0.0: _legacy_pool_preference(s), asc)
    sim = CloudSimulator(prov, PCFG, autoscaler=asc, placement="zone_spread")
    for i in range(10):
        sim.submit(JobSpec(f"j{i}", 1 + i % 3, 4, 12, float(i * 120)),
                   wl(300, data=2e9))
    return sim


@pytest.mark.parametrize("seed", [3, 11])
def test_none_bidder_path_is_byte_identical_to_legacy(seed):
    """An identical seed/trace through the refactored autoscaler with the
    bidder slot left empty must reproduce the legacy `_pool_preference`
    run EXACTLY (metrics repr compared byte-for-byte) — the quota refactor
    may not perturb the default path."""
    m_new = _busy_zone_sim(seed, legacy=False).run()
    m_old = _busy_zone_sim(seed, legacy=True).run()
    assert repr(m_new) == repr(m_old)


def test_bidder_none_explicit_equals_default_config():
    a = AutoscalerConfig(spot_fraction=0.5)
    b = AutoscalerConfig(spot_fraction=0.5, bidder=None)
    assert a == b
    assert NodeAutoscalerConfig is AutoscalerConfig
