"""Golden equivalence gate for the fleet-scale hot-path refactor.

``tests/golden/schedule_metrics.json`` is a committed snapshot of
``ScheduleMetrics.to_dict()`` for the seeded table1 simulation grid (all
four policy variants) and two representative table2 cloud cells.  The
simulators must reproduce it EXACTLY — same floats, same counters, same
percentile and phase decompositions — so any semantic drift in the event
loop, metrics accumulators, placement, or policy ordering fails here
before it can bend a benchmark table.

Provenance: the fixture pins the POST-refactor behavior.  Against the
pre-refactor simulators the values agree at benchmark-table precision but
not to the last float bit on rescale-heavy runs: the mandated lazy
progress sync accrues ``(t3-t1)*rate`` in one step where the old
sync-everyone-per-event loop accrued ``(t2-t1)*rate + (t3-t2)*rate`` —
equal in exact arithmetic, ~1e-13 apart in floats.  The counters also
changed meaning deliberately: ``events`` now counts dispatched events only,
with fast-dropped tombstones split out as ``stale_events``.

Comparison happens on the canonical JSON form (``json.loads(json.dumps(
to_dict()))``): no tolerances anywhere; the round-trip only normalizes
containers (tuples to lists), never float values.

Regenerate (ONLY for an intentional, explained behavior change)::

    PYTHONPATH=src python tests/test_golden_metrics.py --regen
"""
import json
import os

import pytest

FIXTURE = os.path.join(os.path.dirname(__file__), "golden",
                       "schedule_metrics.json")


def _canon(metrics) -> dict:
    return json.loads(json.dumps(metrics.to_dict(), sort_keys=True))


def _table1_cases():
    from repro.core.simulator import (VARIANTS, make_jacobi_jobs,
                                      run_variant)
    specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
    return {f"table1.sim.{v}": _canon(
        run_variant(v, specs, total_slots=64, rescale_gap=180.0))
        for v in VARIANTS}


def _table2_cases():
    from benchmarks.table2_cloud_cost import run_cell
    cells = (("elastic", "static_max", "on_demand"),
             ("elastic", "autoscaled", "spot30"))
    return {f"table2.{p}.{prov}.{mkt}": _canon(run_cell(p, prov, mkt))
            for p, prov, mkt in cells}


def _compute_all() -> dict:
    out = _table1_cases()
    out.update(_table2_cases())
    return out


@pytest.fixture(scope="module")
def golden() -> dict:
    with open(FIXTURE) as fh:
        return json.load(fh)


def test_fixture_covers_expected_scenarios(golden):
    assert sorted(golden) == sorted(_compute_all_names())


def _compute_all_names():
    return (["table1.sim.rigid_min", "table1.sim.rigid_max",
             "table1.sim.moldable", "table1.sim.elastic",
             "table2.elastic.static_max.on_demand",
             "table2.elastic.autoscaled.spot30"])


def test_refactored_simulators_reproduce_golden_exactly(golden):
    fresh = _compute_all()
    for name in sorted(golden):
        assert fresh[name] == golden[name], (
            f"{name}: ScheduleMetrics drifted from the committed golden "
            f"fixture — the refactor changed observable behavior")


if __name__ == "__main__":
    import sys
    if "--regen" not in sys.argv:
        sys.exit("refusing: pass --regen to overwrite the golden fixture")
    # direct-script runs lack pytest's rootdir on sys.path (benchmarks.*)
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as fh:
        json.dump(_compute_all(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {FIXTURE}")
