"""Workload subsystem: trace loaders, normalization invariants, seeded
generators, characterization stats, and open-loop replay through both the
fixed-capacity and the cloud simulators (README §Workloads).

Everything here must stay seconds-fast and JAX-free: it gates the CI fast
lane alongside the scheduler/cloud suites.
"""
import math

import pytest

from repro.cloud import (AutoscalerConfig, CloudProvider, NodeAutoscaler,
                         NodePool)
from repro.core.job import JobStatus
from repro.core.policies import PolicyConfig
from repro.core.simulator import Simulator
from repro.workloads import (GENERATORS, HIGH_PRIORITY, LOW_PRIORITY,
                             ReplayConfig, Trace, TraceJob, bursty_trace,
                             characterize, compile_job, compile_trace,
                             fixture_path, generate, heavy_tail_trace,
                             hill_tail_index, load_azure_trace,
                             load_google_trace, replay_cloud, replay_variant,
                             uniform_trace)


# ---------------------------------------------------------------------------
# CSV loader adapters
# ---------------------------------------------------------------------------

def test_google_loader_units_and_fields(tmp_path):
    p = tmp_path / "g.csv"
    p.write_text(
        "time,job_id,priority,cpu_request,duration,user\n"
        "2000000,j1,9,0.5,60000000,alice\n"
        "1000000,j0,0,1.5,30000000,bob\n")     # out of order on purpose
    t = load_google_trace(str(p), slots_per_machine=8)
    assert len(t) == 2 and t.source == str(p)
    by_id = {j.job_id: j for j in t}
    assert by_id["j1"].submit_time == pytest.approx(2.0)      # us -> s
    assert by_id["j1"].duration == pytest.approx(60.0)
    assert by_id["j1"].slots == 4                             # ceil(0.5 * 8)
    assert by_id["j0"].slots == 12                            # >1 machine
    assert by_id["j0"].user == "bob"


def test_google_loader_column_aliases(tmp_path):
    p = tmp_path / "g.csv"
    p.write_text("timestamp,collection_id,priority,resource_request_cpus,"
                 "duration_us\n5000000,c7,11,0.25,1000000\n")
    (j,) = load_google_trace(str(p)).jobs
    assert j.job_id == "c7" and j.slots == 2 and j.priority == 11


def test_azure_loader_lifetimes_and_categories(tmp_path):
    p = tmp_path / "a.csv"
    p.write_text(
        "vm_id,vm_created,vm_deleted,core_count,category\n"
        "v0,100.0,400.0,4,Interactive\n"
        "v1,50.0,3650.0,16,delay-insensitive\n"
        "v2,0.0,60.0,1,7\n")                   # numeric category passthrough
    t = load_azure_trace(str(p))
    by_id = {j.job_id: j for j in t}
    assert by_id["v0"].duration == pytest.approx(300.0)
    assert by_id["v0"].priority > by_id["v1"].priority   # interactive ranks up
    assert by_id["v1"].slots == 16
    assert by_id["v2"].priority == 7


def test_azure_loader_skips_censored_lifetimes(tmp_path):
    p = tmp_path / "a.csv"
    p.write_text(
        "vm_id,vm_created,vm_deleted,core_count,category\n"
        "alive,100.0,100.0,4,Unknown\n"       # still up at snapshot end
        "done,0.0,60.0,2,Unknown\n")
    t = load_azure_trace(str(p))
    assert [j.job_id for j in t] == ["done"]


def test_bundled_fixtures_load_and_normalize():
    for loader, name in ((load_google_trace, "google_sample.csv"),
                         (load_azure_trace, "azure_sample.csv")):
        t = loader(fixture_path(name))
        assert len(t) >= 20
        n = t.normalized(64)
        assert n.jobs[0].submit_time == 0.0
        assert all(1 <= j.slots <= 32 for j in n)
        assert {j.priority for j in n} <= {LOW_PRIORITY, HIGH_PRIORITY}


# ---------------------------------------------------------------------------
# normalization passes
# ---------------------------------------------------------------------------

def _raw(jobs):
    return Trace(name="t", jobs=tuple(jobs))


def test_rebase_and_sort_round_trip():
    t = _raw([TraceJob("b", 500.0, 10.0, 2, 3),
              TraceJob("a", 100.0, 10.0, 2, 3)]).sorted().rebase_time()
    assert [j.job_id for j in t] == ["a", "b"]
    assert t.jobs[0].submit_time == 0.0
    assert t.jobs[1].submit_time == pytest.approx(400.0)


def test_clamp_durations_bounds():
    t = _raw([TraceJob("a", 0.0, 1e-3, 1, 0), TraceJob("b", 1.0, 1e9, 1, 0)])
    c = t.clamp_durations(30.0, 3600.0)
    assert c.jobs[0].duration == 30.0 and c.jobs[1].duration == 3600.0


def test_rescale_slots_preserves_ordering_and_caps_peak():
    t = _raw([TraceJob("a", 0.0, 10.0, 100, 0),
              TraceJob("b", 1.0, 10.0, 10, 0),
              TraceJob("c", 2.0, 10.0, 1, 0)])
    r = t.rescale_slots(64, max_fraction=0.5)
    slots = {j.job_id: j.slots for j in r}
    assert slots["a"] == 32                     # peak -> 50% of cluster
    assert slots["c"] >= 1                      # floor
    assert slots["a"] > slots["b"] > slots["c"]


def test_bucket_priorities_two_classes():
    t = _raw([TraceJob(f"j{i}", float(i), 10.0, 1, i) for i in range(10)])
    b = t.bucket_priorities(high_fraction=0.3)
    prios = [j.priority for j in b]
    assert set(prios) <= {LOW_PRIORITY, HIGH_PRIORITY}
    high = prios.count(HIGH_PRIORITY)
    assert 1 <= high <= 5                       # ~30% of 10, quantile-rounded


def test_bucket_priorities_full_fraction_all_high():
    t = _raw([TraceJob("a", 0.0, 10.0, 1, 0), TraceJob("b", 1.0, 10.0, 1, 5)])
    assert all(j.priority == HIGH_PRIORITY
               for j in t.bucket_priorities(high_fraction=1.0))


def test_bucket_priorities_degenerate_all_low():
    t = _raw([TraceJob(f"j{i}", float(i), 10.0, 1, 4) for i in range(5)])
    assert all(j.priority == LOW_PRIORITY
               for j in t.bucket_priorities(high_fraction=0.3))


# ---------------------------------------------------------------------------
# synthetic generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(GENERATORS))
def test_generators_seeded_deterministic(kind):
    a = generate(kind, n_jobs=20, seed=5)
    b = generate(kind, n_jobs=20, seed=5)
    assert a == b
    assert generate(kind, n_jobs=20, seed=6) != a
    assert len(a) == 20
    arr = a.arrivals()
    assert arr == sorted(arr) and arr[0] >= 0.0
    assert all(j.slots >= 1 and j.duration > 0.0 for j in a)


def test_arrival_shapes_are_discriminated_by_stats():
    uni = characterize(uniform_trace(n_jobs=40, seed=3))
    bur = characterize(bursty_trace(n_jobs=40, seed=3))
    assert uni.interarrival_cv == pytest.approx(0.0, abs=1e-9)
    assert uni.burstiness == pytest.approx(-1.0)
    assert bur.interarrival_cv > 1.0            # MMPP is overdispersed
    assert bur.burstiness > 0.0
    assert bur.peak_rate_ratio > uni.peak_rate_ratio


def test_heavy_tail_has_low_hill_index():
    heavy = characterize(heavy_tail_trace(n_jobs=60, seed=3))
    light = characterize(uniform_trace(n_jobs=60, seed=3))
    assert heavy.tail_index < 2.0               # elephants dominate
    assert light.tail_index > heavy.tail_index


def test_hill_estimator_recovers_known_alpha():
    import numpy as np
    rng = np.random.default_rng(0)
    x = 1.0 + rng.pareto(1.5, size=4000)
    assert hill_tail_index(x) == pytest.approx(1.5, rel=0.25)
    assert hill_tail_index([3.0, 3.0, 3.0, 3.0, 3.0]) == math.inf


# ---------------------------------------------------------------------------
# replay compilation
# ---------------------------------------------------------------------------

def test_compile_job_brackets_natural_size():
    cfg = ReplayConfig(cluster_slots=64, elasticity=2.0)
    spec, wl = compile_job(TraceJob("j", 12.0, 300.0, 8, 5), cfg)
    assert spec.min_replicas == 4 and spec.max_replicas == 16
    assert spec.submit_time == 12.0
    assert wl.total_work == 300.0
    # the observed point is reproduced exactly: 1 s/step at natural size
    assert wl.scaling.time_per_step(8) == pytest.approx(1.0)
    assert wl.scaling.time_per_step(4) > wl.scaling.time_per_step(16)


def test_compile_clamps_to_cluster():
    cfg = ReplayConfig(cluster_slots=16, elasticity=4.0)
    spec, _ = compile_job(TraceJob("j", 0.0, 60.0, 64, 1), cfg)
    assert spec.max_replicas <= 16
    assert 1 <= spec.min_replicas <= spec.max_replicas


def test_replay_variant_completes_trace():
    trace = uniform_trace(n_jobs=8, seed=2, duration_median=120.0,
                          slot_median=4.0).normalized(32)
    cfg = ReplayConfig(cluster_slots=32)
    for variant in ("rigid", "rigid_max", "moldable", "elastic"):
        m = replay_variant(trace, variant, cfg)
        assert m.dropped_jobs == 0, variant
        assert m.total_time > 0.0 and 0.0 < m.utilization <= 1.0


def test_replay_rigid_runs_at_observed_request():
    trace = _raw([TraceJob("solo", 0.0, 100.0, 5, 1)])
    cfg = ReplayConfig(cluster_slots=32)
    pairs = compile_trace(trace, cfg)
    sim = Simulator(32, PolicyConfig(rescale_gap=180.0))
    spec = pairs[0][0].rigid(5)
    sim.submit(spec, pairs[0][1])
    m = sim.run()
    # 100 steps at 1 s/step at the natural size: runtime reproduced exactly
    assert sim.cluster.jobs["solo"].end_time == pytest.approx(100.0)
    assert m.rescale_count == 0


def test_replay_cloud_autoscaled_completes_and_bills():
    trace = bursty_trace(n_jobs=10, seed=4, duration_median=200.0,
                         slot_median=4.0).normalized(32)
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=60.0,
                                   teardown_delay=10.0, initial_nodes=1,
                                   max_nodes=4)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=20.0, scale_up_cooldown=20.0, scale_down_cooldown=60.0,
        idle_timeout=120.0, headroom_slots=8))
    sim = replay_cloud(trace, ReplayConfig(cluster_slots=32), prov,
                       variant="elastic", autoscaler=asc)
    assert sim.metrics.dropped_jobs == 0
    assert sim.metrics.total_cost > 0.0
    assert asc.scale_ups >= 1                   # the burst forced provisioning
    assert all(j.status is JobStatus.COMPLETED
               for j in sim.cluster.jobs.values())


# ---------------------------------------------------------------------------
# satellite regression: arrival order is insertion-agnostic
# ---------------------------------------------------------------------------

def _metrics_for_order(pairs, order):
    sim = Simulator(16, PolicyConfig(rescale_gap=60.0))
    for i in order:
        sim.submit(*pairs[i])
    m = sim.run()
    ends = {j.job_id: j.end_time for j in sim.cluster.jobs.values()}
    return m, ends


def test_submit_order_does_not_change_schedule():
    """Bursty traces collapse arrivals onto shared timestamps; the schedule
    must depend on (submit_time, priority, job_id), never on the order
    submit() happened to be called in."""
    trace = _raw([
        TraceJob("a", 0.0, 50.0, 4, 1), TraceJob("b", 0.0, 50.0, 4, 5),
        TraceJob("c", 0.0, 80.0, 8, 3), TraceJob("d", 120.0, 50.0, 4, 2),
        TraceJob("e", 120.0, 30.0, 8, 2),
    ])
    pairs = compile_trace(trace, ReplayConfig(cluster_slots=16))
    m0, ends0 = _metrics_for_order(pairs, [0, 1, 2, 3, 4])
    for order in ([4, 3, 2, 1, 0], [2, 0, 4, 1, 3]):
        m, ends = _metrics_for_order(pairs, order)
        assert ends == ends0
        assert m.weighted_mean_completion == pytest.approx(
            m0.weighted_mean_completion)
        assert m.utilization == pytest.approx(m0.utilization)


def test_same_time_arrivals_process_priority_desc():
    trace = _raw([TraceJob("lo", 0.0, 100.0, 16, 1),
                  TraceJob("hi", 0.0, 100.0, 16, 5)])
    pairs = compile_trace(trace, ReplayConfig(cluster_slots=16,
                                              elasticity=1.0))
    # submit the low-priority job FIRST; the high one must still win the
    # single 16-slot block because ties process priority-desc
    sim = Simulator(16, PolicyConfig(rescale_gap=60.0))
    for spec, wl in pairs:
        sim.submit(spec, wl)
    sim.run()
    jobs = sim.cluster.jobs
    assert jobs["hi"].start_time == pytest.approx(0.0)
    assert jobs["lo"].start_time > jobs["hi"].start_time
