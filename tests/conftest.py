# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and benches
# must see the single real CPU device.  Multi-device scenarios run in
# subprocesses (tests/helpers/) that set the flag themselves.
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_helper(script: str, *args, devices: int = 8, timeout: int = 900):
    """Run tests/helpers/<script> in a subprocess with N virtual devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers", script),
         *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def helper():
    return run_helper
