"""Cloud subsystem: node lifecycle, cost accounting, autoscaler behavior,
spot preemption through the checkpoint/requeue path, and the elastic-beats-
static-provisioning economics the benchmark (table2) reports."""
import math

import pytest

from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         CostAccountant, NodeAutoscaler, NodePool, NodeState)
from repro.core.cluster import Cluster
from repro.core.job import JobSpec, JobState, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.autoscale import PreemptingPolicy
from repro.core.simulator import (Simulator, SimWorkload, jacobi_workload,
                                  make_jacobi_jobs)


def wl(steps=100.0, t1=1.0, t_many=1.0, data=1e9):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t1), (64.0, t_many))),
        total_work=steps, data_bytes=data, rescale=RescaleModel())


# ---------------------------------------------------------------------------
# Cluster dynamic capacity
# ---------------------------------------------------------------------------

def test_cluster_dynamic_capacity_arithmetic():
    c = Cluster(4)
    assert c.total_slots == 4
    c.add_node("n0", 8)
    c.add_node("n1", 8)
    assert c.total_slots == 20 and c.free_slots == 20
    assert c.remove_node("n0") == 8
    assert c.total_slots == 12
    with pytest.raises(KeyError):
        c.remove_node("n0")


def test_cluster_overcommit_after_node_removal():
    c = Cluster(0)
    c.add_node("n0", 8)
    c.add_node("n1", 8)
    j = JobState(spec=JobSpec("a", 1, 4, 16), status=JobStatus.RUNNING,
                 replicas=12)
    c.add_job(j)
    c.remove_node("n1")
    assert c.total_slots == 8
    assert c.free_slots == -4
    assert c.overcommit == 4


# ---------------------------------------------------------------------------
# Satellite regression: _SimActions.create no longer asserts
# ---------------------------------------------------------------------------

def test_create_over_allocation_returns_false():
    sim = Simulator(4, PolicyConfig(rescale_gap=0.0))
    job = JobState(spec=JobSpec("big", 1, 8, 8, 0.0))
    sim.workloads["big"] = wl()
    assert sim.actions.create(job, 8) is False
    assert job.status is JobStatus.PENDING      # untouched on failure
    assert sim.cluster.used_slots == 0
    assert sim.actions.create(job, 4) is True
    assert job.status is JobStatus.RUNNING


def test_submit_beyond_capacity_queues_instead_of_crashing():
    # a policy race (capacity gone between its free_slots read and create)
    # must leave the job queued, not crash the simulator
    sim = Simulator(8, PolicyConfig(rescale_gap=0.0))
    sim.submit(JobSpec("a", 1, 4, 8, 0.0), wl(10))
    sim.cluster.add_node("tmp", 8)
    sim.submit(JobSpec("b", 1, 12, 16, 0.0), wl(10))
    m = sim.run()
    assert m.dropped_jobs == 0


# ---------------------------------------------------------------------------
# Provider lifecycle
# ---------------------------------------------------------------------------

def test_provider_node_lifecycle_and_billing_window():
    from repro.core.events import EventQueue
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=120.0,
                                   teardown_delay=30.0, max_nodes=2)])
    q = EventQueue()
    node = prov.request_node("od", now=10.0, queue=q)
    assert node.state is NodeState.PROVISIONING
    ev = q.pop()
    assert (ev.kind, ev.time) == ("node_up", 130.0)
    assert prov.on_node_up(node.node_id, 130.0) is node
    assert node.state is NodeState.UP
    prov.release_node(node.node_id, 500.0, q)
    assert node.state is NodeState.DRAINING
    ev = q.pop()
    assert (ev.kind, ev.time) == ("node_down", 530.0)
    assert prov.on_node_down(node.node_id, 530.0) is node
    assert node.billed_hours(9e9) == pytest.approx(400.0 / 3600.0)
    # pool cap: 1 live+0 -> ok, then full
    assert prov.request_node("od", 0.0, q) is not None
    # DOWN nodes no longer count against max_nodes
    assert prov.pool_census("od") == 1


def test_provider_spot_kill_while_booting_is_harmless():
    from repro.core.events import EventQueue
    prov = CloudProvider([NodePool("sp", market=SPOT, boot_latency=60.0)])
    q = EventQueue()
    node = prov.request_node("sp", 0.0, q)
    got, was_up = prov.on_spot_kill(node.node_id, 10.0)
    assert got is None and not was_up
    # the queued node_up is now stale
    assert prov.on_node_up(node.node_id, 60.0) is None


# ---------------------------------------------------------------------------
# Cost accounting
# ---------------------------------------------------------------------------

def test_cost_accountant_exact_arithmetic():
    acc = CostAccountant()
    node = CloudProvider(
        [NodePool("od", slots_per_node=8,
                  price_per_slot_hour=0.36)])._new_node(
                      NodePool("od", slots_per_node=8,
                               price_per_slot_hour=0.36), 0.0)
    acc.node_up(node)
    job = JobState(spec=JobSpec("a", 1, 4, 8), status=JobStatus.RUNNING,
                   replicas=4)
    acc.set_allocations([job])
    acc.advance(100.0)
    r = acc.report()
    # 8 slots x 100 s x $0.36/slot-h = $0.08; half the slots were used
    assert r.total_cost == pytest.approx(8 * 100 * 0.36 / 3600)
    assert r.used_cost == pytest.approx(4 * 100 * 0.36 / 3600)
    assert r.idle_cost == pytest.approx(r.total_cost - r.used_cost)
    assert r.job_costs["a"] == pytest.approx(r.used_cost)
    assert r.node_hours == pytest.approx(100.0 / 3600.0)
    assert r.slot_hours == pytest.approx(800.0 / 3600.0)


def test_cost_blended_rate_mixes_markets():
    acc = CostAccountant()
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048),
        NodePool("sp", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT),
    ])
    for name in ("od", "sp"):
        n = prov._new_node(prov.pools[name], 0.0)
        acc.node_up(n)
    job = JobState(spec=JobSpec("a", 1, 8, 16), status=JobStatus.RUNNING,
                   replicas=16)                  # uses ALL capacity
    acc.set_allocations([job])
    acc.advance(3600.0)
    r = acc.report()
    assert r.total_cost == pytest.approx(8 * 0.048 + 8 * 0.016)
    assert r.idle_cost == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# Autoscaler
# ---------------------------------------------------------------------------

def _autoscaled_sim(n_jobs=4, **cfg_kw):
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=60.0,
                                   teardown_delay=10.0, initial_nodes=1,
                                   max_nodes=8)])
    cfg = AutoscalerConfig(tick_interval=15.0, scale_up_cooldown=15.0,
                           scale_down_cooldown=60.0, idle_timeout=90.0,
                           **cfg_kw)
    asc = NodeAutoscaler(prov, cfg)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    for i in range(n_jobs):
        sim.submit(JobSpec(f"j{i}", 1 + i % 3, 4, 16, i * 40.0), wl(150))
    return prov, asc, sim


def test_autoscaler_scales_up_on_queue_pressure_and_down_on_idle():
    prov, asc, sim = _autoscaled_sim()
    # a late straggler keeps the sim alive through the post-burst idle valley
    # so the idle_timeout machinery gets a chance to release nodes
    sim.submit(JobSpec("late", 1, 4, 8, 1500.0), wl(50))
    m = sim.run()
    assert m.dropped_jobs == 0
    assert asc.scale_ups > 0                    # pressure provisioned nodes
    assert asc.scale_downs > 0                  # trailing idle released some
    assert any(n.state is NodeState.DOWN for n in prov.nodes.values())
    assert m.total_cost > 0.0 and m.node_hours > 0.0


def test_autoscaler_budget_cap_blocks_provisioning():
    prov, asc, sim = _autoscaled_sim(budget_cap=0.0)
    m = sim.run()
    assert asc.scale_ups == 0
    # only the single initial node ever existed
    assert len(prov.nodes) == 1


def test_autoscaler_budget_cap_bounds_boot_window_commitment():
    """The cap must bite DURING the boot window: billing hasn't started for
    booting nodes, so the check charges a COMMIT_HOURS commitment per node."""
    prov = CloudProvider([NodePool("od", slots_per_node=8,
                                   price_per_slot_hour=0.048,
                                   boot_latency=300.0, initial_nodes=1,
                                   max_nodes=64)])
    # budget: room for ~2 committed node-hours (0.384 $/node-hour) — the
    # initial UP node commits one of them, leaving room for ONE scale-up
    cfg = AutoscalerConfig(tick_interval=10.0, scale_up_cooldown=10.0,
                           budget_cap=2.1 * 8 * 0.048)
    asc = NodeAutoscaler(prov, cfg)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    for i in range(64):                 # huge burst: 512 queued min-slots
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, 0.0), wl(50))
    sim.run()
    # without the commitment term every tick in the 300 s boot window would
    # provision more nodes (spend_through stays ~0); with it, exactly one
    assert asc.scale_ups == 1
    assert len(prov.nodes) == 2


def test_preempting_policy_respects_divides_constraint():
    """The post-preemption create must not start a job at a replica count
    violating its divides contract."""
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = Simulator(12, pcfg)
    sim.policy = PreemptingPolicy(pcfg)
    sim.submit(JobSpec("lo", 1, 12, 12, 0.0), wl(50))
    # free after preempting lo is 12; max 16 -> min(12,16)=12 is NOT feasible
    # for divides=16 (16 % 12 != 0); feasible() must round down to 8
    sim.submit(JobSpec("hi", 5, 4, 16, 1.0, divides=16), wl(10))
    sim.run()
    hi = sim.cluster.jobs["hi"]
    assert hi.preempt_count == 0 and hi.end_time is not None
    assert sim.cluster.jobs["lo"].preempt_count == 1
    # every replica count hi ever ran at divided 16; it started at 8
    assert hi.spec.feasible(12) == 8


def test_unsatisfiable_job_neither_provisions_nor_bills_horizon():
    """A queued job beyond the pools' theoretical ceiling creates no demand
    (no provision/release thrash) and the run stops once only it remains —
    not after 7 days of idle billing."""
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=30.0,
                                   teardown_delay=10.0, initial_nodes=1,
                                   max_nodes=1)])          # can never fit 16
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, scale_down_cooldown=30.0,
        idle_timeout=60.0))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    sim.submit(JobSpec("quick", 1, 4, 8, 0.0), wl(20))
    sim.submit(JobSpec("huge", 5, 16, 16, 0.0), wl(20))    # unsatisfiable
    m = sim.run()
    assert m.dropped_jobs == 1                             # huge never ran
    assert sim.cluster.jobs["quick"].status is JobStatus.COMPLETED
    assert asc.scale_ups == 0                              # no thrash
    assert sim.now < 60.0                                  # stopped promptly
    # ~20 s of one 8-slot node: 8 * 20/3600 * $0.048 = $0.00213
    assert m.total_cost == pytest.approx(8 * 20 / 3600 * 0.048)


def test_budget_stranded_demand_releases_idle_nodes():
    """Satisfiable queued demand that the budget can no longer fund must not
    pin idle capacity: the autoscaler falls through to scale-down."""
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=30.0,
                                   teardown_delay=10.0, initial_nodes=2,
                                   max_nodes=4)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, scale_down_cooldown=30.0,
        idle_timeout=60.0, budget_cap=1e-9,    # provisioning always blocked
        max_horizon=3600.0))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    # `busy` must grab its node before `wants16` arrives (same-time arrivals
    # now process priority-desc, which would hand wants16 both nodes)
    sim.submit(JobSpec("busy", 1, 8, 8, 0.0), wl(600))     # holds one node
    sim.submit(JobSpec("wants16", 5, 16, 16, 0.5), wl(10))  # satisfiable,
    m = sim.run()                                           # but unfundable
    # the second node idled while `busy` ran; stranded demand released it
    assert asc.scale_downs >= 1
    assert sim.cluster.jobs["busy"].status is JobStatus.COMPLETED
    assert m.dropped_jobs == 1


def test_stuck_workload_stops_clock_instead_of_billing_to_spot_fates():
    """A job whose min_replicas can never fit again (node killed, no
    autoscaler) must not drag billing out to far-future spot-fate events."""
    prov = CloudProvider([NodePool("sp", slots_per_node=8, market=SPOT,
                                   initial_nodes=2, max_nodes=2,
                                   spot_lifetime_mean=1e12)])
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg))
    sim.submit(JobSpec("a", 1, 16, 16, 0.0), wl(100))
    prov.inject_spot_kill(sorted(prov.nodes)[0], 20.0, sim.queue)
    m = sim.run()
    assert m.dropped_jobs == 1
    assert sim.now < 100.0              # stopped at the stuck point ...
    assert m.total_cost < 0.01          # ... not at the t~1e12 spot fate


def test_spot_kill_cost_attribution_never_exceeds_total():
    """During the post-kill checkpoint window allocations transiently exceed
    billed capacity; attribution must be scaled so used <= total."""
    prov = CloudProvider([
        NodePool("sp", slots_per_node=8, market=SPOT, initial_nodes=2,
                 max_nodes=2, spot_lifetime_mean=1e12),
    ])
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg))
    sim.submit(JobSpec("a", 1, 16, 16, 0.0), wl(100, data=4e9))  # slow ckpt
    prov.inject_spot_kill(sorted(prov.nodes)[0], 20.0, sim.queue)
    sim.run()
    r = sim.cost_report
    assert r.used_cost <= r.total_cost + 1e-12
    assert sum(r.job_costs.values()) == pytest.approx(r.used_cost)
    assert r.idle_cost == pytest.approx(r.total_cost - r.used_cost)


def test_autoscaler_scale_up_hysteresis_limits_burst():
    # all jobs arrive at once; one evaluation window may provision several
    # nodes, but the cooldown forbids back-to-back-tick provisioning
    prov = CloudProvider([NodePool("od", slots_per_node=8, boot_latency=60.0,
                                   initial_nodes=1, max_nodes=8)])
    cfg = AutoscalerConfig(tick_interval=10.0, scale_up_cooldown=1e9)
    asc = NodeAutoscaler(prov, cfg)
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0), autoscaler=asc)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, 0.0), wl(50))
    sim.run()
    # one provisioning action total (cooldown never expires again)
    ticks_that_provisioned = asc.scale_ups
    assert 0 < ticks_that_provisioned <= 5      # single burst, bounded


# ---------------------------------------------------------------------------
# Spot preemption (acceptance: all jobs complete under PreemptingPolicy)
# ---------------------------------------------------------------------------

def test_spot_kill_victims_checkpoint_requeue_and_resume():
    prov = CloudProvider([
        NodePool("sp", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, initial_nodes=2, max_nodes=4,
                 spot_lifetime_mean=1e12),       # fates far beyond the run
    ])
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg))
    sim.submit(JobSpec("lo", 1, 8, 8, 0.0), wl(100))
    sim.submit(JobSpec("hi", 5, 8, 8, 1.0), wl(60))
    victim_node = sorted(prov.nodes)[0]
    prov.inject_spot_kill(victim_node, 30.0, sim.queue)
    m = sim.run()
    lo, hi = sim.cluster.jobs["lo"], sim.cluster.jobs["hi"]
    assert m.dropped_jobs == 0                  # every job completed
    assert m.spot_preemptions == 1
    assert sim.spot_victim_jobs == 1
    # the LOW priority job was the victim; it checkpointed to disk, requeued,
    # and resumed with progress intact (ends later than its solo runtime but
    # far earlier than restarting from scratch at the resume point)
    assert lo.preempt_count == 1 and lo.status is JobStatus.COMPLETED
    assert hi.preempt_count == 0
    resume_overhead = RescaleModel().resume_cost(8, 1e9)
    ckpt = RescaleModel().preempt_cost(8, 1e9)
    # hi runs 60 steps alone after the kill; lo did ~30 steps before dying,
    # resumes after hi completes and finishes its remaining ~70 steps
    assert hi.end_time == pytest.approx(61.0 + ckpt, rel=0.05)
    assert lo.end_time == pytest.approx(
        hi.end_time + resume_overhead + 70.0, rel=0.10)


def test_spot_kill_shrinks_elastic_jobs_before_preempting():
    prov = CloudProvider([
        NodePool("sp", slots_per_node=8, market=SPOT, initial_nodes=2,
                 max_nodes=2, spot_lifetime_mean=1e12),
    ])
    pcfg = PolicyConfig(rescale_gap=0.0)
    sim = CloudSimulator(prov, pcfg)
    sim.submit(JobSpec("a", 3, 4, 16, 0.0), wl(100))   # elastic: 16 -> 8 fits
    prov.inject_spot_kill(sorted(prov.nodes)[0], 20.0, sim.queue)
    m = sim.run()
    a = sim.cluster.jobs["a"]
    assert a.preempt_count == 0                 # shrunk, never preempted
    assert a.rescale_count >= 1
    assert m.dropped_jobs == 0


def test_spot_victim_restarts_despite_rescale_gap_cooldown():
    """A preempted job re-enters the queue with its gap clock cleared (job.py:
    queued jobs always pass the gap check), so a completion shortly after the
    kill restarts it instead of stranding it for a whole rescale_gap."""
    prov = CloudProvider([
        NodePool("sp", slots_per_node=8, market=SPOT, initial_nodes=2,
                 max_nodes=2, spot_lifetime_mean=1e12),
    ])
    pcfg = PolicyConfig(rescale_gap=600.0)      # long cool-down
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg))
    # stagger so `victim` packs onto the first node (the one killed below) —
    # same-time arrivals process priority-desc since the tiebreak change
    sim.submit(JobSpec("victim", 1, 8, 8, 0.0), wl(200))
    sim.submit(JobSpec("other", 5, 8, 8, 0.5), wl(60))   # done at ~60 s
    prov.inject_spot_kill(sorted(prov.nodes)[0], 30.0, sim.queue)
    m = sim.run()
    victim = sim.cluster.jobs["victim"]
    assert victim.preempt_count == 1
    assert m.dropped_jobs == 0                  # restarted well inside 600 s
    # resumed on `other`'s completion (~60 s), not after the gap expired
    assert victim.end_time < 600.0


def test_spot_heavy_random_kills_still_complete_under_preempting_policy():
    """Aggressive random spot market: every job still finishes (checkpoint ->
    requeue -> resume), possibly after autoscaled replacement capacity."""
    prov = CloudProvider([
        NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                 boot_latency=60.0, initial_nodes=1, max_nodes=6),
        NodePool("sp", slots_per_node=8, price_per_slot_hour=0.016,
                 market=SPOT, boot_latency=60.0, initial_nodes=2, max_nodes=6,
                 spot_lifetime_mean=300.0),      # mean life: 5 minutes (!)
    ], seed=3)
    pcfg = PolicyConfig(rescale_gap=0.0)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0, idle_timeout=120.0,
        spot_fraction=0.5))
    sim = CloudSimulator(prov, pcfg, policy=PreemptingPolicy(pcfg),
                         autoscaler=asc)
    for i in range(6):
        sim.submit(JobSpec(f"j{i}", 1 + i % 5, 4, 16, i * 30.0), wl(120))
    m = sim.run()
    assert m.dropped_jobs == 0
    assert all(j.status is JobStatus.COMPLETED
               for j in sim.cluster.jobs.values())
    assert m.spot_preemptions > 0               # the market did bite


# ---------------------------------------------------------------------------
# Economics: node-autoscaled elastic vs. static-max provisioning
# ---------------------------------------------------------------------------

def _jacobi_cloud_run(*, initial_nodes, autoscaled, n_jobs=16):
    # small/medium only: their max_replicas (8/16) cap how much capacity the
    # elastic policy can absorb, so a 64-slot static cluster — sized for the
    # peak burst — idles most of the window.  That is the economics the cloud
    # subsystem exists to expose.
    specs = make_jacobi_jobs(seed=7, n_jobs=n_jobs, submission_gap=90.0,
                             sizes=("small", "medium"))
    prov = CloudProvider([NodePool("od", slots_per_node=8,
                                   price_per_slot_hour=0.048,
                                   boot_latency=120.0, teardown_delay=30.0,
                                   initial_nodes=initial_nodes, max_nodes=8)])
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=180.0, headroom_slots=8)) if autoscaled else None
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=180.0),
                         autoscaler=asc)
    for s in specs:
        sim.submit(s, jacobi_workload(s.workload))
    return sim.run()


def test_autoscaled_elastic_cheaper_than_static_max():
    static = _jacobi_cloud_run(initial_nodes=8, autoscaled=False)
    scaled = _jacobi_cloud_run(initial_nodes=1, autoscaled=True)
    assert static.dropped_jobs == 0 and scaled.dropped_jobs == 0
    # the whole point of the subsystem: meaningfully cheaper ...
    assert scaled.total_cost < 0.85 * static.total_cost
    # ... at comparable weighted mean completion time (boot latency tax only)
    assert scaled.weighted_mean_completion < \
        1.5 * static.weighted_mean_completion


def test_capacity_weighted_utilization_uses_dynamic_denominator():
    # static-max wastes capacity the small/medium jobs cannot absorb;
    # the autoscaled cluster tracks demand, so its utilization is far higher
    static = _jacobi_cloud_run(initial_nodes=8, autoscaled=False)
    scaled = _jacobi_cloud_run(initial_nodes=1, autoscaled=True)
    assert scaled.utilization > static.utilization
