"""Hypothesis properties for the workload subsystem:

- every generator is a pure function of its seed (same seed, same Trace);
- arrivals are sorted and non-negative for ANY generator parameters;
- normalization keeps every demand inside the target cluster bounds and
  every priority in the paper's two classes;
- compilation brackets the natural size: min <= natural <= max <= cluster.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.workloads import (GENERATORS, HIGH_PRIORITY, LOW_PRIORITY,
                             ReplayConfig, Trace, TraceJob, compile_trace,
                             generate)

KINDS = st.sampled_from(sorted(GENERATORS))


@st.composite
def raw_traces(draw):
    n = draw(st.integers(1, 30))
    jobs = tuple(
        TraceJob(job_id=f"j{i}",
                 submit_time=draw(st.floats(0.0, 1e6, allow_nan=False)),
                 duration=draw(st.floats(1e-3, 1e6, allow_nan=False,
                                         exclude_min=True)),
                 slots=draw(st.integers(1, 10_000)),
                 priority=draw(st.integers(0, 11)))
        for i in range(n))
    return Trace(name="t", jobs=jobs)


@settings(max_examples=25, deadline=None)
@given(kind=KINDS, seed=st.integers(0, 2**31), n=st.integers(1, 40))
def test_generators_pure_in_seed(kind, seed, n):
    assert generate(kind, n_jobs=n, seed=seed) == \
        generate(kind, n_jobs=n, seed=seed)


@settings(max_examples=25, deadline=None)
@given(kind=KINDS, seed=st.integers(0, 2**31), n=st.integers(1, 40))
def test_generator_arrivals_sorted_nonnegative(kind, seed, n):
    t = generate(kind, n_jobs=n, seed=seed)
    arr = t.arrivals()
    assert len(t) == n
    assert arr == sorted(arr)
    assert all(a >= 0.0 for a in arr)
    assert all(j.slots >= 1 and j.duration > 0.0 for j in t)


@settings(max_examples=50, deadline=None)
@given(trace=raw_traces(), cluster=st.integers(1, 256),
       frac=st.floats(0.1, 1.0))
def test_normalized_demands_within_cluster_bounds(trace, cluster, frac):
    n = trace.normalized(cluster, max_fraction=frac)
    peak_target = max(1, int(cluster * frac))
    for j in n:
        # rounding can add at most half a job's worth above the linear map,
        # never above the pre-rescale peak target (the peak maps exactly)
        assert 1 <= j.slots <= peak_target
        assert j.priority in (LOW_PRIORITY, HIGH_PRIORITY)
        assert j.submit_time >= 0.0
    assert n.jobs[0].submit_time == 0.0
    assert [j.submit_time for j in n] == sorted(j.submit_time for j in n)


@settings(max_examples=50, deadline=None)
@given(trace=raw_traces(), cluster=st.integers(1, 256),
       elasticity=st.floats(1.0, 8.0))
def test_compile_brackets_natural_size(trace, cluster, elasticity):
    cfg = ReplayConfig(cluster_slots=cluster, elasticity=elasticity)
    for (spec, wl), tj in zip(compile_trace(trace, cfg), trace.jobs):
        natural = min(max(1, tj.slots), cluster)
        assert 1 <= spec.min_replicas <= natural
        assert natural <= spec.max_replicas <= cluster
        assert wl.total_work == tj.duration
        assert wl.scaling.time_per_step(natural) == pytest.approx(1.0)
