"""Hypothesis property tests for the placement layer's invariants:

- no slot is ever owned by two jobs, under ANY op sequence;
- per-node residency sums equal the cluster's counted ``used_slots`` after
  every simulator event;
- a spot kill displaces EXACTLY the killed node's residents — bystander jobs
  keep their replica counts and are never preempted.
"""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.cloud import CloudProvider, CloudSimulator, NodePool, SPOT
from repro.core.job import JobSpec, JobStatus
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.placement import PlacementError, PlacementMap
from repro.core.policies import PolicyConfig
from repro.core.simulator import SimWorkload


# ---------------------------------------------------------------------------
# PlacementMap under arbitrary op sequences
# ---------------------------------------------------------------------------

@st.composite
def op_sequences(draw):
    n_nodes = draw(st.integers(1, 5))
    node_slots = [draw(st.integers(1, 8)) for _ in range(n_nodes)]
    ops = draw(st.lists(st.tuples(
        st.sampled_from(["place", "evict", "cordon", "uncordon", "migrate"]),
        st.integers(0, 4),              # job index
        st.integers(0, n_nodes - 1),    # node index
        st.integers(1, 8),              # count
    ), max_size=40))
    strategy = draw(st.sampled_from(["pack", "spread"]))
    return node_slots, ops, strategy


@settings(max_examples=80, deadline=None)
@given(op_sequences())
def test_no_slot_double_owned_under_any_op_sequence(seq):
    node_slots, ops, strategy = seq
    p = PlacementMap(strategy)
    names = [f"n{i}" for i in range(len(node_slots))]
    for name, slots in zip(names, node_slots):
        p.add_node(name, slots)
    for kind, ji, ni, count in ops:
        job = f"job{ji}"
        if kind == "place":
            try:
                p.place(job, count)
            except PlacementError:
                pass
        elif kind == "evict":
            p.evict(job, min(count, p.owned(job)) or None)
        elif kind == "cordon":
            p.cordon(names[ni])
        elif kind == "uncordon":
            p.uncordon(names[ni])
        elif kind == "migrate":
            p.migrate(job, names[ni])
        # invariants after EVERY op
        p.check()
        owned_total = sum(p.owned(f"job{k}") for k in range(5))
        residency_total = sum(p.resident_count(n) for n in names)
        assert owned_total == residency_total
        assert owned_total + p.free() <= sum(node_slots)
        assert 0.0 <= p.fragmentation() <= 1.0


# ---------------------------------------------------------------------------
# CloudSimulator: residency == used_slots after every event; kills are exact
# ---------------------------------------------------------------------------

def _wl(steps, t_step):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, t_step), (64.0, t_step))),
        total_work=steps, data_bytes=1e6, rescale=RescaleModel())


@st.composite
def cloud_streams(draw):
    n_nodes = draw(st.integers(2, 4))
    jobs = []
    for i in range(draw(st.integers(2, 8))):
        mn = draw(st.integers(1, 8))
        mx = draw(st.integers(mn, 16))
        jobs.append(dict(job_id=f"j{i:02d}",
                         priority=draw(st.integers(1, 5)),
                         min_replicas=mn, max_replicas=mx,
                         submit_time=float(draw(st.integers(0, 200))),
                         work=float(draw(st.integers(1, 100))),
                         t_step=draw(st.floats(0.1, 2.0))))
    kill_at = float(draw(st.integers(5, 300)))
    kill_idx = draw(st.integers(0, n_nodes - 1))
    strategy = draw(st.sampled_from(["pack", "spread"]))
    return n_nodes, jobs, kill_at, kill_idx, strategy


class _AuditedCloudSim(CloudSimulator):
    def _record_util(self):
        super()._record_util()
        placed = sum(self.cluster.resident_count(n)
                     for n in self.cluster.nodes())
        assert placed == self.cluster.used_slots, \
            f"residency {placed} != used {self.cluster.used_slots}"
        self.cluster.placement.check()


@settings(max_examples=40, deadline=None)
@given(cloud_streams())
def test_residency_equals_used_slots_and_kills_are_node_exact(stream):
    n_nodes, jobs, kill_at, kill_idx, strategy = stream
    prov = CloudProvider([NodePool(
        "sp", slots_per_node=8, market=SPOT, initial_nodes=n_nodes,
        max_nodes=n_nodes, spot_lifetime_mean=1e12)])
    sim = _AuditedCloudSim(prov, PolicyConfig(rescale_gap=0.0),
                           placement=strategy)
    victim_node = sorted(prov.nodes)[kill_idx]
    prov.inject_spot_kill(victim_node, kill_at, sim.queue)

    snapshot = {}
    before = {}
    orig = sim._on_spot_kill

    def probed(node_id):
        if node_id == victim_node:
            snapshot.update(sim.cluster.residents(node_id))
            before.update({j.job_id: (j.replicas, j.preempt_count)
                           for j in sim.cluster.running_jobs()})
        orig(node_id)
        if node_id == victim_node and before:
            # bystanders (running jobs NOT resident on the killed node) are
            # never harmed by the kill: no shrink, no preemption.  They MAY
            # legitimately be EXPANDED — _on_spot_kill ends with a Fig.-3
            # redistribution of capacity the victims' eviction freed up
            for jid, (reps, pre) in before.items():
                if jid in snapshot:
                    continue
                j = sim.cluster.jobs[jid]
                assert j.replicas >= reps, f"bystander {jid} shrunk"
                assert j.preempt_count == pre, f"bystander {jid} preempted"
    sim._on_spot_kill = probed
    sim.run()
    # every displaced job was genuinely resident on the killed node
    if sim.kill_blasts:
        blast = sim.kill_blasts[0]
        assert blast.jobs == len(snapshot)
        assert blast.slots == sum(snapshot.values())
        assert blast.zone == "default-a"    # NodePool's default zone
