"""Critical-path decomposition (repro.obs.critical_path): the phases
partition every completed job's makespan exactly (live ledger and offline
trace replay agree), preemption/boot phases land where they should, the
fleet rollup is priority-weighted like WMCT, and reconcile() catches a
stream whose decomposition cannot cover the makespan.
"""
import pytest

from repro.cloud import (AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool)
from repro.core.autoscale import PreemptingPolicy
from repro.core.job import JobSpec
from repro.core.perf_model import PiecewiseScalingModel, RescaleModel
from repro.core.policies import PolicyConfig
from repro.core.simulator import (SimWorkload, Simulator, make_jacobi_jobs,
                                  run_variant)
from repro.obs import Tracer, install
from repro.obs.critical_path import (PHASES, analyze, decompose,
                                     merge_intervals, overlap, reconcile,
                                     rollup)


def wl(steps=100.0):
    return SimWorkload(
        scaling=PiecewiseScalingModel(((1.0, 1.0), (64.0, 1.0))),
        total_work=steps, data_bytes=1e9, rescale=RescaleModel())


# ---------------------------------------------------------------------------
# interval helpers
# ---------------------------------------------------------------------------

def test_merge_and_overlap():
    ivs = merge_intervals([(5.0, 7.0), (0.0, 2.0), (1.0, 3.0), (4.0, 4.0)])
    assert ivs == [(0.0, 3.0), (5.0, 7.0)]
    assert overlap((2.0, 6.0), ivs) == pytest.approx(2.0)   # [2,3] + [5,6]
    assert overlap((10.0, 12.0), ivs) == 0.0


# ---------------------------------------------------------------------------
# the partition invariant, live and offline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["elastic", "elastic_preempt"])
def test_phase_sums_partition_the_weighted_mean_completion(variant):
    specs = make_jacobi_jobs(seed=7, n_jobs=12, submission_gap=60.0)
    with install(Tracer()) as tr:
        m = run_variant(variant, specs, total_slots=48, rescale_gap=180.0)
    assert set(m.phase_seconds) == set(PHASES)
    assert sum(m.phase_seconds.values()) == \
        pytest.approx(m.weighted_mean_completion, rel=1e-9)
    assert reconcile(tr.records) == []
    assert m.phase_seconds["compute"] > 0.0
    assert m.phase_seconds["queue_wait"] > 0.0


def test_offline_decompose_matches_live_ledger():
    specs = make_jacobi_jobs(seed=11, n_jobs=8, submission_gap=45.0)
    with install(Tracer()) as tr:
        m = run_variant("elastic_preempt", specs, total_slots=32,
                        rescale_gap=120.0)
    prio = {r["job"]: r["priority"] for r in tr.records
            if r["kind"] == "job_submit"}
    fleet = rollup(decompose(tr.records), prio)
    assert fleet.jobs == 8
    for p in PHASES:
        assert fleet.phase_seconds[p] == \
            pytest.approx(m.phase_seconds[p], abs=1e-6), p
    assert fleet.phase_by_priority == m.phase_by_priority
    assert fleet.dominant_phase == m.dominant_phase


def test_preemption_phases_attributed():
    pcfg = PolicyConfig(rescale_gap=0.0)
    tr = Tracer()
    with install(tr):
        sim = Simulator(8, pcfg)
        sim.policy = PreemptingPolicy(pcfg)
        sim.submit(JobSpec("lo", 1, 8, 8, 0.0), wl(100))
        sim.submit(JobSpec("hi", 5, 8, 8, 1.0), wl(50))
        sim.run()
    lo = sim.phases.phases_of("lo")
    assert lo is not None
    assert lo["ckpt"] > 0.0              # paid the checkpoint
    assert lo["outage"] > 0.0            # sat out hi's run
    assert lo["restore"] > 0.0           # paid the restore on resume
    lo_end = next(r["t"] for r in tr.records
                  if r["kind"] == "job_complete" and r["job"] == "lo")
    assert sum(lo.values()) == pytest.approx(lo_end - 0.0, rel=1e-9)
    assert reconcile(tr.records) == []
    hi = sim.phases.phases_of("hi")
    assert hi["outage"] == 0.0 and hi["ckpt"] == 0.0


def test_boot_wait_attributed_on_cloud_scale_up():
    pool = NodePool("od", slots_per_node=8, price_per_slot_hour=0.048,
                    boot_latency=120.0, teardown_delay=30.0,
                    initial_nodes=1, max_nodes=4, zone="z1")
    prov = CloudProvider([pool], seed=5)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=15.0, scale_up_cooldown=15.0,
        scale_down_cooldown=120.0, idle_timeout=600.0))
    sim = CloudSimulator(prov, PolicyConfig(rescale_gap=0.0),
                         autoscaler=asc)
    for i in range(3):                   # 24 slots wanted, 8 live
        sim.submit(JobSpec(f"j{i}", 1, 8, 8, 0.0), wl(300))
    m = sim.run()
    per_job = sim.phases.per_job()
    assert any(ph["boot_wait"] > 0.0 for ph in per_job.values()), \
        "some job must wait out a node boot"
    assert sum(m.phase_seconds.values()) == \
        pytest.approx(m.weighted_mean_completion, rel=1e-9)


# ---------------------------------------------------------------------------
# rollups and reconciliation
# ---------------------------------------------------------------------------

def test_rollup_is_priority_weighted():
    zero = {p: 0.0 for p in PHASES}
    per_job = {"a": dict(zero, compute=10.0),
               "b": dict(zero, compute=30.0, queue_wait=2.0)}
    fleet = rollup(per_job, {"a": 1, "b": 3})
    # (1*10 + 3*30) / 4, exactly like WMCT weighting
    assert fleet.phase_seconds["compute"] == pytest.approx(25.0)
    assert fleet.phase_seconds["queue_wait"] == pytest.approx(1.5)
    assert fleet.phase_by_priority["prio1.compute"] == 10.0
    assert fleet.phase_by_priority["prio3.compute"] == 30.0
    assert fleet.dominant_phase == {"compute": 2}
    assert fleet.shares()["compute"] == pytest.approx(25.0 / 26.5)
    assert rollup({}, {}).jobs == 0


def test_analyze_includes_causal_chain():
    specs = make_jacobi_jobs(seed=7, n_jobs=6, submission_gap=60.0)
    with install(Tracer()) as tr:
        run_variant("elastic_preempt", specs, total_slots=24)
    fleet = analyze(tr.records)
    assert fleet.jobs == 6
    assert fleet.longest_causal_chain >= 1


def test_reconcile_flags_uncovered_makespan():
    # a preempt with no resume record: the outage is never closed into the
    # partition, so the phase sum cannot cover the makespan
    records = [
        {"kind": "job_submit", "t": 0.0, "job": "j", "priority": 1},
        {"kind": "job_start", "t": 10.0, "job": "j", "slots": 4},
        {"kind": "job_preempt", "t": 50.0, "job": "j", "slots": 4,
         "ckpt_s": 0.0},
        {"kind": "job_complete", "t": 100.0, "job": "j", "slots": 4},
    ]
    violations = reconcile(records)
    assert len(violations) == 1 and "j:" in violations[0]
    # restoring the resume closes the partition again
    fixed = records[:3] + [
        {"kind": "job_start", "t": 80.0, "job": "j", "slots": 4,
         "resume": True, "overhead_s": 0.0},
    ] + records[3:]
    assert reconcile(fixed) == []
