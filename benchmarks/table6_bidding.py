"""Table 6 (beyond-paper) — demand-aware per-zone spot bidding vs. the
static ``spot_fraction`` split, across skewed reclaim regimes.

The autoscaler's static spot share buys the same zone mix no matter what the
market does to it.  The :class:`~repro.cloud.bidding.DemandAwareBidder`
instead folds every kill's realized preemption cost (checkpoint write +
restore at the victim's slot count, outage lost-work, cross-region transfer)
into a per-zone risk ledger and closes zones whose observed risk-cost rate
outruns the spot discount they buy.  This grid replays bursty (MMPP) and
heavy-tailed traces through a THREE-ZONE fleet and sweeps the bidding policy
against the shape of the reclaim pressure:

- ``uniform``     every spot zone carries the same mild correlated-reclaim
                  stream: no zone is worth abandoning (risk below each
                  zone's break-even), so the bidder must match the static
                  split — and its dollars.
- ``one_hot``     one zone is wiped whole every ~4 min — an order of
                  magnitude hotter than its discount justifies; the bidder
                  should abandon it (fewer preemptions, lower WMCT) while
                  static keeps buying back into the fire after every wipe
                  (a freshly-wiped zone is the least saturated, so it is
                  static's FIRST preference).
- ``escalating``  the hot zone starts calm and its reclaims accelerate
                  (injected bursts at shrinking gaps): the bidder exits
                  mid-run once the evidence accrues.

Scenario physics (what makes the trade-off bite): pack placement parks each
job inside one zone, elasticity 1.25 makes a whole-node loss un-absorbable
(checkpoint-preempt, not shrink), 2 GB/slot checkpoints go to DISK on
preemption, and 300 s spot boots make every wipe a long outage.

Verdict (PASS/FAIL, per the ISSUE-5 acceptance bar): demand-aware spends no
more than static under ``uniform`` risk, AND strictly beats it on both
preemption-overhead dollars and WMCT under ``one_hot`` (where the hot
zone's observed kill rate exceeds its discount's break-even).  The
``escalating`` row is reported (adaptation speed), not gated.
"""
import time

if __package__ in (None, ""):       # `python benchmarks/table6_bidding.py`
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, kv, phases_kv
from repro.cloud import (SPOT, AutoscalerConfig, BidderConfig, CloudProvider,
                         DemandAwareBidder, NodeAutoscaler, NodePool)
from repro.workloads import ReplayConfig, generate, replay_cloud

CLUSTER_SLOTS = 48
SLOTS_PER_NODE = 8
PRICE_OD = 0.048
PRICE_SPOT = 0.016
N_JOBS = 32
DURATION_MEDIAN = 900.0
SEEDS = (5, 13, 29)
WORKLOADS = ("bursty", "heavy_tail")
POLICIES = ("static", "demand_aware")
HOT_ZONE = "east-1b"

#: per-zone (mean seconds between correlated reclaim events, fraction of
#: the zone's UP spot nodes per event).  ``uniform`` is mild everywhere (a
#: partial wipe per zone per half hour — below every zone's break-even);
#: ``one_hot`` wipes ONE zone whole every ~4 min (far past break-even; a
#: freshly-wiped zone is the least saturated, so static keeps buying back
#: into the fire); ``escalating`` starts calm and injects hot-zone bursts
#: at shrinking gaps instead.
REGIMES = {
    "uniform": ({"east-1b": 1800.0, "east-1c": 1800.0, "west-2a": 1800.0},
                0.5),
    "one_hot": ({HOT_ZONE: 240.0}, 1.0),
    "escalating": ({}, 1.0),
}
#: injected hot-zone bursts for the escalating regime: calm first third,
#: then reclaim gaps shrink 900 -> 300 s (the market deteriorating)
ESCALATION = (1500.0, 2400.0, 3100.0, 3650.0, 4100.0, 4500.0, 4850.0,
              5150.0, 5450.0, 5750.0, 6050.0)


def _bidder():
    # min_evidence 3: one uniform partial wipe (1-2 nodes) is an anecdote
    # and keeps the prior; the hot zone's ~4-min kill cadence accumulates
    # decayed evidence past 3 within a few wipes.  risk_aversion 10 weights
    # the realized pain (and the kill-frequency floor) enough to cross the
    # 1.25 close threshold on the hot cadence, while the uniform streams
    # mostly stay below the evidence threshold (the occasional symmetric
    # reclassification never changes a buying decision: spend is identical)
    return DemandAwareBidder(BidderConfig(
        half_life=1800.0, hysteresis=0.25, risk_aversion=10.0,
        min_evidence_kills=3.0, spot_fraction_max=0.5))


def _provider(regime: str, seed: int) -> CloudProvider:
    intervals, fraction = REGIMES[regime]
    pools = [
        NodePool("od-east", slots_per_node=SLOTS_PER_NODE,
                 price_per_slot_hour=PRICE_OD, boot_latency=120.0,
                 teardown_delay=30.0, initial_nodes=1, max_nodes=3,
                 region="east", zone="east-1a"),
    ]
    for region, zone, init in (("east", "east-1b", 1), ("east", "east-1c", 1),
                               ("west", "west-2a", 1)):
        pools.append(NodePool(
            f"spot-{zone}", slots_per_node=SLOTS_PER_NODE,
            price_per_slot_hour=PRICE_SPOT, market=SPOT,
            boot_latency=300.0, teardown_delay=30.0, initial_nodes=init,
            max_nodes=6, spot_lifetime_mean=14400.0, region=region,
            zone=zone))
    return CloudProvider(
        pools, seed=seed,
        region_price_multipliers={"east": 1.0, "west": 1.08},
        zone_reclaim_interval=intervals or None,
        zone_reclaim_fraction=fraction, transfer_price_per_gb=0.02)


def run_cell(trace, regime: str, policy: str, seed: int):
    prov = _provider(regime, seed)
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=240.0, spot_fraction=0.75,
        bidder=_bidder() if policy == "demand_aware" else None))
    # elasticity 1.25: a whole-node loss exceeds the shrink headroom, so a
    # zone wipe checkpoint-preempts its packed residents (disk, 2 GB/slot)
    cfg = ReplayConfig(cluster_slots=CLUSTER_SLOTS, elasticity=1.25,
                       bytes_per_slot=2.0e9)

    def inject(sim):
        if regime == "escalating":
            for t in ESCALATION:
                prov.inject_zone_reclaim(HOT_ZONE, t, sim.queue)
    sim = replay_cloud(trace, cfg, prov, variant="elastic", autoscaler=asc,
                       placement="pack", pre_run=inject)
    return sim.metrics


def _mean(xs):
    return sum(xs) / len(xs)


def run():
    agg = {}
    for regime in REGIMES:
        for policy in POLICIES:
            cells = []
            t0 = time.perf_counter()
            for wname in WORKLOADS:
                for seed in SEEDS:
                    kw = ({"duration_scale": DURATION_MEDIAN / 2}
                          if wname == "heavy_tail"
                          else {"duration_median": DURATION_MEDIAN})
                    trace = generate(wname, n_jobs=N_JOBS, seed=seed,
                                     **kw).normalized(CLUSTER_SLOTS,
                                                      max_fraction=0.2)
                    cells.append(run_cell(trace, regime, policy, seed))
            us = (time.perf_counter() - t0) * 1e6 / len(cells)
            agg[(regime, policy)] = a = dict(
                wmct=_mean([m.weighted_mean_completion for m in cells]),
                cost=_mean([m.total_cost for m in cells]),
                idle=_mean([m.idle_cost for m in cells]),
                ovh=_mean([m.preempt_overhead_cost for m in cells]),
                xfer=_mean([m.transfer_cost for m in cells]),
                kills=_mean([m.spot_preemptions for m in cells]),
                reclaims=_mean([m.zone_reclaims for m in cells]),
                bids=_mean([m.bid_adjustments for m in cells]),
                hot_share=_mean([m.spot_share_by_zone.get(HOT_ZONE, 0.0)
                                 for m in cells]),
                dropped=sum(m.dropped_jobs for m in cells),
            )
            emit(f"table6.{regime}.{policy}", us, kv(
                wmct=a["wmct"], cost=a["cost"], idle=a["idle"],
                ovh=a["ovh"], xfer=a["xfer"], kills=a["kills"],
                zone_reclaims=a["reclaims"], bids=a["bids"],
                hot_share=a["hot_share"], dropped=a["dropped"]))
            emit(f"table6.{regime}.{policy}.phases", 0.0, phases_kv(cells))

    # verdict per the ISSUE-5 acceptance bar: matches static's dollars when
    # no zone is worth abandoning; strictly beats it on preemption-overhead
    # dollars AND WMCT when one zone's kill rate outruns its discount
    uni_s, uni_d = agg[("uniform", "static")], agg[("uniform", "demand_aware")]
    hot_s, hot_d = agg[("one_hot", "static")], agg[("one_hot", "demand_aware")]
    uniform_ok = uni_d["cost"] <= uni_s["cost"] * 1.005 + 1e-9
    one_hot_ok = (hot_d["ovh"] < hot_s["ovh"] and
                  hot_d["wmct"] < hot_s["wmct"] and
                  hot_s["dropped"] == 0 and hot_d["dropped"] == 0)
    emit("table6.verdict.uniform", 0.0, kv(
        "PASS" if uniform_ok else "FAIL",
        cost_demand=uni_d["cost"], cost_static=uni_s["cost"],
        bids_demand=uni_d["bids"]))
    emit("table6.verdict.one_hot", 0.0, kv(
        "PASS" if one_hot_ok else "FAIL",
        ovh_demand=hot_d["ovh"], ovh_static=hot_s["ovh"],
        wmct_demand=hot_d["wmct"], wmct_static=hot_s["wmct"],
        hot_share_demand=hot_d["hot_share"], hot_share_static=hot_s["hot_share"]))
    # adaptation speed under deteriorating markets: reported, not gated
    esc_s = agg[("escalating", "static")]
    esc_d = agg[("escalating", "demand_aware")]
    emit("table6.escalating.summary", 0.0, kv(
        ovh_delta=esc_d["ovh"] - esc_s["ovh"],
        wmct_delta=esc_d["wmct"] - esc_s["wmct"],
        bids_demand=esc_d["bids"]))
    emit("table6.verdict.demand_aware_bidding", 0.0,
         "PASS" if (uniform_ok and one_hot_ok) else "FAIL")
    return agg


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
