"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,table1] [--fast]
                                          [--trace [--trace-dir DIR]]

``--trace`` installs the repro.obs flight recorder around every module: each
table/figure writes ``DIR/<name>.jsonl`` (structured span/event records —
the input of ``python -m repro.obs.audit``) plus ``DIR/<name>.timeline.txt``
(the text Gantt of the file's last run).  Tracing rides the module-global
``obs.trace.install`` hook, so the modules themselves stay trace-agnostic.

``--profile`` installs a fresh :class:`repro.obs.profile.SimProfiler` around
each module the same way and prints per-module ``<name>.profile.*`` rows
(per-event-kind handler cost, heap/metrics section cost) after the module's
own rows — where each table's wall-clock actually goes.
"""
import argparse
import os
import sys
import traceback

MODULES = [
    ("fig4", "benchmarks.fig4_scaling"),
    ("fig5", "benchmarks.fig5_rescale_overhead"),
    ("fig6", "benchmarks.fig6_timeline"),
    ("fig7", "benchmarks.fig7_submission_gap"),
    ("fig8", "benchmarks.fig8_rescale_gap"),
    ("table1", "benchmarks.table1_policies"),
    ("table2", "benchmarks.table2_cloud_cost"),
    ("table3", "benchmarks.table3_placement"),
    ("table4", "benchmarks.table4_traces"),
    ("table5", "benchmarks.table5_zones"),
    ("table6", "benchmarks.table6_bidding"),
    ("roofline", "benchmarks.roofline"),
]


def _run_traced(name, fn, trace_dir: str) -> None:
    from repro.obs.timeline import render_last_run
    from repro.obs.trace import Tracer, install
    os.makedirs(trace_dir, exist_ok=True)
    path = os.path.join(trace_dir, f"{name}.jsonl")
    with Tracer(path) as tracer, install(tracer):
        fn()
    records = Tracer.load(path)
    if records:
        art = os.path.join(trace_dir, f"{name}.timeline.txt")
        with open(art, "w") as fh:
            fh.write(render_last_run(records) + "\n")


def _emit_profile(name, prof) -> None:
    from benchmarks.common import emit, kv
    report = prof.report()
    for kind, row in report["events"].items():
        emit(f"{name}.profile.event.{kind}", row["mean_us"],
             kv(count=row["count"], total_s=row["total_s"]))
    for sec, row in report["sections"].items():
        emit(f"{name}.profile.section.{sec}", row["mean_us"],
             kv(count=row["count"], total_s=row["total_s"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="fewer seeds for the simulation sweeps; fig5 skips "
                         "its live-subprocess section (sim+model only)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-module trace JSONL + timeline artifacts")
    ap.add_argument("--trace-dir", default="trace-artifacts")
    ap.add_argument("--profile", action="store_true",
                    help="self-profile each module's simulator event loop "
                         "and print <name>.profile.* rows")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    for name, module in MODULES:
        if only and name not in only:
            continue
        try:
            import importlib
            mod = importlib.import_module(module)
            if args.fast and name in ("fig7", "fig8"):
                fn = lambda: mod.run(seeds=range(3))  # noqa: E731
            elif args.fast and name == "fig5":
                fn = lambda: mod.run(sim_only=True)  # noqa: E731
            else:
                fn = mod.run
            if args.profile:
                from repro.obs.profile import SimProfiler, install_profiler
                prof = SimProfiler()
                inner = fn

                def fn(inner=inner, prof=prof):
                    with install_profiler(prof):
                        inner()
            if args.trace:
                _run_traced(name, fn, args.trace_dir)
            else:
                fn()
            if args.profile:
                _emit_profile(name, prof)
        except Exception as e:
            print(f"{name}.ERROR,0.0,{e!r}"[:400].replace("\n", " "))
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
