"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,table1] [--fast]
"""
import argparse
import sys
import traceback

MODULES = [
    ("fig4", "benchmarks.fig4_scaling"),
    ("fig5", "benchmarks.fig5_rescale_overhead"),
    ("fig6", "benchmarks.fig6_timeline"),
    ("fig7", "benchmarks.fig7_submission_gap"),
    ("fig8", "benchmarks.fig8_rescale_gap"),
    ("table1", "benchmarks.table1_policies"),
    ("table2", "benchmarks.table2_cloud_cost"),
    ("table3", "benchmarks.table3_placement"),
    ("table4", "benchmarks.table4_traces"),
    ("table5", "benchmarks.table5_zones"),
    ("table6", "benchmarks.table6_bidding"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="fewer seeds for the simulation sweeps")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}

    print("name,us_per_call,derived")
    for name, module in MODULES:
        if only and name not in only:
            continue
        try:
            import importlib
            mod = importlib.import_module(module)
            if args.fast and name in ("fig7", "fig8"):
                mod.run(seeds=range(3))
            else:
                mod.run()
        except Exception as e:
            print(f"{name}.ERROR,0.0,{e!r}"[:400].replace("\n", " "))
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
