"""Table 4 (beyond-paper) — scheduler policies x workload shapes.

The paper's verdicts all rest on one arrival pattern (near-uniform synthetic
streams).  This grid replays the SAME scheduler variants under five workload
shapes — uniform, bursty (MMPP), diurnal, heavy-tailed sizes/durations, and
a bundled Azure-style trace fixture — through :class:`CloudSimulator`, so
the elastic-vs-static comparison faces realistic burstiness and job-size
tails (the axis Zojer et al. show flips scheduler rankings).

Cells per workload:

- ``rigid_static``    non-malleable jobs at their observed request size on a
                      fixed max fleet (what a conventional batch scheduler
                      would have run for this trace)
- ``moldable_static`` size picked at launch, never rescaled, same fleet
- ``elastic_static``  the paper's elastic policy, same fleet
- ``elastic_auto``    elastic policy + CLUES-style node autoscaler (fleet
                      grows from 1 node under queue pressure)

Every row carries the workload's characterization columns (interarrival CV,
burstiness index, peak/mean rate, size-tail Hill alpha) so a verdict is
never quoted without naming the pressure it was measured under.

Verdict (PASS/FAIL): on EVERY workload shape, elastic beats static —
``elastic_static`` improves weighted mean completion time over
``rigid_static``, and ``elastic_auto`` spends fewer dollars than the static
max fleet.  Rows are reproducible: generators are pure functions of
``SEED``; the fixture is checked in.
"""
import time

if __package__ in (None, ""):       # `python benchmarks/table4_traces.py`
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, kv, phases_kv
from repro.cloud import (AutoscalerConfig, CloudProvider, NodeAutoscaler,
                         NodePool)
from repro.workloads import (ReplayConfig, characterize, fixture_path,
                             generate, load_azure_trace, replay_cloud)

CLUSTER_SLOTS = 64              # 8 nodes x 8 slots, the paper's scale
SLOTS_PER_NODE = 8
MAX_NODES = CLUSTER_SLOTS // SLOTS_PER_NODE
PRICE = 0.048                   # $/slot-hour (~c5.2xlarge / 8 vCPU)
N_JOBS = 24
SEED = 17

WORKLOADS = ("uniform", "bursty", "diurnal", "heavy_tail", "azure_sample")
POLICIES = ("rigid_static", "moldable_static", "elastic_static",
            "elastic_auto")


def make_workload(name: str):
    """A normalized Trace for one grid row — seeded generator or the
    checked-in fixture, always rescaled to the benchmark cluster."""
    if name == "azure_sample":
        raw = load_azure_trace(fixture_path("azure_sample.csv"))
    else:
        raw = generate(name, n_jobs=N_JOBS, seed=SEED)
    return raw.normalized(CLUSTER_SLOTS)


def _provider(autoscaled: bool) -> CloudProvider:
    return CloudProvider([NodePool(
        "od", slots_per_node=SLOTS_PER_NODE, price_per_slot_hour=PRICE,
        boot_latency=120.0, teardown_delay=30.0, max_nodes=MAX_NODES,
        initial_nodes=1 if autoscaled else MAX_NODES)], seed=23)


def run_cell(trace, policy: str):
    variant = {"rigid_static": "rigid", "moldable_static": "moldable",
               "elastic_static": "elastic", "elastic_auto": "elastic"}[policy]
    autoscaled = policy == "elastic_auto"
    prov = _provider(autoscaled)
    autoscaler = None
    if autoscaled:
        autoscaler = NodeAutoscaler(prov, AutoscalerConfig(
            tick_interval=30.0, scale_up_cooldown=30.0,
            scale_down_cooldown=120.0, idle_timeout=180.0,
            headroom_slots=SLOTS_PER_NODE))
    cfg = ReplayConfig(cluster_slots=CLUSTER_SLOTS)
    return replay_cloud(trace, cfg, prov, variant=variant,
                        autoscaler=autoscaler).metrics


def run():
    results = {}
    for wname in WORKLOADS:
        trace = make_workload(wname)
        stats = characterize(trace)
        emit(f"table4.workload.{wname}", 0.0, stats.kv())
        for policy in POLICIES:
            t0 = time.perf_counter()
            m = run_cell(trace, policy)
            us = (time.perf_counter() - t0) * 1e6
            results[(wname, policy)] = m
            emit(f"table4.{wname}.{policy}", us, kv(
                cost=m.total_cost, idle=m.idle_cost,
                wmct=m.weighted_mean_completion, util=m.utilization,
                dropped=m.dropped_jobs, rescales=m.rescale_count,
                cv=stats.interarrival_cv, burst=stats.burstiness))
            emit(f"table4.{wname}.{policy}.phases", 0.0, phases_kv(m))

    # verdict: elastic beats static on EVERY workload shape — better WMCT at
    # equal capacity, fewer dollars under autoscaled provisioning
    all_ok = True
    for wname in WORKLOADS:
        rigid = results[(wname, "rigid_static")]
        el_st = results[(wname, "elastic_static")]
        el_au = results[(wname, "elastic_auto")]
        wmct_gain = 1.0 - el_st.weighted_mean_completion / \
            rigid.weighted_mean_completion
        saving = 1.0 - el_au.total_cost / rigid.total_cost
        ok = (el_st.weighted_mean_completion < rigid.weighted_mean_completion
              and el_au.total_cost < rigid.total_cost
              and el_st.dropped_jobs == 0 and el_au.dropped_jobs == 0)
        all_ok &= ok
        emit(f"table4.verdict.{wname}", 0.0, kv(
            f"{'PASS' if ok else 'FAIL'}",
            wmct_gain=f"{wmct_gain:.1%}", cost_saving=f"{saving:.1%}"))
    emit("table4.verdict.elastic_beats_static_all_shapes", 0.0,
         "PASS" if all_ok else "FAIL")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
