"""Paper Table 1 — the four policies on one job set: simulation AND an
"actual" run (the live controller with real JAX training jobs on virtual
devices — the EKS analog this container can execute honestly).

The live run uses 8 slots and tiny jobs; absolute numbers differ from the
64-vCPU EKS cluster, but the table's *orderings* are the reproduction target
(DESIGN.md §6.5).
"""
import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import emit, metrics_kv

LIVE_HELPER = r"""
import json, math
import jax
from repro.configs import smoke_config
from repro.core import (ElasticClusterController, ElasticTrainer, JobSpec,
                        PolicyConfig, TrainJobConfig)

devs = jax.devices()

JOBS = [  # (id, priority, min, max, submit_tick, steps)
    ("j0", 3, 2, 8, 0.000, 12),
    ("j1", 5, 2, 4, 0.001, 8),
    ("j2", 1, 2, 8, 0.002, 10),
    ("j3", 4, 4, 8, 0.003, 8),
    ("j4", 2, 2, 4, 0.004, 8),
]

def factory(steps, seed):
    def f(devices):
        return ElasticTrainer(smoke_config("yi-6b"),
                              TrainJobConfig(global_batch=8, seq_len=16,
                                             total_steps=steps, seed=seed),
                              devices)
    return f

def run(variant):
    gap = 0.0 if variant in ("elastic",) else (math.inf if variant == "moldable" else 0.0)
    op = ElasticClusterController(devs, slots=8,
                                  policy=PolicyConfig(rescale_gap=gap),
                                  steps_per_tick=2)
    for i, (jid, prio, mn, mx, sub, steps) in enumerate(JOBS):
        if variant == "rigid_min":
            mn2 = mx2 = mn
        elif variant == "rigid_max":
            mn2 = mx2 = mx
        else:
            mn2, mx2 = mn, mx
        op.submit(JobSpec(jid, prio, mn2, mx2, sub, divides=8),
                  factory(steps, i))
    m = op.run()
    return dict(total=m.total_time, util=m.utilization,
                resp=m.weighted_mean_response,
                compl=m.weighted_mean_completion,
                rescales=m.rescale_count, dropped=m.dropped_jobs)

out = {v: run(v) for v in ("rigid_min", "rigid_max", "moldable", "elastic")}
print("JSON" + json.dumps(out))
"""


def run():
    import time

    from repro.core.simulator import VARIANTS, make_jacobi_jobs, run_variant

    # --- simulation columns (paper setup: gap 90 s, T_gap 180 s) ------------
    specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
    for v in VARIANTS:
        t0 = time.perf_counter()
        m = run_variant(v, specs, total_slots=64, rescale_gap=180.0)
        us = (time.perf_counter() - t0) * 1e6
        # machine-readable row off ScheduleMetrics.to_dict(); the resp_p99
        # prefix pulls the aggregate AND per-priority-class p99 response,
        # the phase_seconds prefix the per-phase makespan decomposition
        # counters.stale_events rides along: rescale-heavy variants show how
        # much dead weight (invalidated completions) the event heap carried
        emit(f"table1.sim.{v}", us, metrics_kv(
            m, "total_time", "utilization", "weighted_mean_response",
            "weighted_mean_completion", "rescale_count",
            "counters.events", "counters.stale_events",
            prefixes=("percentiles.resp_p99", "phase_seconds.")))

    # --- "actual" columns: live controller with real training jobs ----------
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", LIVE_HELPER],
                          capture_output=True, text=True, timeout=3600,
                          env=env)
    data = {}
    for line in proc.stdout.splitlines():
        if line.startswith("JSON"):
            data = json.loads(line[4:])
    if not data:
        emit("table1.live.FAILED", 0.0, proc.stderr[-200:].replace(",", ";"))
        return
    for v, m in data.items():
        emit(f"table1.live.{v}", m["total"] * 1e6,
             f"util={m['util']:.3f};resp={m['resp']:.2f};"
             f"compl={m['compl']:.2f};rescales={m['rescales']};"
             f"dropped={m['dropped']}")
