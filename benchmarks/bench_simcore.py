"""Simulator-core throughput + observability-overhead benchmark.

Two questions, answered into ``BENCH_simcore.json`` (the repo's first
machine-readable perf snapshot — CI uploads it per run so the trajectory of
the discrete-event core is diffable across commits):

- **throughput** — events/sec and wall-clock of the elastic policy as the
  job count grows (the event loop is the floor under every table; a
  regression here silently stretches the whole benchmark suite);
- **tracing overhead** — the flight recorder must be free when off.  The
  table1 policy grid runs (a) untraced (the ``NULL_TRACER`` default: every
  instrumentation site is one ``tracer.enabled`` attribute check) and
  (b) actively tracing to a JSONL file.  The *null* overhead — what every
  user pays — is additionally composed from a microbenchmarked per-site
  guard cost times the number of instrumented operations the grid actually
  executed; the acceptance bars are composed null overhead < 3% of grid
  wall-clock and active overhead under ``ACTIVE_OVERHEAD_CEILING_PCT``,
  each printed as a PASS/FAIL row;
- **profile** (schema 2) — a :class:`repro.obs.profile.SimProfiler` run of
  the largest throughput rung: per-event-kind handler cost, heap-op and
  metrics-tick cost, plus the profiler's own overhead — the instrument the
  ROADMAP event-loop refactor steers by;
- **peak_rss_bytes** (schema 2) — ``resource.getrusage`` high-water mark,
  diffed against the committed baseline by :mod:`repro.obs.watchdog`;
- **fleet** (schema 3) — the bounded-memory replay of a synthetic
  Google-shape trace (``workloads.google_fleet_trace``) at fleet scale.
  The smoke row (~20k jobs, 1.25k nodes, 3 days) always runs — it is the
  CI regression gate; pass ``--fleet-full`` (or set ``BENCH_FLEET_FULL=1``)
  for the month-long 10k-node ~1M-job row the ROADMAP acceptance names.
- **ckpt** (schema 4) — the checkpoint fast lane: full vs. delta disk-save
  walls and bytes (the delta must write strictly less — a machine-
  independent watchdog invariant) plus async submit/barrier latency, with
  the barrier required to publish the last submitted step.

Walls are best-of-N (min), not median: the grid is ~10 ms, where scheduler
noise is strictly additive — the minimum is the least-noisy estimate.  The
throughput rungs report ``events_retired`` = dispatched + stale-dropped:
pre-refactor the queue dispatched stale completions at full cost (they were
counted as events), post-refactor they are dropped inside the heap pass —
retired/sec is the like-for-like rate across both eras, and ``stale_events``
(schema 3) shows how much dead weight the heap carried.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_simcore [--out BENCH_simcore.json]
"""
import argparse
import json
import os
import sys
import tempfile
import time

from benchmarks.common import emit, kv
from repro.core.simulator import VARIANTS, make_jacobi_jobs, run_variant
from repro.obs.profile import SimProfiler, install_profiler
from repro.obs.trace import NULL_TRACER, Tracer, install
from repro.workloads import ReplayConfig, google_fleet_trace, replay_variant

JOB_COUNTS = (16, 32, 64, 128)
GRID_REPEATS = 7
#: active (file-writing) tracing may cost at most this much of grid wall.
#: Recalibrated for the fleet-scale hot-path refactor: the untraced grid is
#: ~2.3x faster, so the same absolute tracing cost (~2.7-4ms across the
#: grid, no worse than the pre-refactor ~3.1ms) now reads as ~40% instead
#: of ~21% — and the file-write noise that used to move the ratio a few
#: points now swings it 37-65% run to run.  The ceiling guards the tracer's
#: own cost, not the loop's, so it moves with the denominator: 90% trips
#: when tracing roughly doubles its absolute cost, and stays clear of the
#: observed noise band.
ACTIVE_OVERHEAD_CEILING_PCT = 90.0
#: instrumented emission sites executed per processed event, conservatively:
#: the run-loop guard itself plus the action-layer guards (start/rescale/
#: queue/complete each fire at most a few per event) — used to COMPOSE the
#: null overhead from the microbenchmarked per-site cost
SITES_PER_EVENT = 6.0


def _grid(seed: int = 7):
    specs = make_jacobi_jobs(seed=seed, n_jobs=16, submission_gap=90.0)
    for v in VARIANTS:
        run_variant(v, specs, total_slots=64, rescale_gap=180.0)


def _best_wall(fn, repeat: int) -> float:
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _guard_cost_s(n: int = 200_000) -> float:
    """Per-site cost of the disabled-path guard (`tracer.enabled` read)."""
    tracer = NULL_TRACER
    t0 = time.perf_counter()
    hits = 0
    for _ in range(n):
        if tracer.enabled:
            hits += 1
    dt = time.perf_counter() - t0
    assert hits == 0
    return dt / n


def bench_throughput():
    rows = []
    for n_jobs in JOB_COUNTS:
        specs = make_jacobi_jobs(seed=11, n_jobs=n_jobs,
                                 submission_gap=45.0)

        def rung():
            return run_variant("elastic", specs, total_slots=64,
                               rescale_gap=180.0)
        m = rung()                                    # warm + counters
        wall = _best_wall(rung, GRID_REPEATS)         # best-of-N like the grid
        events = m.counters.get("events", 0)
        stale = m.counters.get("stale_events", 0)
        retired = events + stale
        rows.append(dict(n_jobs=n_jobs, wall_s=wall, events=events,
                         stale_events=stale, events_retired=retired,
                         events_per_sec=events / wall if wall > 0 else 0.0,
                         events_retired_per_sec=retired / wall
                         if wall > 0 else 0.0,
                         completions=m.counters.get("completions", 0)))
        emit(f"bench_simcore.throughput.jobs{n_jobs}", wall * 1e6,
             kv(events=events, stale_events=stale,
                events_per_sec=rows[-1]["events_per_sec"],
                events_retired_per_sec=rows[-1]["events_retired_per_sec"]))
    return rows


# -- fleet-scale replay (schema 3) -------------------------------------------

#: (name, n_jobs, nodes, days) — smoke is the always-on CI gate; full is the
#: ROADMAP acceptance row (month-long, 10k nodes, ~1M jobs)
FLEET_SMOKE = ("smoke", 20_000, 1_250, 3.0)
FLEET_FULL = ("full", 1_000_000, 10_000, 30.0)
FLEET_SLOTS_PER_NODE = 8
FLEET_SEED = 3


def bench_fleet(full: bool = False):
    """Replay the Google-shape fleet trace through the simulator's
    bounded-memory mode (O(1) utilization accumulators, no phase ledger).
    One run per row — at these scales the wall is seconds-to-minutes, far
    above scheduler noise."""
    rows = []
    scales = (FLEET_SMOKE, FLEET_FULL) if full else (FLEET_SMOKE,)
    for name, n_jobs, nodes, days in scales:
        capacity = nodes * FLEET_SLOTS_PER_NODE
        trace = google_fleet_trace(
            n_jobs=n_jobs, seed=FLEET_SEED, days=days, nodes=nodes,
            slots_per_node=FLEET_SLOTS_PER_NODE).bucket_priorities()
        load = trace.slot_seconds / (capacity * days * 86400.0)
        t0 = time.perf_counter()
        m = replay_variant(
            trace, "elastic",
            ReplayConfig(cluster_slots=capacity, rescale_gap=1800.0),
            slots_per_node=FLEET_SLOTS_PER_NODE,
            util_series=False, track_phases=False)
        wall = time.perf_counter() - t0
        events = m.counters.get("events", 0)
        stale = m.counters.get("stale_events", 0)
        retired = events + stale
        rows.append(dict(
            name=name, n_jobs=n_jobs, nodes=nodes,
            slots_per_node=FLEET_SLOTS_PER_NODE, days=days,
            offered_load=load, wall_s=wall, events=events,
            stale_events=stale, events_retired=retired,
            events_retired_per_sec=retired / wall if wall > 0 else 0.0,
            jobs_per_sec=n_jobs / wall if wall > 0 else 0.0,
            completions=m.counters.get("completions", 0),
            rescales=m.counters.get("rescales", 0),
            utilization=m.utilization, dropped_jobs=m.dropped_jobs))
        emit(f"bench_simcore.fleet.{name}", wall * 1e6, kv(
            n_jobs=n_jobs, nodes=nodes, wall_s=round(wall, 2),
            events=events, stale_events=stale,
            events_retired_per_sec=round(rows[-1]
                                         ["events_retired_per_sec"]),
            jobs_per_sec=round(rows[-1]["jobs_per_sec"]),
            utilization=round(m.utilization, 4)))
    return rows


def bench_tracing_overhead():
    # (a) untraced baseline: the NULL_TRACER default
    null_wall = _best_wall(_grid, GRID_REPEATS)

    # (b) actively tracing the same grid to a throwaway JSONL file
    def traced():
        path = tempfile.mktemp(suffix=".jsonl")
        try:
            with Tracer(path) as tr, install(tr):
                _grid()
        finally:
            if os.path.exists(path):
                os.unlink(path)
    active_wall = _best_wall(traced, GRID_REPEATS)

    # composed null overhead: per-site guard cost x sites executed
    specs = make_jacobi_jobs(seed=7, n_jobs=16, submission_gap=90.0)
    events = sum(
        run_variant(v, specs, total_slots=64,
                    rescale_gap=180.0).counters.get("events", 0)
        for v in VARIANTS)
    guard_s = _guard_cost_s()
    composed_null_s = guard_s * events * SITES_PER_EVENT
    null_pct = 100.0 * composed_null_s / null_wall
    active_pct = 100.0 * (active_wall / null_wall - 1.0)
    ok = null_pct < 3.0
    active_ok = active_pct < ACTIVE_OVERHEAD_CEILING_PCT
    emit("bench_simcore.tracing.null_overhead", composed_null_s * 1e6, kv(
        "PASS" if ok else "FAIL", null_pct=null_pct,
        guard_ns=guard_s * 1e9, sites=events * SITES_PER_EVENT,
        grid_wall_s=null_wall))
    emit("bench_simcore.tracing.active_overhead", active_wall * 1e6, kv(
        "PASS" if active_ok else "FAIL", active_pct=active_pct,
        ceiling_pct=ACTIVE_OVERHEAD_CEILING_PCT, null_wall_s=null_wall,
        active_wall_s=active_wall))
    return dict(grid_null_wall_s=null_wall, grid_active_wall_s=active_wall,
                active_overhead_pct=active_pct,
                active_overhead_ceiling_pct=ACTIVE_OVERHEAD_CEILING_PCT,
                active_overhead_under_ceiling=active_ok,
                guard_cost_ns=guard_s * 1e9,
                grid_events=events, sites_per_event=SITES_PER_EVENT,
                composed_null_overhead_pct=null_pct,
                null_overhead_under_3pct=ok)


def bench_profile():
    """Profile the largest throughput rung with the obs self-profiler: where
    does simulator wall-clock go, and what does watching it cost?"""
    n_jobs = JOB_COUNTS[-1]
    specs = make_jacobi_jobs(seed=11, n_jobs=n_jobs, submission_gap=45.0)

    def rung():
        run_variant("elastic", specs, total_slots=64, rescale_gap=180.0)

    plain_wall = _best_wall(rung, GRID_REPEATS)
    prof = SimProfiler()

    def profiled():
        with install_profiler(prof):
            rung()
    profiled_wall = _best_wall(profiled, GRID_REPEATS)
    prof.wall_s = profiled_wall * GRID_REPEATS  # accumulators span all reps
    report = prof.report()
    overhead_pct = 100.0 * (profiled_wall / plain_wall - 1.0) \
        if plain_wall > 0 else 0.0
    for kind, row in report["events"].items():
        emit(f"bench_simcore.profile.event.{kind}", row["mean_us"],
             kv(count=row["count"], total_s=row["total_s"]))
    for name, row in report["sections"].items():
        emit(f"bench_simcore.profile.section.{name}", row["mean_us"],
             kv(count=row["count"], total_s=row["total_s"]))
    emit("bench_simcore.profile.overhead", profiled_wall * 1e6,
         kv(profiler_overhead_pct=overhead_pct, plain_wall_s=plain_wall))
    report["n_jobs"] = n_jobs
    report["repeats"] = GRID_REPEATS
    report["profiler_overhead_pct"] = overhead_pct
    return report


def bench_ckpt():
    """Checkpoint fast-lane micro-bench (schema 4): full vs. delta save
    bytes/walls and async submit/barrier latency on a table5-shaped state
    tree (cold-weight majority + hot optimizer minority), pure numpy — no
    devices involved, so the rows are stable enough to diff."""
    import shutil

    import numpy as np

    from repro.checkpoint import AsyncCheckpointer, DiskCheckpointStore

    rng = np.random.default_rng(0)
    cold = {f"layer{i}": rng.standard_normal(65536).astype(np.float32)
            for i in range(8)}
    hot = {f"slab{i}": rng.standard_normal(16384).astype(np.float32)
           for i in range(4)}
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        store = DiskCheckpointStore(root)
        full_wall = _best_wall(
            lambda: store.save("job", 0, {"weights": cold, "opt": hot}),
            GRID_REPEATS)
        full_bytes = store.last_bytes_written

        step = [0]

        def delta_save():
            step[0] += 1
            hot2 = {k: v + step[0] for k, v in hot.items()}
            store.save("job", step[0], {"weights": cold, "opt": hot2},
                       delta=True)
        delta_wall = _best_wall(delta_save, GRID_REPEATS)
        delta_bytes = store.last_bytes_written
        load_wall = _best_wall(lambda: store.load("job"), GRID_REPEATS)

        ac = AsyncCheckpointer(store, delta=True)
        t0 = time.perf_counter()
        for i in range(3):
            hot2 = {k: v + 100 + i for k, v in hot.items()}
            ac.submit("job", 1000 + i, {"weights": cold, "opt": hot2})
        submit_wall = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        ac.barrier()
        barrier_wall = time.perf_counter() - t0
        published = store.latest_step("job")
        ac.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)

    rows = dict(
        full_save_us=full_wall * 1e6, delta_save_us=delta_wall * 1e6,
        load_us=load_wall * 1e6, full_bytes=full_bytes,
        delta_bytes=delta_bytes,
        delta_ratio=delta_bytes / full_bytes if full_bytes else 1.0,
        async_submit_us=submit_wall * 1e6,
        async_barrier_us=barrier_wall * 1e6,
        async_published_latest=published == 1002)
    emit("bench_simcore.ckpt.full_save", rows["full_save_us"],
         kv(bytes=full_bytes))
    emit("bench_simcore.ckpt.delta_save", rows["delta_save_us"],
         kv(bytes=delta_bytes, ratio=round(rows["delta_ratio"], 3)))
    emit("bench_simcore.ckpt.load", rows["load_us"], "")
    emit("bench_simcore.ckpt.async", rows["async_submit_us"],
         kv("PASS" if rows["async_published_latest"] else "FAIL",
            barrier_us=rows["async_barrier_us"]))
    return rows


def _peak_rss_bytes():
    """High-water RSS of this process (the bench is the workload), or None
    where the resource module is unavailable (non-POSIX)."""
    try:
        import resource
    except ImportError:                           # pragma: no cover
        return None
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # Linux reports KiB, macOS bytes
    return peak * 1024 if sys.platform != "darwin" else peak


def run(out: str = "BENCH_simcore.json", fleet_full: bool = False):
    throughput = bench_throughput()
    tracing = bench_tracing_overhead()
    profile = bench_profile()
    fleet = bench_fleet(full=fleet_full)
    ckpt = bench_ckpt()
    peak_rss = _peak_rss_bytes()
    payload = dict(bench="simcore", schema=4, throughput=throughput,
                   tracing=tracing, profile=profile, fleet=fleet,
                   ckpt=ckpt, peak_rss_bytes=peak_rss)
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    emit("bench_simcore.json", 0.0, f"path={out}")
    if peak_rss:
        emit("bench_simcore.peak_rss", 0.0, kv(bytes=peak_rss,
                                               mb=round(peak_rss / 1e6, 1)))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_simcore.json")
    ap.add_argument("--fleet-full", action="store_true",
                    help="also run the month-long 10k-node ~1M-job fleet "
                         "row (minutes of wall-clock; the smoke row always "
                         "runs)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out, fleet_full=args.fleet_full
        or os.environ.get("BENCH_FLEET_FULL") == "1")
