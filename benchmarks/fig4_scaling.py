"""Paper Fig. 4 — strong scaling of the two workloads.

The paper measures Jacobi2D (communication-bound) and LeanMD (compute-bound)
on EKS.  Here: (a) the calibrated analytic Jacobi model that feeds the
simulator (exact paper grid sizes), and (b) real measured step times of a JAX
Jacobi2D stencil (examples/jacobi2d_elastic.py's kernel) across problem sizes
on this host — the measured column is the "LeanMD-like compute scaling" stand-
in since a 1-core container cannot show multi-replica speedup honestly.
"""
from benchmarks.common import emit, time_call


def run():
    from repro.core.perf_model import JACOBI_SIZES, JacobiModel

    for size, d in JACOBI_SIZES.items():
        m = JacobiModel(d["grid_n"], d["timesteps"])
        for p in (1, 2, 4, 8, 16, 32, 64):
            t = m.time_per_step(p)
            emit(f"fig4.jacobi_model.{size}.p{p}", t * 1e6,
                 f"speedup_vs_1={m.time_per_step(1) / t:.2f}")

    # real stencil step on this host (single device), problem-size scaling
    import jax
    import jax.numpy as jnp

    @jax.jit
    def jacobi_step(grid):
        up = jnp.roll(grid, 1, 0)
        down = jnp.roll(grid, -1, 0)
        left = jnp.roll(grid, 1, 1)
        right = jnp.roll(grid, -1, 1)
        return 0.25 * (up + down + left + right)

    for n in (256, 512, 1024, 2048):
        g = jnp.zeros((n, n))
        jacobi_step(g).block_until_ready()          # compile
        us = time_call(lambda: jacobi_step(g).block_until_ready(), repeat=5)
        emit(f"fig4.jacobi_measured.n{n}", us,
             f"mpoints_per_s={n * n / us:.1f}")
