"""Table 2 (beyond-paper) — cloud cost vs. provisioning strategy.

Sweep: scheduling policy x provisioning {static-max, static-min,
node-autoscaled} x market {pure on-demand, 30%-spot}.  Every cell reports
total dollars, wasted-idle dollars, weighted mean completion time, and
makespan on the same 16-job small/medium Jacobi stream (their max_replicas
cap what elastic jobs can absorb, so static-max — a cluster sized for the
peak burst — pays for capacity nothing can use).

The derived verdict row checks the headline claim: the node-autoscaled
elastic variant is cheaper than static-max at comparable weighted mean
completion time.
"""
import time

from benchmarks.common import emit, metrics_kv
from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, CloudSimulator,
                         NodeAutoscaler, NodePool)
from repro.core.autoscale import PreemptingPolicy
from repro.core.policies import PolicyConfig
from repro.core.simulator import jacobi_workload, make_jacobi_jobs

PRICE_OD = 0.048            # $/slot-hour (~c5.2xlarge / 8 vCPU)
PRICE_SPOT = 0.016          # ~1/3 of on-demand
SLOTS_PER_NODE = 8
MAX_NODES = 8               # 64-slot ceiling, matching the paper's cluster

POLICIES = ("moldable", "elastic", "elastic_preempt")
PROVISIONING = ("static_max", "static_min", "autoscaled")
MARKETS = ("on_demand", "spot30")


def _pools(provisioning: str, market: str, seed_extra: int):
    spot = market == "spot30"
    od_nodes = {"static_max": MAX_NODES, "static_min": 4, "autoscaled": 1}[
        provisioning]
    pools = []
    if spot:
        # 30% of the static fleet from the spot market (rounded to nodes);
        # the autoscaler instead steers toward spot_fraction at runtime
        spot_nodes = {"static_max": 2, "static_min": 1, "autoscaled": 0}[
            provisioning]
        od_nodes = od_nodes - spot_nodes
        pools.append(NodePool(
            "spot", slots_per_node=SLOTS_PER_NODE,
            price_per_slot_hour=PRICE_SPOT, market=SPOT, boot_latency=90.0,
            teardown_delay=30.0, max_nodes=MAX_NODES,
            initial_nodes=spot_nodes, spot_lifetime_mean=1800.0))
    pools.append(NodePool(
        "od", slots_per_node=SLOTS_PER_NODE, price_per_slot_hour=PRICE_OD,
        boot_latency=120.0, teardown_delay=30.0, max_nodes=MAX_NODES,
        initial_nodes=od_nodes))
    return CloudProvider(pools, seed=11 + seed_extra)


def _policy(name: str, pcfg: PolicyConfig):
    if name == "elastic_preempt":
        return PreemptingPolicy(pcfg)
    return None                       # plain ElasticPolicy from the config


def run_cell(policy_name: str, provisioning: str, market: str, seed: int = 7):
    specs = make_jacobi_jobs(seed=seed, n_jobs=16, submission_gap=90.0,
                             sizes=("small", "medium"))
    pcfg = (PolicyConfig.moldable() if policy_name == "moldable"
            else PolicyConfig(rescale_gap=180.0))
    # deterministic per-cell RNG stream (hash() is randomized per process)
    prov = _pools(provisioning, market,
                  seed_extra=(POLICIES.index(policy_name) * len(PROVISIONING)
                              + PROVISIONING.index(provisioning)))
    autoscaler = None
    if provisioning == "autoscaled":
        autoscaler = NodeAutoscaler(prov, AutoscalerConfig(
            tick_interval=30.0, scale_up_cooldown=30.0,
            scale_down_cooldown=120.0, idle_timeout=180.0, headroom_slots=8,
            spot_fraction=0.3 if market == "spot30" else 0.0))
    sim = CloudSimulator(prov, pcfg, policy=_policy(policy_name, pcfg),
                         autoscaler=autoscaler)
    for s in specs:
        sim.submit(s, jacobi_workload(s.workload))
    return sim.run()


def run():
    results = {}
    for policy in POLICIES:
        for prov in PROVISIONING:
            for market in MARKETS:
                t0 = time.perf_counter()
                m = run_cell(policy, prov, market)
                us = (time.perf_counter() - t0) * 1e6
                results[(policy, prov, market)] = m
                emit(f"table2.{policy}.{prov}.{market}", us, metrics_kv(
                    m, "total_cost", "idle_cost",
                    "weighted_mean_completion", "total_time", "utilization",
                    "spot_preemptions", "dropped_jobs",
                    "percentiles.resp_p99",
                    "counters.events", "counters.stale_events",
                    prefixes=("phase_seconds.",)))

    # headline verdict: autoscaled elastic beats static-max elastic on cost
    # at comparable weighted mean completion time (pure on-demand cell)
    static = results[("elastic", "static_max", "on_demand")]
    scaled = results[("elastic", "autoscaled", "on_demand")]
    saving = 1.0 - scaled.total_cost / static.total_cost
    wmct_ratio = (scaled.weighted_mean_completion
                  / static.weighted_mean_completion)
    ok = scaled.total_cost < static.total_cost and wmct_ratio < 1.5
    emit("table2.verdict.autoscaled_vs_static_max", 0.0,
         f"cost_saving={saving:.1%};wmct_ratio={wmct_ratio:.2f};"
         f"{'PASS' if ok else 'FAIL'}")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
