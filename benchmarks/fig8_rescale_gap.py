"""Paper Fig. 8 — scheduler metrics vs. T_rescale_gap (submission gap 180 s)."""
import numpy as np

from benchmarks.common import emit


def run(seeds=range(12), tgaps=(0, 60, 180, 300, 600, 900, 1200)):
    import time

    from repro.core.simulator import VARIANTS, make_jacobi_jobs, run_variant

    for tg in tgaps:
        for v in ("elastic", "moldable", "rigid_min"):
            rows = []
            us = 0.0
            for seed in seeds:
                specs = make_jacobi_jobs(seed=seed, n_jobs=16,
                                         submission_gap=180.0)
                t0 = time.perf_counter()
                m = run_variant(v, specs, total_slots=64,
                                rescale_gap=float(tg))
                us += (time.perf_counter() - t0) * 1e6
                rows.append([m.total_time, m.utilization,
                             m.weighted_mean_response,
                             m.weighted_mean_completion, m.rescale_count])
            a = np.mean(rows, axis=0)
            emit(f"fig8.tgap{tg}.{v}", us / len(list(seeds)),
                 f"total={a[0]:.0f};util={a[1]:.3f};resp={a[2]:.1f};"
                 f"compl={a[3]:.1f};rescales={a[4]:.1f}")
