"""Roofline summary — reads results/dryrun.json (produced by
``python -m repro.launch.dryrun --all``) and emits the per-cell terms.
Run the dry-run first; this benchmark only reports."""
import json
import os

from benchmarks.common import emit


def run(path: str = "results/dryrun.json"):
    if not os.path.exists(path):
        emit("roofline.MISSING", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    with open(path) as f:
        data = json.load(f)
    for key in sorted(data):
        rec = data[key]
        if rec.get("status") == "skipped":
            emit(f"roofline.{rec['arch']}.{rec['shape']}.skipped", 0.0,
                 rec.get("reason", "")[:80].replace(",", ";"))
            continue
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        rl = rec["roofline"]
        mesh = rec.get("mesh", "?")
        step_us = rl["step_time_lower_bound"] * 1e6
        emit(f"roofline.{rec['arch']}.{rec['shape']}.{mesh}", step_us,
             f"bottleneck={rl['bottleneck']};"
             f"tc={rl['t_compute']:.4f};tm={rl['t_memory']:.4f};"
             f"tx={rl['t_collective']:.4f};"
             f"useful={rl['useful_flops_fraction']:.3f};"
             f"mfu_bound={rl['mfu_bound']:.3f};"
             f"mem_gb={rec.get('memory', {}).get('peak_bytes', 0) / 1e9:.1f};"
             f"fits={rec.get('fits_hbm')}")
