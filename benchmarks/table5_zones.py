"""Table 5 (beyond-paper) — correlated zone reclaims x placement strategy.

Real spot markets do not reclaim nodes independently: capacity crunches hit a
whole availability zone at once (cf. Kub, arXiv:2410.10655).  This grid
replays bursty (MMPP) and heavy-tailed traces through a THREE-ZONE,
TWO-REGION cloud and sweeps the correlated-reclaim severity against the
placement strategy:

- ``pack``         zone-oblivious: fill the fullest node first.  A job tends
                   to sit entirely inside one zone, so one zone reclaim takes
                   its whole allocation (checkpoint-preempt, full restart).
- ``zone_spread``  balance each job's slots across zones: a zone reclaim
                   takes at most ~1/zones of the job, which an elastic
                   shrink absorbs in place.

Severity sweeps the per-zone Poisson reclaim stream: ``calm`` disables it
(independent per-node fates only), ``mild`` reclaims half a zone's UP spot
nodes roughly twice per run, ``severe`` wipes whole zones more often.

Columns per cell: WMCT, blast radius (displaced slots per victim job per
kill), checkpoint-preemptions per kill, dollars (total / idle / inter-region
checkpoint transfer — a job preempted in region east and resumed on
replacement capacity in west drags its checkpoint across the boundary),
zone-reclaim event count, and dropped jobs.

Verdict (PASS/FAIL): under every correlated severity and on both workload
shapes, ``zone_spread`` beats ``pack`` on kill blast radius AND on weighted
mean completion time, with no dropped jobs; the dollar delta (diversification
is not free: spread capacity idles a little longer and west is pricier) is
quantified in the verdict row rather than gated.
"""
import time

if __package__ in (None, ""):       # `python benchmarks/table5_zones.py`
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, kv, phases_kv
from repro.cloud import (SPOT, AutoscalerConfig, CloudProvider, NodeAutoscaler,
                         NodePool)
from repro.workloads import ReplayConfig, generate, replay_cloud

CLUSTER_SLOTS = 48
SLOTS_PER_NODE = 8
PRICE_OD = 0.048
PRICE_SPOT = 0.016
# sustained concurrency is what makes placement discriminate (several jobs
# resident per node); a short sparse stream parks one job per node and both
# strategies produce the same blasts
N_JOBS = 24
DURATION_MEDIAN = 900.0
SEEDS = (5, 13, 29, 41, 57)
WORKLOADS = ("bursty", "heavy_tail")
PLACEMENTS = ("pack", "zone_spread")

#: (zone_reclaim_interval s, fraction of the zone's UP spot nodes per event)
SEVERITIES = {
    "calm": (None, 0.5),        # independent per-node churn only
    "mild": (1200.0, 0.5),
    "severe": (900.0, 1.0),     # whole-zone wipes, a few per run
}


def _provider(severity: str, seed: int) -> CloudProvider:
    interval, fraction = SEVERITIES[severity]
    pools = [
        # on-demand anchor in east: survives every reclaim, holds the queue
        NodePool("od-east", slots_per_node=SLOTS_PER_NODE,
                 price_per_slot_hour=PRICE_OD, boot_latency=120.0,
                 teardown_delay=30.0, initial_nodes=1, max_nodes=2,
                 region="east", zone="east-1a"),
    ]
    for region, zone, init in (("east", "east-1a", 1), ("east", "east-1b", 1),
                               ("west", "west-2a", 1)):
        # 300 s spot boots: during a capacity crunch replacement spot is NOT
        # back in 90 s — the window in which a checkpoint-preempted (pack)
        # job sits queued while a shrunk (zone_spread) job keeps running
        pools.append(NodePool(
            f"spot-{zone}", slots_per_node=SLOTS_PER_NODE,
            price_per_slot_hour=PRICE_SPOT, market=SPOT, boot_latency=300.0,
            teardown_delay=30.0, initial_nodes=init, max_nodes=3,
            spot_lifetime_mean=7200.0, region=region, zone=zone))
    return CloudProvider(
        pools, seed=seed,
        region_price_multipliers={"east": 1.0, "west": 1.08},
        zone_reclaim_interval=interval, zone_reclaim_fraction=fraction,
        transfer_price_per_gb=0.02)


def run_cell(trace, severity: str, placement: str, seed: int):
    prov = _provider(severity, seed)
    # headroom keeps jobs running ABOVE min_replicas: shrink-absorbing a
    # zone's worth of a job needs headroom between its current size and its
    # floor, and a scarcity-tuned fleet (everyone at min) has none
    asc = NodeAutoscaler(prov, AutoscalerConfig(
        tick_interval=30.0, scale_up_cooldown=30.0, scale_down_cooldown=120.0,
        idle_timeout=240.0, spot_fraction=0.75, headroom_slots=12))
    # elasticity 1.5 keeps min_replicas at ~2/3 of the natural size: losing
    # a third of a job (its zone-spread share of one zone) is absorbable in
    # place, losing its whole packed allocation is not — which is exactly
    # the shrink-vs-preempt trade-off the placement strategies differ on.
    # 2 GB/slot of checkpoint state makes that trade-off bite: preemption
    # checkpoints go to DISK (10x slower than the in-memory rescale path),
    # so a full-loss preempt costs ~10x a shrink-absorb
    cfg = ReplayConfig(cluster_slots=CLUSTER_SLOTS, elasticity=1.5,
                       bytes_per_slot=2.0e9)
    sim = replay_cloud(trace, cfg, prov, variant="elastic", autoscaler=asc,
                       placement=placement)
    return sim.metrics


def _mean(xs):
    return sum(xs) / len(xs)


def run():
    agg = {}
    for severity in SEVERITIES:
        for placement in PLACEMENTS:
            cells = []
            t0 = time.perf_counter()
            for wname in WORKLOADS:
                for seed in SEEDS:
                    kw = ({"duration_scale": DURATION_MEDIAN / 2}
                          if wname == "heavy_tail"
                          else {"duration_median": DURATION_MEDIAN})
                    # max_fraction 0.2 keeps the largest job near ONE node's
                    # worth of slots: placement only discriminates when
                    # several jobs share a node (a cluster-half-sized job
                    # blankets every node under either strategy)
                    trace = generate(wname, n_jobs=N_JOBS, seed=seed,
                                     **kw).normalized(CLUSTER_SLOTS,
                                                      max_fraction=0.2)
                    cells.append(run_cell(trace, severity, placement, seed))
            us = (time.perf_counter() - t0) * 1e6 / len(cells)
            agg[(severity, placement)] = a = dict(
                wmct=_mean([m.weighted_mean_completion for m in cells]),
                blast=_mean([m.zone_blast_radius for m in cells]),
                node_blast=_mean([m.kill_blast_radius for m in cells]),
                preempts=_mean([m.zone_preemptions for m in cells]),
                cost=_mean([m.total_cost for m in cells]),
                idle=_mean([m.idle_cost for m in cells]),
                xfer=_mean([m.transfer_cost for m in cells]),
                reclaims=_mean([m.zone_reclaims for m in cells]),
                kills=_mean([m.spot_preemptions for m in cells]),
                dropped=sum(m.dropped_jobs for m in cells),
            )
            emit(f"table5.{severity}.{placement}", us, kv(
                wmct=a["wmct"], blast=a["blast"],
                node_blast=a["node_blast"], preempts=a["preempts"],
                cost=a["cost"], idle=a["idle"], xfer=a["xfer"],
                zone_reclaims=a["reclaims"], kills=a["kills"],
                dropped=a["dropped"]))
            emit(f"table5.{severity}.{placement}.phases", 0.0,
                 phases_kv(cells))

    # verdict: under EVERY correlated severity, zone_spread shrinks the blast
    # radius and the WMCT vs zone-oblivious pack; the dollar delta is
    # reported, not gated (diversification costs a few idle/west cents)
    all_ok = True
    for severity in ("mild", "severe"):
        pack = agg[(severity, "pack")]
        zs = agg[(severity, "zone_spread")]
        ok = (zs["blast"] < pack["blast"] and zs["wmct"] < pack["wmct"]
              and pack["dropped"] == 0 and zs["dropped"] == 0)
        all_ok &= ok
        emit(f"table5.verdict.{severity}", 0.0, kv(
            "PASS" if ok else "FAIL",
            blast_zone_spread=zs["blast"], blast_pack=pack["blast"],
            wmct_zone_spread=zs["wmct"], wmct_pack=pack["wmct"],
            cost_delta=zs["cost"] - pack["cost"],
            xfer_zone_spread=zs["xfer"], xfer_pack=pack["xfer"]))
    emit("table5.verdict.zone_spread_absorbs_correlated_reclaims", 0.0,
         "PASS" if all_ok else "FAIL")
    _delta_ckpt_gate()
    return agg


def _delta_ckpt_gate():
    """CSV-gate row: on a table5-shaped per-slot state (mostly-cold weights +
    a hot optimizer minority, the 2 GB/slot physics scaled to MBs for CI),
    the delta checkpoint must write strictly fewer bytes than the full
    snapshot it follows."""
    import shutil
    import tempfile

    import numpy as np

    from repro.checkpoint import DiskCheckpointStore

    rng = np.random.default_rng(0)
    cold = {f"layer{i}": rng.standard_normal(65536).astype(np.float32)
            for i in range(8)}                       # frozen between preempts
    hot = {f"slab{i}": rng.standard_normal(16384).astype(np.float32)
           for i in range(4)}                        # churns every step
    root = tempfile.mkdtemp(prefix="table5_ckpt_")
    try:
        store = DiskCheckpointStore(root)
        store.save("physics", 100, {"weights": cold, "opt": hot})
        full_bytes = store.last_bytes_written
        hot2 = {k: v + 0.1 for k, v in hot.items()}
        store.save("physics", 200, {"weights": cold, "opt": hot2}, delta=True)
        delta_bytes = store.last_bytes_written
        flat, manifest = store.load("physics")
        intact = (manifest["delta"]
                  and all(np.array_equal(flat[f"weights/{k}"], cold[k])
                          for k in cold)
                  and all(np.array_equal(flat[f"opt/{k}"], hot2[k])
                          for k in hot2))
        ok = intact and delta_bytes < full_bytes
        emit("table5.verdict.delta_ckpt_writes_less", 0.0, kv(
            "PASS" if ok else "FAIL", full_bytes=full_bytes,
            delta_bytes=delta_bytes,
            ratio=round(delta_bytes / full_bytes, 3)))
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
